"""Fault-tolerant checkpointing: atomic, sharded, manifest-verified.

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json        # step, tree structure, per-leaf shape/dtype/crc
        leaf_00000.npy ...   # one .npy per pytree leaf (host-gathered)

Write protocol (atomicity against preemption mid-write):
  1. serialize into ``step_N.tmp-<pid>``,
  2. fsync files, write the manifest LAST (a checkpoint without a
     manifest is invalid by construction),
  3. atomic ``os.rename`` to ``step_N``.

``latest()``/``restore()`` skip temp dirs and any directory whose
manifest is missing or whose CRCs mismatch, so a job killed mid-save
restarts from the previous complete checkpoint.  ``keep`` bounds disk
use (old steps garbage-collected after a successful save).

At multi-pod scale the same protocol runs per-host against a shared
filesystem with per-leaf shard files; here leaves are host-gathered
numpy arrays, which is the single-process degenerate case of that
layout (the manifest format already records per-leaf sharding).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_MANIFEST = "manifest.json"

# keystr of a single-level {"name": leaf} dict: ['name'].  Flat-dict
# checkpoints (the serving-state layout repro.serve.recovery writes) are
# restored by NAME via restore_items, so the reader does not need a
# ``like`` tree whose structure it cannot know before reading.
_FLAT_KEY = re.compile(r"\['([^']*)'\]")


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [
        jax.tree_util.keystr(kp)
        for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write ------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None) -> str:
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = f"{final}.tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)

        leaves, paths, _ = _flatten_with_paths(state)
        entries = []
        for i, (leaf, path) in enumerate(zip(leaves, paths)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            fpath = os.path.join(tmp, fname)
            with open(fpath, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            entries.append(
                {
                    "path": path,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(arr.tobytes()),
                    "sharding": "replicated",  # single-host gather layout
                }
            )
        manifest = {"step": step, "leaves": entries, "extra": extra or {}}
        mpath = os.path.join(tmp, _MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):  # re-save of the same step
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    # -- read -------------------------------------------------------------
    def available_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if not name.startswith("step_") or ".tmp-" in name:
                continue
            if not os.path.exists(os.path.join(self.directory, name, _MANIFEST)):
                continue  # incomplete (killed mid-write)
            steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest(self) -> int | None:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; returns (state, extra).

        Verifies every leaf CRC; a corrupt checkpoint raises and the
        caller falls back to an earlier step (see ``restore_latest``).
        """
        if step is None:
            step = self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        cdir = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(cdir, _MANIFEST)) as f:
            manifest = json.load(f)

        leaves, paths, treedef = _flatten_with_paths(like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        out = []
        for leaf, path in zip(leaves, paths):
            e = by_path[path]
            arr = np.load(os.path.join(cdir, e["file"]))
            if zlib.crc32(arr.tobytes()) != e["crc32"]:
                raise IOError(f"crc mismatch for {path} in {cdir}")
            tgt_dtype = np.asarray(leaf).dtype if hasattr(leaf, "dtype") else arr.dtype
            out.append(arr.astype(tgt_dtype, copy=False))
        return jax.tree.unflatten(treedef, out), manifest["extra"]

    def restore_items(
        self, step: int | None = None
    ) -> tuple[dict[str, np.ndarray], dict]:
        """CRC-verified restore of a flat single-level dict checkpoint
        WITHOUT a ``like`` tree: returns ``({name: array}, extra)``.

        This is the reader for serving-state checkpoints
        (:mod:`repro.serve.recovery`), whose structure — how many
        flights, which prep leaves — is itself part of the checkpoint,
        so the caller cannot supply a structural template up front.
        Leaf names come from the manifest paths (``['name']`` for a flat
        dict); non-flat paths are returned under their full keystr."""
        if step is None:
            step = self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        cdir = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(cdir, _MANIFEST)) as f:
            manifest = json.load(f)
        items: dict[str, np.ndarray] = {}
        for e in manifest["leaves"]:
            arr = np.load(os.path.join(cdir, e["file"]))
            if zlib.crc32(arr.tobytes()) != e["crc32"]:
                raise IOError(f"crc mismatch for {e['path']} in {cdir}")
            m = _FLAT_KEY.fullmatch(e["path"])
            items[m.group(1) if m else e["path"]] = arr
        return items, manifest["extra"]

    def restore_latest_items(
        self,
    ) -> tuple[dict[str, np.ndarray], dict, int] | None:
        """Walk checkpoints newest-first until one verifies (same
        fallback contract as :meth:`restore_latest`, flat-dict reader)."""
        for step in reversed(self.available_steps()):
            try:
                items, extra = self.restore_items(step)
                return items, extra, step
            except (IOError, KeyError, ValueError, json.JSONDecodeError):
                continue
        return None

    def restore_latest(self, like: Any) -> tuple[Any, dict, int] | None:
        """Walk checkpoints newest-first until one verifies; None if none."""
        for step in reversed(self.available_steps()):
            try:
                state, extra = self.restore(like, step)
                return state, extra, step
            except (IOError, KeyError, json.JSONDecodeError):
                continue
        return None

    # -- gc ---------------------------------------------------------------
    def _gc(self):
        steps = self.available_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"))
        # stale temp dirs from crashed writers
        for name in os.listdir(self.directory):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)
