"""Observability subsystem: metrics, spans, throughput artifacts.

The measurement layer every perf PR is judged with:

* :mod:`repro.obs.metrics` — a typed metrics registry (counters,
  gauges, fixed-bucket histograms with quantile estimation), labeled by
  ``(p, refine, policy, devices)``, with snapshot/merge/diff semantics
  and Prometheus-text + JSON export.  ``ElasticityService.stats`` is a
  read-only view over one of these.
* :mod:`repro.obs.spans` — per-request lifecycle spans and per-chunk
  device-fenced timing, exportable as a JSON-lines event log and a
  Chrome ``trace_event`` file viewable in Perfetto.
* :mod:`repro.obs.throughput` — kernel-level operator apply throughput
  (DoF/s, effective GB/s against the streaming-bytes model) on the
  batched path; feeds ``benchmarks/operator_sweep.py`` and the
  ``BENCH_*.json`` perf trajectory.
* :mod:`repro.obs.schema` — a dependency-free JSON-schema validator for
  the ``BENCH_*.json`` artifact schemas checked into
  ``benchmarks/schemas/``.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_latency_edges,
    merge_snapshots,
    diff_snapshots,
)
from repro.obs.spans import Span, SpanRecorder
from repro.obs.schema import SchemaError, validate_json

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_latency_edges",
    "merge_snapshots",
    "diff_snapshots",
    "Span",
    "SpanRecorder",
    "SchemaError",
    "validate_json",
]
