"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

Design constraints, in order:

* **cheap on the hot path** — a counter increment is a float add on a
  cached cell object; no locks, no string formatting, no allocation
  after the first touch of a ``(name, labels)`` cell;
* **deterministic and testable** — the registry takes an injectable
  clock (only used to stamp exports), histograms have *fixed* bucket
  edges declared at creation, and every aggregate is derivable from a
  plain-data :meth:`MetricsRegistry.snapshot`;
* **windowable** — :func:`diff_snapshots` subtracts an earlier snapshot
  (counters and histogram buckets are monotone), which is how a
  benchmark reports "this workload's" latency distribution from a
  long-lived registry, and :func:`merge_snapshots` adds snapshots from
  independent registries (e.g. per-process shards);
* **exportable** — :meth:`to_prometheus_text` emits the Prometheus text
  exposition format; :meth:`to_json` a schema-versioned JSON document.

Labels are free-form ``str -> str`` pairs; the serving stack uses the
``(p, refine, policy, devices)`` vocabulary throughout (see
``docs/OBSERVABILITY.md`` for the metric catalog).
"""

from __future__ import annotations

import bisect
import json
import math
import re
import time
from typing import Iterable, Mapping

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_latency_edges",
    "merge_snapshots",
    "diff_snapshots",
]

SNAPSHOT_SCHEMA = "repro.obs.metrics/v1"

Labels = tuple[tuple[str, str], ...]


def _label_key(labels: Mapping[str, object]) -> Labels:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def default_latency_edges() -> tuple[float, ...]:
    """Log-spaced latency bucket upper bounds (seconds): 1 ms .. ~100 s,
    8 buckets per decade.  Wide enough for CPU-interpret solves and
    tight enough (~33%/bucket) for meaningful p50/p95 interpolation."""
    edges = []
    e = 1e-3
    while e < 120.0:
        edges.append(round(e, 12))
        e *= 10 ** (1 / 8)
    return tuple(edges)


class Counter:
    """Monotone counter.  ``inc`` rejects negative deltas so diffs of
    snapshots are always well-defined."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.value -= v


class Histogram:
    """Fixed-bucket histogram (Prometheus ``le`` convention: bucket i
    counts observations ``v <= edges[i]``; one implicit +inf bucket).

    Tracks observed min/max next to the buckets so
    :meth:`quantile` can clamp interpolation to the observed range —
    without it, a single sample in a wide bucket would report the
    bucket's midpoint instead of something near the sample."""

    __slots__ = ("edges", "counts", "sum", "count", "vmin", "vmax")
    kind = "histogram"

    def __init__(self, edges: Iterable[float]):
        edges = tuple(float(e) for e in edges)
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        for a, b in zip(edges, edges[1:]):
            if not a < b:
                raise ValueError(
                    f"histogram edges must be strictly increasing, got "
                    f"{a} before {b}"
                )
        if not all(math.isfinite(e) for e in edges):
            raise ValueError("histogram edges must be finite (+inf is implicit)")
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0 <= q <= 1) by linear interpolation
        inside the bucket holding it, clamped to the observed
        [min, max].  NaN on an empty histogram.  This is THE percentile
        implementation for the serving stack — the benchmark and the
        service summary both call it (no more ad-hoc np.percentile on
        raw lists)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return math.nan
        rank = q * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.edges[i - 1] if i > 0 else self.vmin
            hi = self.edges[i] if i < len(self.edges) else self.vmax
            lo = max(lo, self.vmin)
            hi = min(hi, self.vmax)
            if cum + c >= rank:
                frac = 0.0 if c == 0 else (rank - cum) / c
                return min(max(lo + frac * (hi - lo), self.vmin), self.vmax)
            cum += c
        return self.vmax

    def quantiles(self, qs: Iterable[float]) -> list[float]:
        return [self.quantile(q) for q in qs]


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One metric name: kind, help text, shared edges, labeled cells."""

    __slots__ = ("name", "kind", "help", "edges", "cells")

    def __init__(self, name, kind, help="", edges=None):
        self.name = name
        self.kind = kind
        self.help = help
        self.edges = edges
        self.cells: dict[Labels, object] = {}

    def cell(self, labels: Labels):
        c = self.cells.get(labels)
        if c is None:
            c = (
                Histogram(self.edges)
                if self.kind == "histogram"
                else _KINDS[self.kind]()
            )
            self.cells[labels] = c
        return c


_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


class MetricsRegistry:
    """Get-or-create registry of metric families.

    ``counter(name, **labels)`` / ``gauge`` / ``histogram`` return the
    live cell for that label set — hold on to it on hot paths.
    Re-registering a name with a different kind (or different histogram
    edges) is an error: one name, one meaning.

    ``clock`` stamps exports (``to_json``) — inject a fake for
    deterministic artifacts in tests."""

    def __init__(self, clock=time.time):
        self.clock = clock
        self._families: dict[str, _Family] = {}

    # -- registration --------------------------------------------------------
    def _family(self, name, kind, help, edges=None) -> _Family:
        if not _NAME_OK.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, kind, help, edges)
            self._families[name] = fam
            return fam
        if fam.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind}, "
                f"cannot re-register as {kind}"
            )
        if kind == "histogram" and edges is not None and tuple(
            float(e) for e in edges
        ) != tuple(fam.edges):
            raise ValueError(
                f"histogram {name!r} already registered with different "
                f"bucket edges"
            )
        if help and not fam.help:
            fam.help = help
        return fam

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._family(name, "counter", help).cell(_label_key(labels))

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._family(name, "gauge", help).cell(_label_key(labels))

    def histogram(
        self, name: str, help: str = "", *, edges=None, **labels
    ) -> Histogram:
        if edges is None and name not in self._families:
            edges = default_latency_edges()
        fam = self._family(name, "histogram", help, edges)
        return fam.cell(_label_key(labels))

    # -- reads ---------------------------------------------------------------
    def names(self) -> list[str]:
        return sorted(self._families)

    def value(self, name: str, **labels) -> float:
        """One cell's value (counter/gauge).  0.0 for a never-touched
        label set of a registered family; KeyError on an unknown name."""
        fam = self._families[name]
        if fam.kind == "histogram":
            raise TypeError(f"{name!r} is a histogram; use get_histogram")
        c = fam.cells.get(_label_key(labels))
        return 0.0 if c is None else c.value

    def total(self, name: str) -> float:
        """Sum of a counter/gauge family across every label set (0.0
        for an unknown name — callers aggregate optimistically)."""
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        if fam.kind == "histogram":
            raise TypeError(f"{name!r} is a histogram; use get_histogram")
        return sum(c.value for c in fam.cells.values())

    def get_histogram(self, name: str, **labels) -> Histogram | None:
        fam = self._families.get(name)
        if fam is None:
            return None
        if fam.kind != "histogram":
            raise TypeError(f"{name!r} is a {fam.kind}, not a histogram")
        return fam.cells.get(_label_key(labels))

    def merged_histogram(self, name: str) -> Histogram | None:
        """All of a histogram family's cells merged into one (same
        edges), e.g. latency across every (p, refine) label set."""
        fam = self._families.get(name)
        if fam is None or not fam.cells:
            return None
        if fam.kind != "histogram":
            raise TypeError(f"{name!r} is a {fam.kind}, not a histogram")
        out = Histogram(fam.edges)
        for h in fam.cells.values():
            out.counts = [a + b for a, b in zip(out.counts, h.counts)]
            out.sum += h.sum
            out.count += h.count
            out.vmin = min(out.vmin, h.vmin)
            out.vmax = max(out.vmax, h.vmax)
        return out

    # -- snapshot / merge / diff ---------------------------------------------
    def snapshot(self) -> dict:
        """Plain-data copy of every family and cell (JSON-able).  The
        canonical interchange form: ``merge_snapshots`` /
        ``diff_snapshots`` operate on these, and
        :meth:`from_snapshot` restores a live registry."""
        fams = {}
        for name in sorted(self._families):
            fam = self._families[name]
            cells = []
            for labels in sorted(fam.cells):
                c = fam.cells[labels]
                entry: dict = {"labels": dict(labels)}
                if fam.kind == "histogram":
                    entry.update(
                        counts=list(c.counts),
                        sum=c.sum,
                        count=c.count,
                        min=None if c.count == 0 else c.vmin,
                        max=None if c.count == 0 else c.vmax,
                    )
                else:
                    entry["value"] = c.value
                cells.append(entry)
            fams[name] = {"kind": fam.kind, "help": fam.help, "cells": cells}
            if fam.kind == "histogram":
                fams[name]["edges"] = list(fam.edges)
        return {"schema": SNAPSHOT_SCHEMA, "families": fams}

    @classmethod
    def from_snapshot(cls, snap: dict, clock=time.time) -> "MetricsRegistry":
        """Inverse of :meth:`snapshot` (exact round-trip)."""
        if snap.get("schema") != SNAPSHOT_SCHEMA:
            raise ValueError(
                f"unknown metrics snapshot schema {snap.get('schema')!r}"
            )
        reg = cls(clock=clock)
        for name, fam in snap["families"].items():
            f = reg._family(name, fam["kind"], fam.get("help", ""),
                            fam.get("edges"))
            for cell in fam["cells"]:
                labels = _label_key(cell["labels"])
                c = f.cell(labels)
                if fam["kind"] == "histogram":
                    c.counts = list(cell["counts"])
                    c.sum = float(cell["sum"])
                    c.count = int(cell["count"])
                    c.vmin = math.inf if cell["min"] is None else cell["min"]
                    c.vmax = -math.inf if cell["max"] is None else cell["max"]
                else:
                    c.value = float(cell["value"])
        return reg

    # -- exports -------------------------------------------------------------
    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format (counters get the ``_total``
        name as-is — the serving metrics already carry the suffix)."""
        lines = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for labels in sorted(fam.cells):
                c = fam.cells[labels]
                if fam.kind == "histogram":
                    cum = 0
                    for e, n in zip(fam.edges, c.counts):
                        cum += n
                        lines.append(
                            f"{name}_bucket"
                            f"{_prom_labels(labels, le=_prom_float(e))} {cum}"
                        )
                    lines.append(
                        f'{name}_bucket{_prom_labels(labels, le="+Inf")} '
                        f"{c.count}"
                    )
                    lines.append(
                        f"{name}_sum{_prom_labels(labels)} {_prom_float(c.sum)}"
                    )
                    lines.append(
                        f"{name}_count{_prom_labels(labels)} {c.count}"
                    )
                else:
                    lines.append(
                        f"{name}{_prom_labels(labels)} {_prom_float(c.value)}"
                    )
        return "\n".join(lines) + "\n"

    def to_json(self, indent: int | None = None) -> str:
        doc = self.snapshot()
        doc["generated_unix"] = float(self.clock())
        return json.dumps(doc, indent=indent, sort_keys=True)


def _prom_float(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _prom_labels(labels: Labels, **extra) -> str:
    items = list(labels) + sorted(extra.items())
    if not items:
        return ""
    body = ",".join(
        '{}="{}"'.format(
            k, str(v).replace("\\", "\\\\").replace('"', '\\"')
        )
        for k, v in items
    )
    return "{" + body + "}"


# -- snapshot algebra --------------------------------------------------------
def _check_schema(snap: dict) -> dict:
    if snap.get("schema") != SNAPSHOT_SCHEMA:
        raise ValueError(
            f"unknown metrics snapshot schema {snap.get('schema')!r}"
        )
    return snap["families"]


def _cells_by_labels(fam: dict) -> dict:
    return {_label_key(c["labels"]): c for c in fam["cells"]}


def _combine(a: dict, b: dict, counter_op, hist_op, gauge_pick) -> dict:
    """Shared walk for merge/diff: families by name, cells by labels."""
    fa, fb = _check_schema(a), _check_schema(b)
    out_fams: dict = {}
    for name in sorted(set(fa) | set(fb)):
        pa, pb = fa.get(name), fb.get(name)
        proto = pa or pb
        if pa and pb:
            if pa["kind"] != pb["kind"]:
                raise ValueError(
                    f"metric {name!r}: kind mismatch "
                    f"({pa['kind']} vs {pb['kind']})"
                )
            if pa["kind"] == "histogram" and pa["edges"] != pb["edges"]:
                raise ValueError(f"histogram {name!r}: edge mismatch")
        ca = _cells_by_labels(pa) if pa else {}
        cb = _cells_by_labels(pb) if pb else {}
        cells = []
        for labels in sorted(set(ca) | set(cb)):
            xa, xb = ca.get(labels), cb.get(labels)
            if proto["kind"] == "histogram":
                cells.append(hist_op(labels, xa, xb, len(proto["edges"])))
            elif proto["kind"] == "counter":
                va = xa["value"] if xa else 0.0
                vb = xb["value"] if xb else 0.0
                cells.append(
                    {"labels": dict(labels), "value": counter_op(va, vb)}
                )
            else:
                cells.append(
                    {"labels": dict(labels), "value": gauge_pick(xa, xb)}
                )
        out_fams[name] = {
            "kind": proto["kind"],
            "help": proto.get("help", ""),
            "cells": cells,
        }
        if proto["kind"] == "histogram":
            out_fams[name]["edges"] = list(proto["edges"])
    return {"schema": SNAPSHOT_SCHEMA, "families": out_fams}


def _zero_hist_cell(labels: Labels, nedges: int) -> dict:
    return {
        "labels": dict(labels),
        "counts": [0] * (nedges + 1),
        "sum": 0.0,
        "count": 0,
        "min": None,
        "max": None,
    }


def merge_snapshots(a: dict, b: dict) -> dict:
    """Element-wise sum of two snapshots (counters and histogram buckets
    add; gauges take ``b``'s value when both have the cell).  Use to
    aggregate registries from independent shards/processes."""

    def hist(labels, xa, xb, nedges):
        xa = xa or _zero_hist_cell(labels, nedges)
        xb = xb or _zero_hist_cell(labels, nedges)
        mins = [m for m in (xa["min"], xb["min"]) if m is not None]
        maxs = [m for m in (xa["max"], xb["max"]) if m is not None]
        return {
            "labels": dict(labels),
            "counts": [p + q for p, q in zip(xa["counts"], xb["counts"])],
            "sum": xa["sum"] + xb["sum"],
            "count": xa["count"] + xb["count"],
            "min": min(mins) if mins else None,
            "max": max(maxs) if maxs else None,
        }

    return _combine(
        a,
        b,
        counter_op=lambda va, vb: va + vb,
        hist_op=hist,
        gauge_pick=lambda xa, xb: (xb or xa)["value"],
    )


def diff_snapshots(new: dict, old: dict) -> dict:
    """``new - old``: the window between two snapshots of the SAME
    registry.  Counters and histogram buckets subtract (negative
    results raise — counters are monotone, so going backwards means the
    snapshots are from different registries); gauges take ``new``."""

    def counter(vn, vo):
        d = vn - vo
        if d < -1e-9:
            raise ValueError(
                "diff_snapshots: counter went backwards (snapshots are "
                "not from the same registry?)"
            )
        return max(d, 0.0)

    def hist(labels, xn, xo, nedges):
        xn = xn or _zero_hist_cell(labels, nedges)
        xo = xo or _zero_hist_cell(labels, nedges)
        counts = [p - q for p, q in zip(xn["counts"], xo["counts"])]
        if any(c < 0 for c in counts):
            raise ValueError(
                "diff_snapshots: histogram bucket went backwards"
            )
        # Window min/max are unknowable from cumulative data; the new
        # snapshot's observed range is the tightest safe bound.
        return {
            "labels": dict(labels),
            "counts": counts,
            "sum": xn["sum"] - xo["sum"],
            "count": xn["count"] - xo["count"],
            "min": xn["min"],
            "max": xn["max"],
        }

    return _combine(
        new,
        old,
        counter_op=counter,
        hist_op=hist,
        gauge_pick=lambda xn, xo: (xn or xo)["value"],
    )
