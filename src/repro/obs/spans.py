"""Span recording: request lifecycles and device-fenced chunk timing.

A :class:`SpanRecorder` collects closed intervals (``Span``\\ s) from the
serving stack and exports them two ways:

* a **JSON-lines event log** (:meth:`SpanRecorder.to_jsonl`) — one span
  per line, trivially greppable / streamable;
* a **Chrome ``trace_event`` file** (:meth:`SpanRecorder.to_chrome_trace`)
  — open it at https://ui.perfetto.dev to see the engine's timeline:
  one track per in-flight discretization key (prep + chunk spans, the
  chunk split into host ``dispatch`` and device-fenced ``device``
  phases) and one track per batch slot (``queue_wait`` then ``solve``
  per request riding that slot).

The service's span taxonomy and the meaning of every ``args`` field are
cataloged in ``docs/OBSERVABILITY.md``.

Device fencing: jax dispatch is asynchronous, so wall-clock around a
``run_chunk`` call measures *host dispatch*, not compute.  When a
recorder is installed the service fences each chunk with
``jax.block_until_ready`` on the returned state — splitting dispatch
from device compute — WITHOUT fetching the deferred per-row consumed
vector (fencing waits for completion; it does not transfer), so the
PR-5 contract that the consumed fetch rides the next retire pass is
preserved.  With no recorder installed there is no fence and no
per-chunk sync at all (see the instrumentation-overhead guard in
``tests/test_obs.py``).

``clock`` is injectable (default ``time.perf_counter``); the injected-
clock tests drive it deterministically and assert the lifecycle
identity *queue_wait + compute + overhead == wall* per ticket exactly.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

__all__ = ["Span", "SpanRecorder"]

EVENTS_SCHEMA = "repro.obs.spans/v1"


@dataclasses.dataclass
class Span:
    """One closed interval.  ``tid`` selects the Chrome-trace track
    (the recorder's ``thread_name`` map names it); ``args`` is plain
    JSON-able metadata."""

    name: str
    cat: str
    tid: int
    start: float
    end: float
    args: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class SpanRecorder:
    """Collects spans; tracks open begin/end pairs so a leak is
    detectable (``open_count`` must be 0 when the engine is idle).

    ``fence=True`` (default) asks the service to device-fence each
    chunk so dispatch and compute separate; ``fence=False`` records
    host-side dispatch times only (no extra synchronization)."""

    def __init__(self, clock=time.perf_counter, fence: bool = True):
        self.clock = clock
        self.fence = fence
        self.spans: list[Span] = []
        self._open: dict[int, Span] = {}
        self._next_id = 0
        self._thread_names: dict[int, str] = {}

    # -- recording -----------------------------------------------------------
    def begin(self, name: str, *, cat: str = "", tid: int = 0, **args) -> int:
        """Open a span now; returns the id to :meth:`end` it with."""
        sid = self._next_id
        self._next_id += 1
        self._open[sid] = Span(
            name=name, cat=cat, tid=tid, start=self.clock(), end=-1.0,
            args=dict(args),
        )
        return sid

    def end(self, sid: int, **args) -> Span:
        span = self._open.pop(sid)
        span.end = self.clock()
        span.args.update(args)
        self.spans.append(span)
        return span

    def emit(
        self, name: str, *, cat: str = "", tid: int = 0,
        start: float, end: float, **args,
    ) -> Span:
        """Record an already-measured interval (no open/close pair)."""
        span = Span(
            name=name, cat=cat, tid=tid, start=start, end=end,
            args=dict(args),
        )
        self.spans.append(span)
        return span

    def thread_name(self, tid: int, name: str) -> None:
        """Name a Chrome-trace track (idempotent)."""
        self._thread_names[tid] = name

    # -- inspection ----------------------------------------------------------
    @property
    def open_count(self) -> int:
        return len(self._open)

    def open_spans(self) -> list[Span]:
        return list(self._open.values())

    def count(self, name: str | None = None) -> int:
        """Closed spans, optionally by name — what the reconciliation
        tests compare against ``SchedulerTrace`` decision counts and
        the registry counters."""
        if name is None:
            return len(self.spans)
        return sum(1 for s in self.spans if s.name == name)

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def clear(self) -> None:
        if self._open:
            raise RuntimeError(
                f"clear() with {len(self._open)} spans still open"
            )
        self.spans.clear()

    # -- export --------------------------------------------------------------
    def _t0(self) -> float:
        return min((s.start for s in self.spans), default=0.0)

    def to_events(self) -> list[dict]:
        """Chrome ``trace_event`` dicts (``ph: "X"`` complete events,
        microsecond timestamps rebased to the earliest span, plus
        ``thread_name`` metadata events)."""
        t0 = self._t0()
        events: list[dict] = [
            {
                "ph": "M",
                "name": "thread_name",
                "pid": 0,
                "tid": tid,
                "args": {"name": name},
            }
            for tid, name in sorted(self._thread_names.items())
        ]
        for s in self.spans:
            events.append(
                {
                    "ph": "X",
                    "name": s.name,
                    "cat": s.cat or "obs",
                    "pid": 0,
                    "tid": s.tid,
                    "ts": (s.start - t0) * 1e6,
                    "dur": s.duration * 1e6,
                    "args": s.args,
                }
            )
        return events

    def to_chrome_trace(self, path: str) -> None:
        """Write a Perfetto-loadable ``{"traceEvents": [...]}`` file."""
        doc = {
            "traceEvents": self.to_events(),
            "displayTimeUnit": "ms",
            "otherData": {"schema": EVENTS_SCHEMA},
        }
        with open(path, "w") as f:
            json.dump(doc, f)

    def to_jsonl(self, path: str) -> None:
        """One span per line: ``{"name", "cat", "tid", "start", "end",
        "dur", "args"}`` with raw clock timestamps (not rebased)."""
        with open(path, "w") as f:
            for s in self.spans:
                f.write(
                    json.dumps(
                        {
                            "name": s.name,
                            "cat": s.cat,
                            "tid": s.tid,
                            "start": s.start,
                            "end": s.end,
                            "dur": s.duration,
                            "args": s.args,
                        },
                        sort_keys=True,
                    )
                    + "\n"
                )
