"""Dependency-free JSON-schema validation for BENCH_*.json artifacts.

The perf-trajectory artifacts (``BENCH_operator_sweep.json``,
``BENCH_serving.json``) are schema-versioned: their schemas are checked
into ``benchmarks/schemas/`` and the ``bench-smoke`` CI lane fails on
drift.  This validator implements the subset of JSON Schema those
schemas use — ``type``, ``properties``, ``required``, ``items``,
``enum``, ``const``, ``minimum``, ``exclusiveMinimum``, ``minItems``,
``additionalProperties`` — so validation needs no third-party package
(the container may not ship ``jsonschema``; nothing may be installed).

Errors carry JSON-pointer-ish paths (``rows[3].dofs_per_s``) so a
schema-drift failure names the exact offending field.
"""

from __future__ import annotations

import json
import math
from typing import Any

__all__ = ["SchemaError", "validate_json", "validation_errors", "load_and_validate"]

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


class SchemaError(ValueError):
    """Raised by :func:`validate_json`; ``errors`` lists every finding."""

    def __init__(self, errors: list[str]):
        self.errors = errors
        super().__init__(
            f"{len(errors)} schema violation(s):\n  " + "\n  ".join(errors)
        )


def _type_ok(value: Any, t: str) -> bool:
    if t == "number":
        return isinstance(value, (int, float)) and not isinstance(value, bool)
    if t == "integer":
        return (
            isinstance(value, int) and not isinstance(value, bool)
        ) or (isinstance(value, float) and float(value).is_integer())
    cls = _TYPES.get(t)
    if cls is None:
        raise ValueError(f"unsupported schema type {t!r}")
    ok = isinstance(value, cls)
    # bool is an int subclass in Python; don't let it pass as plain int.
    if ok and cls is not bool and isinstance(value, bool) and t != "boolean":
        return False
    return ok


def _walk(value: Any, schema: dict, path: str, errors: list[str]) -> None:
    t = schema.get("type")
    if t is not None:
        types = t if isinstance(t, list) else [t]
        if not any(_type_ok(value, x) for x in types):
            errors.append(
                f"{path or '$'}: expected type {'/'.join(types)}, got "
                f"{type(value).__name__} ({value!r:.80})"
            )
            return
    if "const" in schema and value != schema["const"]:
        errors.append(
            f"{path or '$'}: expected const {schema['const']!r}, got {value!r}"
        )
    if "enum" in schema and value not in schema["enum"]:
        errors.append(
            f"{path or '$'}: {value!r} not in enum {schema['enum']!r}"
        )
    if isinstance(value, (int, float)) and not isinstance(value, bool):
        if "minimum" in schema and value < schema["minimum"]:
            errors.append(
                f"{path or '$'}: {value!r} < minimum {schema['minimum']!r}"
            )
        if "exclusiveMinimum" in schema and value <= schema["exclusiveMinimum"]:
            errors.append(
                f"{path or '$'}: {value!r} <= exclusiveMinimum "
                f"{schema['exclusiveMinimum']!r}"
            )
        if (
            isinstance(value, float)
            and math.isnan(value)
            and not schema.get("allowNaN", False)
        ):
            errors.append(f"{path or '$'}: NaN is not a valid value")
    if isinstance(value, dict):
        props = schema.get("properties", {})
        for name in schema.get("required", []):
            if name not in value:
                errors.append(f"{path or '$'}: missing required key {name!r}")
        for name, sub in props.items():
            if name in value:
                _walk(value[name], sub, f"{path}.{name}" if path else name,
                      errors)
        ap = schema.get("additionalProperties", True)
        if ap is False:
            for name in value:
                if name not in props:
                    errors.append(
                        f"{path or '$'}: unexpected key {name!r} "
                        f"(additionalProperties: false)"
                    )
        elif isinstance(ap, dict):
            for name, v in value.items():
                if name not in props:
                    _walk(v, ap, f"{path}.{name}" if path else name, errors)
    if isinstance(value, list):
        if "minItems" in schema and len(value) < schema["minItems"]:
            errors.append(
                f"{path or '$'}: {len(value)} item(s) < minItems "
                f"{schema['minItems']}"
            )
        items = schema.get("items")
        if isinstance(items, dict):
            for i, v in enumerate(value):
                _walk(v, items, f"{path}[{i}]", errors)


def validation_errors(instance: Any, schema: dict) -> list[str]:
    """Every violation of ``schema`` by ``instance`` (empty = valid)."""
    errors: list[str] = []
    _walk(instance, schema, "", errors)
    return errors


def validate_json(instance: Any, schema: dict) -> None:
    """Raise :class:`SchemaError` listing every violation; no-op when
    ``instance`` conforms."""
    errors = validation_errors(instance, schema)
    if errors:
        raise SchemaError(errors)


def load_and_validate(artifact_path: str, schema_path: str) -> dict:
    """Read a JSON artifact, validate it, and return the parsed doc."""
    with open(artifact_path) as f:
        doc = json.load(f)
    with open(schema_path) as f:
        schema = json.load(f)
    validate_json(doc, schema)
    return doc
