"""Kernel-level operator-apply throughput on the batched path.

Measures what the paper's Fig. 5 measures — operator applications per
second, expressed as DoF/s — but on the *batched* operator the serving
stack actually runs: S scenarios' material fields folded into the
element axis of one :class:`~repro.core.operators.ElasticityOperator`,
exactly as ``BatchedGMGSolver`` binds them inside a solve.  Next to the
wall measurement it evaluates the paper's analytic models so every row
carries its own roofline placement:

* ``flops_per_apply`` — :func:`repro.core.flops.paop_flops_per_elem`
  (or the dense-baseline count) x elements;
* ``bytes_per_apply`` — the PAop streaming-bytes model (read ``x_e``,
  ``lam_w``, ``mu_w``; write ``y_e``; B/G tables and intermediates
  on-chip, paper Sec. 4.5) — the same model ``fig6_roofline`` uses;
* ``oi_model`` = flops / bytes, the analytic operational intensity the
  measured point is placed against.

Timing is device-fenced: every timed call ends in
``jax.block_until_ready``, so asynchronous dispatch cannot leak compute
into a later measurement.  Feeds ``benchmarks/operator_sweep.py``, which
wraps rows into the schema-versioned ``BENCH_operator_sweep.json``
artifact (the perf trajectory's first points).
"""

from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.flops import (
    default_q1d,
    dense_flops_per_elem,
    paop_flops_per_elem,
)
from repro.core.precision import resolve_precision

__all__ = [
    "streaming_bytes_per_elem",
    "model_flops_per_elem",
    "operator_throughput",
]


def streaming_bytes_per_elem(p: int, itemsize: int, q1d: int | None = None) -> int:
    """PAop streaming-bytes model per element per apply: the 3-channel
    ``x_e`` read + ``y_e`` write (D^3 nodes) and the two weighted
    material fields (Q^3 points).  Basis tables and all intermediates
    are on-chip by construction (paper Sec. 4.5).  ``q1d`` defaults to
    :func:`repro.core.flops.default_q1d`; pass the real quadrature
    count (``lam_w.shape[-1]``) when you have an operator in hand."""
    D = p + 1
    Q = default_q1d(p) if q1d is None else q1d
    return itemsize * (2 * 3 * D**3 + 2 * Q**3)


def model_flops_per_elem(p: int, assembly: str, q1d: int | None = None) -> float:
    """Analytic per-element FLOPs of one operator apply for the
    assembly family being measured (sum-factorized vs dense baseline)."""
    if assembly == "pa_baseline":
        return dense_flops_per_elem(p, q1d)
    return paop_flops_per_elem(p, q1d)


def _fenced_median_time(fn, x, *, warmup: int, repeats: int,
                        min_time_s: float, clock=time.perf_counter) -> float:
    """Median wall seconds per call, each sample fenced with
    ``block_until_ready`` (dispatch + device compute, never dispatch
    alone)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(x))
    times = []
    for _ in range(max(repeats, 1)):
        n = 0
        t0 = clock()
        while True:
            jax.block_until_ready(fn(x))
            n += 1
            dt = clock() - t0
            if dt >= min_time_s:
                break
        times.append(dt / n)
    times.sort()
    return times[len(times) // 2]


def _scenario_materials(n: int) -> list[dict]:
    """The beam benchmark's mixed material vocabulary (same family the
    serving benchmarks use), one dict per scenario row."""
    return [
        {1: (50.0 + 5.0 * (i % 3), 50.0), 2: (1.0 + 0.5 * (i % 2), 1.0)}
        for i in range(n)
    ]


def operator_throughput(
    p: int,
    refine: int,
    batch: int,
    *,
    assembly: str = "paop",
    dtype=None,
    precision: str | None = None,
    repeats: int = 3,
    min_time_s: float = 0.05,
    pallas_interpret: bool | None = None,
    pallas_lane: str | None = None,
    coarse_mesh=None,
    clock=time.perf_counter,
) -> dict[str, Any]:
    """Measure batched operator-apply throughput for one (p, refine,
    batch) cell; returns one artifact row (plain JSON-able dict).

    The operator is built exactly like a solve level: S scenario
    material dicts folded to per-element fields on the fine mesh of
    ``coarse_mesh`` (beam default) refined ``refine`` times, applied to
    a random (S, nscalar, 3) L-vector under jit.

    The row records the Pallas lane that *actually ran*
    (``pallas_lane``: the operator's resolved lane for ``paop_pallas``,
    ``"none"`` for assemblies that never enter Pallas) next to the lane
    that was *requested* (``lane_requested``) — so a sweep that asks for
    ``compiled`` on a backend that cannot lower Pallas is recorded as
    the interpret run it really was.

    ``precision`` names a :class:`~repro.core.precision.PrecisionPolicy`;
    the operator is measured at the policy's ``precond_dtype`` — the
    dtype the V-cycle element kernel streams under that policy, which is
    where the bandwidth-bound bytes live — and the row records
    ``precision_policy`` so the artifact carries the axis.  The legacy
    ``dtype`` argument still works for uniform-dtype measurements."""
    from repro.core.operators import ElasticityOperator
    from repro.fem.mesh import beam_hex
    from repro.fem.space import H1Space

    policy = resolve_precision(precision, dtype)
    dtype = policy.precond_dtype
    lane_requested = (
        pallas_lane
        if pallas_lane is not None
        else ("interpret" if pallas_interpret else "auto")
    )
    mesh = (coarse_mesh if coarse_mesh is not None else beam_hex()).refined(
        refine
    )
    space = H1Space(mesh, p)
    op = ElasticityOperator(
        space,
        assembly=assembly,
        materials=_scenario_materials(batch),
        dtype=dtype,
        pallas_interpret=pallas_interpret,
        pallas_lane=pallas_lane,
    )
    lane_ran = op.pallas_lane if assembly == "paop_pallas" else "none"
    x = jax.random.normal(
        jax.random.PRNGKey(p * 1000 + refine * 10 + batch),
        (batch, space.nscalar, 3),
        dtype,
    )
    t = _fenced_median_time(
        jax.jit(op.apply), x,
        warmup=1, repeats=repeats, min_time_s=min_time_s, clock=clock,
    )

    itemsize = jnp.dtype(dtype).itemsize
    nelem = space.nelem * batch  # folded scenario-element axis
    dofs = space.ndof * batch
    # Real quadrature count off the bound material field — the same
    # number the kernel's VMEM budgeting sees — so the bytes/OI model
    # cannot drift from what the kernel actually streams.
    q1d = int(op.lam_w.shape[-1]) if op.lam_w is not None else None
    bytes_per_apply = streaming_bytes_per_elem(p, itemsize, q1d) * nelem
    flops_per_apply = model_flops_per_elem(p, assembly, q1d) * nelem
    return {
        "p": int(p),
        "refine": int(refine),
        "batch": int(batch),
        "assembly": assembly,
        "pallas_lane": lane_ran,
        "lane_requested": lane_requested,
        "pallas_interpret": bool(lane_ran == "interpret"),
        "dtype": str(jnp.dtype(dtype)),
        "precision_policy": policy.name,
        "ndof": int(space.ndof),
        "nelem": int(space.nelem),
        "dofs": int(dofs),
        "t_apply_s": float(t),
        "dofs_per_s": float(dofs / t),
        "gdofs_per_s": float(dofs / t / 1e9),
        "bytes_per_apply": int(bytes_per_apply),
        "gbytes_per_s": float(bytes_per_apply / t / 1e9),
        "flops_per_apply": float(flops_per_apply),
        "gflops_per_s": float(flops_per_apply / t / 1e9),
        "oi_model": float(flops_per_apply / bytes_per_apply),
    }
