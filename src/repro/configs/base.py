"""Architecture and shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; per-arch modules
in this package export ``CONFIG`` (the exact published configuration) and
``reduced()`` (a small same-family config for CPU smoke tests).  The
paper's own workload is the ``elasticity`` config (see elasticity.py),
which flows through the same registry, launcher, dry-run and roofline
machinery as the LM architectures.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config", "get_reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | ssm | hybrid | vlm | moe | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA width (mixtral)
    head_dim: Optional[int] = None
    rope_theta: float = 1e6
    pos_embed: str = "rope"  # rope | mrope | sinusoidal
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl (t, h, w) half-dim split

    # mlp
    mlp_type: str = "swiglu"  # swiglu | gelu

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # ssm / hybrid
    block_pattern: str = "attn"  # attn | xlstm | mamba2 | zamba2
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    slstm_indices: tuple[int, ...] = ()  # xlstm: which blocks are sLSTM
    shared_attn_every: int = 0  # zamba2: shared attn block cadence
    chunk_size: int = 256  # SSD / mLSTM chunk length

    # modality
    n_codebooks: int = 0  # musicgen EnCodec codebooks
    n_vision_tokens: int = 0  # qwen2-vl stub frontend

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k decode is runnable (SSM/hybrid/SWA)."""
        return self.block_pattern in ("xlstm", "mamba2", "zamba2") or (
            self.sliding_window is not None
        )

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.n_codebooks:
            emb = self.n_codebooks * v * d * 2
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.mlp_type == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.is_moe:
            mlp = self.n_experts * (3 * d * f)
        if self.block_pattern == "attn":
            per_layer = attn + mlp
        elif self.block_pattern in ("mamba2", "zamba2"):
            d_in = self.ssm_expand * d
            per_layer = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
        elif self.block_pattern == "xlstm":
            d_in = self.ssm_expand * d
            per_layer = 2 * d * d_in + d_in * d + 3 * d_in
        else:
            per_layer = attn + mlp
        total = emb + self.n_layers * per_layer
        if self.block_pattern == "zamba2" and self.shared_attn_every:
            total += attn + 3 * d * self.d_ff  # one shared block
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_mlp = self.n_layers * 3 * d * f
        return (
            self.n_params()
            - self.n_layers * self.n_experts * 3 * d * f
            + self.top_k * dense_mlp
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = (
    "qwen15_32b",
    "qwen3_32b",
    "qwen3_17b",
    "granite_8b",
    "xlstm_125m",
    "zamba2_27b",
    "qwen2_vl_7b",
    "olmoe_1b_7b",
    "mixtral_8x7b",
    "musicgen_medium",
    "elasticity",
)

# CLI aliases matching the assignment sheet ids.
ALIASES = {
    "qwen1.5-32b": "qwen15_32b",
    "qwen3-32b": "qwen3_32b",
    "qwen3-1.7b": "qwen3_17b",
    "granite-8b": "granite_8b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-2.7b": "zamba2_27b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "musicgen-medium": "musicgen_medium",
}


def _module(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_reduced(arch: str):
    return _module(arch).reduced()
