"""Qwen3-32B [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA, head_dim=128.  [hf:Qwen/Qwen3-8B family; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1e6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-32b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=16,
    )
