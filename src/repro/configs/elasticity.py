"""The paper's own workload as a first-class config: matrix-free
high-order linear elasticity on the two-material beam, solved with
GMG-PCG and the PAop operator.

Shapes mirror the paper's problem scales (Sec. 5): the 6.5M-DoF and
51.17M-DoF studies.  At p=8 the coarse 8x1x1 beam refined r times gives
(8*2^r*8+1)(2^r*8+1)^2 * 3 vector DoFs: r=3 -> 6.5M, r=4 -> 51.17M —
exactly the paper's sizes.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ElasticityConfig:
    name: str = "elasticity"
    family: str = "fem"
    p: int = 8
    n_h_refine: int = 3
    assembly: str = "paop"
    dtype: str = "float32"


CONFIG = ElasticityConfig()


@dataclasses.dataclass(frozen=True)
class ElasticityShape:
    name: str
    kind: str  # operator | solve
    p: int
    n_h_refine: int


# The paper's two problem scales (Fig. 6) plus the p=2 low-order point.
ELASTICITY_SHAPES = {
    "beam_p2_6m": ElasticityShape("beam_p2_6m", "operator", p=2, n_h_refine=5),
    "beam_p8_6m": ElasticityShape("beam_p8_6m", "operator", p=8, n_h_refine=3),
    "beam_p8_51m": ElasticityShape("beam_p8_51m", "operator", p=8, n_h_refine=4),
}


def reduced() -> ElasticityConfig:
    return ElasticityConfig(name="elasticity-reduced", p=2, n_h_refine=1)
