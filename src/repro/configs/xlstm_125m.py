"""xLSTM-125M [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks (the xLSTM[7:1]-style mix; block indices 5 and 11 carry the
sLSTM).  Sub-quadratic: runs the long_500k cell.  [arXiv:2405.04517]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern="xlstm",
    slstm_indices=(5, 11),
    ssm_expand=2,
    ssm_head_dim=192,  # d_inner / n_heads = 1536 / 8? heads act per-block
    chunk_size=256,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="xlstm-125m-reduced",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        vocab=256,
        slstm_indices=(1,),
        ssm_head_dim=16,
        chunk_size=16,
    )
