"""MusicGen-medium [audio]: 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens (4 codebooks with the delay
pattern; the EnCodec frontend is a STUB — input_specs provides the
(B, S, 4) code tokens directly, embeddings are summed over codebooks and
4 parallel LM heads predict the next codes).  GELU MLP, sinusoidal
positions.  [arXiv:2306.05284; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    mlp_type="gelu",
    pos_embed="sinusoidal",
    n_codebooks=4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="musicgen-medium-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=64,
        n_codebooks=4,
    )
