from repro.configs.base import (
    ALIASES,
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    ShapeConfig,
    get_config,
    get_reduced,
)

__all__ = [
    "ALIASES",
    "ARCH_IDS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "get_config",
    "get_reduced",
]
