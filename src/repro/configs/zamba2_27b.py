"""Zamba2-2.7B [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone with a shared attention+MLP
block applied every 6 layers (weight-shared across all applications, the
Zamba trick).  Sub-quadratic backbone: runs the long_500k cell.
[arXiv:2411.15242; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    block_pattern="zamba2",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    chunk_size=256,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="zamba2-2.7b-reduced",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        ssm_state=16,
        ssm_head_dim=16,
        shared_attn_every=2,
        chunk_size=16,
    )
