"""Qwen2-VL-7B [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (t/h/w sections 16/24/24 on the 64 half-dim pairs),
dynamic-resolution vision.  The vision frontend is a STUB: input_specs
provides precomputed patch embeddings; the transformer backbone is what
this config exercises.  [arXiv:2409.12191; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    pos_embed="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    n_vision_tokens=256,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen2-vl-7b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        mrope_sections=(2, 3, 3),
        n_vision_tokens=8,
    )
