"""OLMoE-1B-7B [moe]: 16L d_model=2048 16H (MHA kv=16) d_ff=1024
vocab=50304, 64 experts top-8.  [arXiv:2409.02060; hf]"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="olmoe-1b-7b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=256,
        n_experts=8,
        top_k=2,
    )
