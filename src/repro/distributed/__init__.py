from repro.distributed.sharding import (  # noqa: F401
    batch_pspec,
    param_pspecs,
    state_pspecs,
    decode_state_pspecs,
)
