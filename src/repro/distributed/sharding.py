"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

FSDP + Megatron-style tensor parallelism:

* ``model`` axis — TP/EP: attention qkv shard the head (output) dim, the
  output projection shards its input dim (one all-reduce per block); MLP
  up/gate shard d_ff out, down shards d_ff in; MoE expert tensors shard
  the expert dim (expert parallelism); embeddings/LM head shard vocab.
* ``data`` axis — FSDP/ZeRO-3: the *other* matrix dim of every large
  tensor is sharded over ``data``, so parameters, gradients and both
  Adam moments are fully sharded over the whole pod (a 32B-param config
  is 64 GB of bf16 weights + 256 GB of f32 moments — per-device this
  must divide by all 256 chips, not just the 16-wide model axis).
  GSPMD turns this into the usual FSDP schedule: per-layer all-gather of
  weights in the forward/backward, reduce-scatter of gradients.
* ``pod`` axis — pure DP: only the gradient all-reduce crosses pods.

Optimizer moments mirror parameter specs (they are pytrees of the same
structure, so ``param_pspecs`` applies directly).  Rules are name-based
over the pytree path; any block following the naming convention inherits
distribution for free.

Sequence parallelism: ``act_pspec`` returns the between-blocks activation
constraint P(dp, 'model', None) — with scan-over-layers + remat the
per-layer saved residual is (B, S, d) and at 4k x 64 layers it must not
be replicated over the model axis (43 GB -> 2.7 GB per device at 32B
scale).  The forward pass applies it via with_sharding_constraint.

Scenario data parallelism (the solver side): the batched elasticity
solve (:mod:`repro.solvers.batched`) carries a leading scenario axis S
with *no cross-scenario coupling* — per-row inner products, per-row
smoother coefficients, per-row coarse factors.  ``scenario_mesh`` /
``scenario_spec`` / ``pin_scenario`` / ``device_put_scenario`` give that
axis a 1-D ``jax.sharding`` mesh: every (S, ...) state/prep array and
every folded (S*E, ...) element array is sharded on axis 0, the fused PA
kernels run unchanged per shard, and the only cross-device traffic is
the (S,)-vector reductions of bpcg's convergence logic.
"""

from __future__ import annotations

import os
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map with a fallback for older jax, where it lives in
    jax.experimental.shard_map and the replication-check kwarg is named
    check_rep instead of check_vma."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )

__all__ = [
    "param_pspecs",
    "state_pspecs",
    "batch_pspec",
    "decode_state_pspecs",
    "act_pspec",
    "SCENARIO_AXIS",
    "scenario_mesh",
    "normalize_scenario_mesh",
    "scenario_spec",
    "scenario_sharding",
    "pin_scenario",
    "device_put_scenario",
    "scenario_row_devices",
    "scenario_layout_mismatches",
    "force_host_device_count",
]

# -- scenario-axis data parallelism (batched elasticity solves) -------------

SCENARIO_AXIS = "scenario"


def force_host_device_count(n: int | None) -> None:
    """Ask XLA for ``n`` virtual host (CPU) devices.

    Must run before the first jax backend touch (any ``jax.devices()`` /
    array op); appends ``--xla_force_host_platform_device_count`` to
    XLA_FLAGS unless one is already present, so an operator-set flag
    always wins.  Centralized here so the CLIs (``--devices N``) and the
    test suite (``REPRO_HOST_DEVICES``) cannot diverge in how they spell
    the flag."""
    if not n or n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={int(n)}".strip()
    )


def scenario_mesh(n_devices: int | None = None, devices=None) -> Mesh:
    """A 1-D device mesh over :data:`SCENARIO_AXIS`.

    ``n_devices`` takes the first n of ``jax.devices()`` (all of them
    when None), so one process forced to 8 host devices can build 1-, 2-,
    4- and 8-wide meshes for differential testing."""
    if devices is None:
        devices = jax.devices()
        if n_devices is not None:
            if n_devices < 1:
                raise ValueError(
                    f"scenario_mesh needs n_devices >= 1, got {n_devices}"
                )
            if n_devices > len(devices):
                raise ValueError(
                    f"scenario_mesh({n_devices}) but only "
                    f"{len(devices)} devices are available"
                )
            devices = devices[:n_devices]
    if len(devices) < 1:
        raise ValueError("scenario_mesh needs at least one device")
    return Mesh(np.asarray(devices), (SCENARIO_AXIS,))


def normalize_scenario_mesh(mesh) -> tuple[Mesh | None, int]:
    """(mesh, n_shards) from the ``mesh`` option every scenario-sharded
    constructor accepts: None (single-device), an int ("first n
    devices"), or a prebuilt 1-D Mesh.  Shared so `BatchedGMGSolver` and
    `ElasticityService` can never normalize inconsistently."""
    if isinstance(mesh, int):
        mesh = scenario_mesh(mesh)
    return mesh, (1 if mesh is None else int(mesh.devices.size))


def scenario_spec(ndim: int = 1) -> P:
    """PartitionSpec sharding axis 0 (the scenario axis — or the folded
    scenario*element axis) of an ndim-dimensional array."""
    return P(SCENARIO_AXIS, *(None,) * (max(ndim, 1) - 1))


def scenario_sharding(mesh: Mesh, ndim: int = 1) -> NamedSharding:
    return NamedSharding(mesh, scenario_spec(ndim))


def pin_scenario(tree: Any, mesh: Mesh | None) -> Any:
    """with_sharding_constraint every array leaf of ``tree`` onto the
    scenario mesh along axis 0 (scalars untouched).  No-op when ``mesh``
    is None, so sharded and unsharded code paths stay one code path."""
    if mesh is None:
        return tree

    def pin(x):
        nd = jnp_ndim(x)
        if nd == 0:
            return x
        return jax.lax.with_sharding_constraint(
            x, scenario_sharding(mesh, nd)
        )

    return jax.tree.map(pin, tree)


def device_put_scenario(tree: Any, mesh: Mesh | None) -> Any:
    """device_put every array leaf with axis-0 scenario sharding (a no-op
    for arrays already laid out that way).  Host-side counterpart of
    :func:`pin_scenario` for feeding jitted entry points."""
    if mesh is None:
        return tree

    def put(x):
        nd = jnp_ndim(x)
        if nd == 0:
            return x
        return jax.device_put(x, scenario_sharding(mesh, nd))

    return jax.tree.map(put, tree)


def scenario_row_devices(s: int, n_shards: int) -> np.ndarray:
    """Device index owning each of ``s`` scenario rows under axis-0
    scenario sharding: a 1-D ``NamedSharding`` splits the axis into
    ``n_shards`` contiguous blocks of ``s // n_shards`` rows, so row
    ``r`` lives on device ``r // (s // n_shards)``.  Pure host math (the
    shard-aware chunk policy consumes it every step, so it must not
    touch the device); ``s`` must divide the mesh, exactly as the
    compiled programs require.  The multidevice suite checks this
    against the actual ``Array.sharding`` layout so the two can never
    silently diverge."""
    if n_shards < 1:
        raise ValueError(f"scenario_row_devices: n_shards must be >= 1, got {n_shards}")
    if s % n_shards:
        raise ValueError(
            f"scenario_row_devices: {s} rows do not divide {n_shards} shards"
        )
    return np.arange(s) // max(s // n_shards, 1)


def scenario_layout_mismatches(tree: Any, mesh: Mesh | None) -> list[str]:
    """Tree paths of array leaves NOT carrying axis-0 scenario
    ``NamedSharding`` on ``mesh`` (empty list == correctly laid out).

    The elastic-restore differential asserts on this: after a
    checkpoint restored onto a different device count, every leaf of
    the re-pinned state/prep pytrees must live on the NEW mesh with the
    scenario axis sharded — a silently replicated (or stale-mesh) leaf
    would still compute correctly but defeat the rescale.  With ``mesh``
    None (single-device) any placement is accepted."""
    if mesh is None:
        return []
    bad = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        nd = jnp_ndim(leaf)
        if nd == 0:
            continue
        sh = getattr(leaf, "sharding", None)
        want = scenario_sharding(mesh, nd)
        if sh is None or not sh.is_equivalent_to(want, nd):
            path = jax.tree_util.keystr(kp)
            bad.append(f"{path}: {sh}")
    return bad


def jnp_ndim(x) -> int:
    return getattr(x, "ndim", np.ndim(x))

# (regex over the tree path, trailing-dims sharding) — first match wins.
# The tuple addresses the *last* len(tuple) dims of the leaf; leading dims
# (stacked layer axis, MoE expert axis, codebook axis) are unsharded by
# left-padding with None — so one rule serves plain, stacked and
# expert-stacked variants of a matrix.
_RULES: list[tuple[str, tuple]] = [
    # --- embeddings / head: vocab over model, d over data (fsdp)
    (r"embed", ("model", "data")),
    (r"lm_head", ("data", "model")),
    # --- attention
    (r"attn.*\['w[qkv]'\]", ("data", "model")),
    (r"attn.*\['b[qkv]'\]", ("model",)),
    (r"attn.*\['wo'\]", ("model", "data")),
    # --- mlp (dense and MoE expert-stacked; E is left-padded to None)
    (r"\['router'\]", (None, None)),
    (r"\['w_gate'\]", ("data", "model")),
    (r"\['w_up'\]", ("data", "model")),
    (r"\['w_down'\]", ("model", "data")),
    # --- ssm / mamba2 / mlstm mixers
    (r"mixer.*\['in_proj'\]", ("data", "model")),
    (r"mixer.*\['out_proj'\]", ("model", "data")),
    (r"mixer.*\['w[qkv]'\]", ("data", "model")),
    # --- xlstm sLSTM
    (r"\['w_in'\]", ("data", "model")),
    (r"\['w_out'\]", ("model", "data")),
]


def act_pspec(mesh_axes: tuple[str, ...]) -> P:
    """Between-blocks residual constraint: batch over dp, sequence over
    'model' (Megatron-SP: the saved scan carries are what this bounds)."""
    dp = tuple(a for a in mesh_axes if a in ("pod", "data"))
    return P(dp, "model", None)


def _spec_for(path: str, leaf, mesh_shape: dict | None = None) -> P:
    nd = getattr(leaf, "ndim", 0)
    # MoE expert weights: true expert parallelism (E over 'model') when the
    # expert count divides the axis — every expert einsum is then local to
    # its shard and the backward has no model-axis partial sums.  Falls
    # through to the d_ff-sharding rules otherwise (e.g. 8 experts on a
    # 16-wide axis).
    if mesh_shape is not None and re.search(r"moe.*\['w_(gate|up|down)'\]", path):
        shape = getattr(leaf, "shape", ())
        e_ax = nd - 3
        if e_ax >= 0 and shape[e_ax] % mesh_shape.get("model", 1) == 0:
            parts = [None] * nd
            parts[e_ax] = "model"
            if shape[e_ax + 1] % mesh_shape.get("data", 1) == 0:
                parts[e_ax + 1] = "data"
            return P(*parts)
    for pat, trailing in _RULES:
        if re.search(pat, path):
            parts = [None] * max(nd - len(trailing), 0) + list(trailing)
            parts = parts[-nd:] if nd else []
            if mesh_shape is not None:
                shape = getattr(leaf, "shape", ())
                parts = [
                    a if (a is None or shape[i] % mesh_shape.get(a, 1) == 0) else None
                    for i, a in enumerate(parts)
                ]
            return P(*parts)
    return P()  # replicated


def param_pspecs(params, mesh=None, tp: bool = True) -> Any:
    """PartitionSpec pytree matching ``params``.

    When ``mesh`` is given, any axis that does not divide its dimension
    evenly is dropped (pjit argument shardings require exact division;
    e.g. an 8-expert tensor cannot ride a 16-wide axis).  ``tp=False``
    drops the 'model' axis from every rule — the pure-DP layout for
    models too small to amortize tensor parallelism (a 16-way TP of a
    125M-param stack pays one activation all-reduce per matmul for
    near-zero compute saved).
    """
    mesh_shape = dict(mesh.shape) if mesh is not None else None
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def drop_tp(spec):
        if tp:
            return spec
        return P(*[
            None if part == "model"
            else (tuple(a for a in part if a != "model") or None)
            if isinstance(part, tuple) else part
            for part in spec
        ])

    specs = [
        drop_tp(_spec_for(jax.tree_util.keystr(kp), leaf, mesh_shape))
        for kp, leaf in flat
    ]
    return jax.tree.unflatten(jax.tree.structure(params), specs)


def state_pspecs(state, mesh=None, tp: bool = True) -> Any:
    """Specs for a TrainState: moments mirror params; counters replicated."""
    from repro.train.trainer import TrainState

    pspec = param_pspecs(state.params, mesh, tp)
    return TrainState(
        params=pspec,
        opt_state={
            "m": param_pspecs(state.opt_state["m"], mesh, tp),
            "v": param_pspecs(state.opt_state["v"], mesh, tp),
            "step": P(),
        },
        step=P(),
    )


def batch_pspec(mesh_axes: tuple[str, ...], batch: Any) -> Any:
    """Shard the global-batch dim over the data(+pod) axes."""
    dp = tuple(a for a in mesh_axes if a in ("pod", "data"))

    def spec(leaf):
        nd = getattr(leaf, "ndim", 0)
        return P(dp, *(None,) * (nd - 1))

    return jax.tree.map(spec, batch)


def decode_state_pspecs(state, mesh_axes: tuple[str, ...], cfg=None,
                        mesh=None) -> Any:
    """KV caches / recurrent states: batch over data(+pod), heads (or the
    head_dim fallback when the kv-head count doesn't divide the axis)
    over 'model'.

    A 32k decode cache is the dominant HBM resident at serving time
    (e.g. olmoe at B=128: 550 GB of kv) — it MUST shard over the model
    axis, exactly like the attention heads that consume it.  Stacked-
    family states (attn kv / mamba2) carry a leading layer axis, so
    batch is axis 1; xlstm states are per-layer python lists with batch
    at axis 0.
    """
    dp = tuple(a for a in mesh_axes if a in ("pod", "data"))
    mesh_shape = dict(mesh.shape) if mesh is not None else {}
    model_size = mesh_shape.get("model", 1)
    batch_axis = 0 if (cfg is not None and cfg.block_pattern == "xlstm") else 1

    def spec(leaf):
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        if nd <= batch_axis:
            return P(*(None,) * nd)
        parts: list = [None] * nd
        if shape[batch_axis] % max(int(np.prod([mesh_shape.get(a, 1) for a in dp])), 1) == 0:
            parts[batch_axis] = dp
        # Shard axis 2 over 'model' first: for kv caches (L, B, S, K, hd)
        # that is the *sequence* axis — flash-decode layout: the score dot
        # keeps S as an output dim (no contraction resharding; softmax and
        # the o-reduction psum over the model axis), and S always divides
        # the mesh unlike the kv-head count.  For mamba2 states
        # (L, B, H, N, P) axis 2 is the head axis — also the right one.
        # Fall back to trailing axes when axis 2 doesn't divide.
        if nd >= 4 and model_size > 1:
            for ax in (2, nd - 2, nd - 1):
                if ax == batch_axis:
                    continue
                if shape[ax] % model_size == 0 and shape[ax] >= model_size:
                    parts[ax] = "model"
                    break
        return P(*parts)

    return jax.tree.map(spec, state)
