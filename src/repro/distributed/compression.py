"""Gradient compression for slow inter-pod links.

Two composable transforms, both pure pytree->pytree so they plug into
``make_train_step(grad_transform=...)``:

* :func:`int8_compress` — per-tensor symmetric int8 quantization with an
  *error-feedback* residual carried across steps (the standard fix for
  biased quantizers: the quantization error is added back into the next
  step's gradient, so the compression error telescopes instead of
  accumulating).  4x traffic reduction on the gradient all-reduce.
* :func:`topk_compress` — keep the largest-|g| fraction per tensor (with
  error feedback), zeroing the rest; combined with sparsity-aware
  collectives this gives 10-100x reduction and is the classic deep
  gradient compression scheme.

In the pjit dataflow the transform runs *before* GSPMD inserts the
gradient all-reduce, so the reduced-precision representation is what
crosses the pod boundary.  Error-feedback state is part of TrainState
extensions (see examples/train_lm.py for wiring).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "int8_compress",
    "int8_decompress",
    "topk_compress",
    "make_error_feedback_transform",
]


def int8_compress(g):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def topk_compress(g, frac: float = 0.01):
    """Keep the top-``frac`` entries by magnitude (per tensor)."""
    flat = g.reshape(-1)
    k = max(int(frac * flat.size), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(g) >= thresh
    return jnp.where(mask, g, 0.0), mask


def make_error_feedback_transform(mode: str = "int8", frac: float = 0.01):
    """Returns (init_fn, transform_fn) for error-feedback compression.

    init_fn(grads_like) -> residual pytree (zeros)
    transform_fn(grads, residual) -> (compressed_grads, new_residual)
    """

    def init_fn(grads_like: Any):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

    def transform_fn(grads: Any, residual: Any):
        def one(g, r):
            g32 = g.astype(jnp.float32) + r
            if mode == "int8":
                q, scale = int8_compress(g32)
                out = int8_decompress(q, scale)
            elif mode == "topk":
                out, _ = topk_compress(g32, frac)
            else:
                raise ValueError(mode)
            return out.astype(g.dtype), g32 - out

        pairs = jax.tree.map(one, grads, residual)
        comp = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
        return comp, res

    return init_fn, transform_fn
