"""Elastic scaling + failure handling for the serving and training loops.

The recovery model is checkpoint-based (the standard for TPU pods, where
a failed host takes down its slice): on any fault the job restarts from
the last complete checkpoint, possibly on a *different* device count.
For the continuous solve service that restart path is
:class:`repro.serve.recovery.ServiceRecovery` — in-flight
:class:`~repro.solvers.batched.BpcgState` checkpoints restore onto
whatever scenario mesh the survivor process builds here.

* :func:`elastic_scenario_mesh` — the serving-side remesh: a 1-D
  scenario mesh over whatever devices are alive (the scenario axis has
  no architecture-bound degree, so any device count is a valid mesh;
  restored states are re-laid-out row-wise via
  ``BatchedGMGSolver.take_rows`` / ``device_put_scenario``).
* :func:`elastic_remesh` — the training-side variant: largest valid
  (data, model) mesh, preserving the model-axis size when possible (TP
  degree is architecture-bound; DP degree is the elastic dimension).
* :func:`reshard_state` — move a restored state pytree onto a new mesh
  by re-applying sharding rules (jax.device_put with the new
  NamedSharding tree).
* :func:`simulate_failures` — deterministic device-loss test hook, used
  by the fault-injection suite to rehearse shrink/regrow rescales.
* :class:`StepWatchdog` — straggler/hang mitigation: a monitor thread
  that fires a callback when a step exceeds ``timeout``.  The solve
  service wires it onto ``step()`` via
  ``ElasticityService.attach_watchdog`` (fires feed the metrics
  registry and span stream); at pod scale the callback escalates to the
  cluster manager to evict the straggler.
"""

from __future__ import annotations

import threading
import time
from typing import Callable

import jax
from jax.sharding import Mesh, NamedSharding

__all__ = [
    "elastic_scenario_mesh",
    "elastic_remesh",
    "reshard_state",
    "StepWatchdog",
    "simulate_failures",
]


def elastic_scenario_mesh(devices=None) -> Mesh:
    """1-D scenario mesh over the alive devices (all of them by
    default) — the serving-side ``elastic_remesh``.  Unlike the
    (data, model) training mesh there is no architecture-bound axis to
    preserve: scenarios never couple, so every device count is a valid
    mesh and a rescale is purely a row re-layout (see
    :meth:`repro.solvers.batched.BatchedGMGSolver.take_rows`)."""
    from repro.distributed.sharding import scenario_mesh

    return scenario_mesh(devices=devices)


def elastic_remesh(
    devices=None, *, model_parallel: int = 16, axis_names=("data", "model")
) -> Mesh:
    """Largest (data, model) mesh over the alive devices.

    Keeps the model axis at ``model_parallel`` if the device count
    allows, else falls back to the largest power-of-two divisor — the
    params must still fit per-device, so shrinking TP is the last
    resort.  Drops stragglers beyond the largest usable rectangle.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    mp = model_parallel
    while mp > 1 and n // mp == 0:
        mp //= 2
    dp = n // mp
    if dp == 0:
        raise RuntimeError(f"not enough devices ({n}) for any mesh")
    used = devices[: dp * mp]
    import numpy as np

    arr = np.array(used).reshape(dp, mp)
    return Mesh(arr, axis_names)


def reshard_state(state, pspecs, mesh: Mesh):
    """Place (possibly host-resident, possibly differently-sharded) state
    onto ``mesh`` according to ``pspecs``."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, pspecs
    )


def simulate_failures(devices, n_failed: int):
    """Drop the last ``n_failed`` devices (test hook for elastic logic)."""
    if n_failed >= len(devices):
        raise ValueError("cannot fail every device")
    return devices[: len(devices) - n_failed]


class StepWatchdog:
    """Detects hung/straggling steps.

    Usage::

        wd = StepWatchdog(timeout_s=300, on_timeout=escalate)
        for batch in data:
            with wd.step():
                state, metrics = train_step(state, batch)
    """

    def __init__(self, timeout_s: float, on_timeout: Callable[[float], None] | None = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self.timeouts = 0
        self.slowest = 0.0

    class _StepCtx:
        def __init__(self, wd: "StepWatchdog"):
            self.wd = wd
            self._fired = threading.Event()
            self._done = threading.Event()

        def __enter__(self):
            self.t0 = time.perf_counter()

            def monitor():
                if not self._done.wait(self.wd.timeout_s):
                    self._fired.set()
                    self.wd.timeouts += 1
                    if self.wd.on_timeout:
                        self.wd.on_timeout(time.perf_counter() - self.t0)

            self._thread = threading.Thread(target=monitor, daemon=True)
            self._thread.start()
            return self

        def __exit__(self, *exc):
            self._done.set()
            self._thread.join(timeout=1.0)
            self.wd.slowest = max(self.wd.slowest, time.perf_counter() - self.t0)
            return False

    def step(self) -> "_StepCtx":
        return self._StepCtx(self)
