"""Pipeline parallelism over the ``pod`` axis (GPipe schedule via
``jax.shard_map`` + ``jax.lax.ppermute``).

The layer stack is split into ``n_stages`` contiguous groups; stage ``s``
lives on slice ``s`` of the pipeline mesh axis.  The microbatch stream
enters stage 0; every tick each stage applies its layers to the
activation resident on it and forwards the result to the next stage with
``ppermute`` (collective_permute — the TPU-native nearest-neighbour ICI
primitive, which is exactly what an inter-pod hop should use).  After
``n_micro + n_stages - 1`` ticks every microbatch has traversed every
stage; the bubble fraction is the classic (n_stages-1)/(n_micro+n_stages-1).

The last stage accumulates its outputs masked to its own ticks; a final
``psum`` over the stage axis replicates the result (all other stages
contribute zeros), so the caller sees an ordinary replicated batch.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map

__all__ = ["pipeline_apply", "split_stages", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def split_stages(stacked_params, n_stages: int):
    """Reshape stacked per-layer params (L, ...) -> (n_stages, L/S, ...)."""

    def reshape(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])

    return jax.tree.map(reshape, stacked_params)


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x,
    *,
    mesh,
    n_micro: int,
    axis: str = "pod",
):
    """Run x (B, ...) through the staged stack.

    stage_fn(stage_param_slice, microbatch) -> microbatch.
    stage_params: pytree with leading (n_stages, ...) axis.
    Returns the transformed batch, replicated over ``axis``.
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro

    def staged(params_local, x_full):
        my_params = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        xs = x_full.reshape((n_micro, mb) + x_full.shape[1:])
        n_ticks = n_micro + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, out = carry
            m = t - sid  # microbatch index seen by this stage at tick t
            active = (m >= 0) & (m < n_micro)
            # stage 0 ingests microbatch t while the stream lasts
            inj = jnp.where(
                (sid == 0) & (t < n_micro),
                xs[jnp.clip(t, 0, n_micro - 1)],
                buf,
            )
            y = stage_fn(my_params, inj)
            y = jnp.where(active, y, buf)
            # last stage emits its finished microbatch into the output slot
            emit = active & (sid == n_stages - 1)
            sel = (jnp.arange(n_micro) == jnp.clip(m, 0, n_micro - 1)) & emit
            out = out + sel.reshape((n_micro,) + (1,) * y.ndim).astype(y.dtype) * y[None]
            y = jax.lax.ppermute(y, axis, fwd)
            return (y, out), None

        buf0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(n_ticks))
        # replicate: only the last stage wrote non-zeros
        out = jax.lax.psum(out, axis)
        return out.reshape(x_full.shape)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        staged,
        mesh=mesh,
        in_specs=(spec_p, P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, x)
