"""Deterministic, shard-aware synthetic token pipeline.

Every batch is a pure function of ``(seed, step, shard_index)`` — the
pipeline is *stateless*, so checkpoint/restart and elastic re-sharding
need to persist only the step counter: a restarted or re-sharded job
regenerates byte-identical data for any step.  Tokens follow a mixed
zipfian/ngram-ish distribution so the loss curve is non-trivial (the
model can actually learn bigram structure in the end-to-end example).

``batch_spec`` returns the ShapeDtypeStruct stand-ins consumed by the
multi-pod dry-run (no allocation); ``make_batch`` materializes the same
shapes on host.  ``TokenPipeline`` wraps them in a prefetching iterator.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["batch_spec", "make_batch", "TokenPipeline"]


def _batch_shapes(cfg, shape) -> dict[str, tuple[tuple[int, ...], np.dtype]]:
    """Shapes/dtypes of one global batch for (arch cfg, ShapeConfig)."""
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    out = {
        "tokens": (tok_shape, np.int32),
        "labels": (tok_shape, np.int32),
    }
    if cfg.n_vision_tokens:
        out["vision_embeds"] = (
            (B, cfg.n_vision_tokens, cfg.d_model),
            np.dtype(cfg.dtype),
        )
    return out


def batch_spec(cfg, shape) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (never allocates)."""
    return {
        k: jax.ShapeDtypeStruct(shp, dt)
        for k, (shp, dt) in _batch_shapes(cfg, shape).items()
    }


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish token draw: rank ~ floor(vocab * u^3) biases low ids."""
    u = rng.random(shape)
    toks = (vocab * u**3).astype(np.int64)
    return np.minimum(toks, vocab - 1).astype(np.int32)


def make_batch(cfg, shape, step: int, seed: int = 0, shard=None) -> dict[str, np.ndarray]:
    """Materialize the (optionally sharded) batch for ``step``.

    shard: None for the full global batch, or (index, count) to produce
    rows [index*B/count, (index+1)*B/count) — each shard's rows depend
    only on their global row id, so any shard layout yields the same
    global batch (elastic-rescale invariant).
    """
    B = shape.global_batch
    rows = np.arange(B)
    if shard is not None:
        idx, count = shard
        assert B % count == 0, (B, count)
        rows = rows[idx * (B // count) : (idx + 1) * (B // count)]

    shapes = _batch_shapes(cfg, shape)
    out: dict[str, np.ndarray] = {}
    tok_shape, _ = shapes["tokens"]
    per_row = tok_shape[1:]
    toks = np.empty((len(rows),) + per_row, np.int32)
    for i, r in enumerate(rows):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, int(r)])
        )
        t = _zipf_tokens(rng, per_row, cfg.vocab)
        # inject learnable bigram structure: even positions repeat a
        # row-constant "topic" token 25% of the time.
        topic = int(rng.integers(cfg.vocab))
        mask = (rng.random(per_row) < 0.25) & (
            (np.arange(per_row[0]) % 2 == 0)[(...,) + (None,) * (len(per_row) - 1)]
        )
        toks[i] = np.where(mask, topic, t)
    out["tokens"] = toks

    # next-token labels; -1 masks the last position (and vision prefix).
    labels = np.concatenate(
        [toks[:, 1:], np.full_like(toks[:, :1], -1)], axis=1
    )
    if cfg.n_vision_tokens:
        labels[:, : cfg.n_vision_tokens] = -1
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, 1 << 20]))
        out["vision_embeds"] = rng.standard_normal(
            (len(rows), cfg.n_vision_tokens, cfg.d_model), np.float32
        ).astype(shapes["vision_embeds"][1])
    out["labels"] = labels
    return out


class TokenPipeline:
    """Prefetching iterator over deterministic batches.

    State = the step counter alone; ``state_dict()``/``load_state_dict``
    are what the checkpoint manager persists.
    """

    def __init__(self, cfg, shape, seed: int = 0, start_step: int = 0,
                 shard=None, prefetch: int = 2):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.shard = shard
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.shape, step, self.seed, self.shard)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)

    # -- checkpointable state -------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: dict):
        self.close()
        self.__init__(self.cfg, self.shape, d["seed"], d["step"], self.shard)
