from repro.data.pipeline import TokenPipeline, batch_spec, make_batch  # noqa: F401
