from repro.serve.chunk_policy import (  # noqa: F401
    AdaptiveChunkPolicy,
    ChunkObservation,
    ChunkPolicy,
    FixedChunkPolicy,
    SchedulerTrace,
    ShardAdaptiveChunkPolicy,
    make_chunk_policy,
    simulate_cadence_trace,
)
from repro.serve.elasticity_service import (  # noqa: F401
    ElasticityService,
    SolveReport,
    SolveRequest,
)
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.recovery import ServiceRecovery  # noqa: F401
