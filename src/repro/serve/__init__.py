from repro.serve.elasticity_service import (  # noqa: F401
    ElasticityService,
    SolveReport,
    SolveRequest,
)
from repro.serve.engine import ServeEngine  # noqa: F401
