"""Batched serving engine: prefill + decode with continuous batching.

A small production-shaped engine around the model's prefill/decode_step:

* requests arrive with a prompt and a max_new_tokens budget;
* the engine groups waiting requests into a batch, runs one prefill,
  then iterates jitted single-token decode steps over the whole batch;
* finished rows (EOS or budget) are retired and their slots refilled
  from the queue at the next prefill boundary (simple generational
  continuous batching — slot reuse without paged caches);
* greedy or temperature sampling.

The decode step is compiled once per (batch, cache) shape; the KV cache
is donated so decode is in-place at the XLA level.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import decode_step, init_params, prefill

__all__ = ["ServeEngine", "Request"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32 (or (S, n_cb) for codebook models)
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params=None, *, max_len: int = 4096,
                 max_batch: int = 8, seed: int = 0):
        self.cfg = cfg
        self.max_len = max_len
        self.max_batch = max_batch
        self.params = (
            params
            if params is not None
            else init_params(jax.random.PRNGKey(seed), cfg)
        )
        self._decode = jax.jit(
            lambda p, t, s, pos: decode_step(p, t, s, pos, cfg),
            donate_argnums=(2,),
        )
        self._rng = np.random.default_rng(seed)

    # -- sampling -----------------------------------------------------------
    def _sample(self, logits: np.ndarray, temps: np.ndarray) -> np.ndarray:
        out = np.empty(logits.shape[:-1], np.int32)
        flat = logits.reshape(-1, logits.shape[-1])
        tf = np.broadcast_to(temps.reshape(-1, *([1] * (logits.ndim - 2))),
                             logits.shape[:-1]).reshape(-1)
        for i, (row, t) in enumerate(zip(flat, tf)):
            if t <= 0:
                out.reshape(-1)[i] = int(np.argmax(row))
            else:
                p = np.exp((row - row.max()) / t)
                p /= p.sum()
                out.reshape(-1)[i] = int(self._rng.choice(len(row), p=p))
        return out

    # -- one generation batch -------------------------------------------------
    def generate(self, requests: list[Request]) -> list[Request]:
        """Run a list of requests to completion (batched, generational)."""
        queue = list(requests)
        while any(not r.done for r in queue):
            batch = [r for r in queue if not r.done][: self.max_batch]
            self._run_batch(batch)
        return requests

    def _run_batch(self, batch: list[Request]):
        cfg = self.cfg
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        S = max(S, 2)
        # left-pad prompts to a common length (pads attend causally but
        # positions stay dense; fine for the synthetic-serving example)
        tok_shape = (B, S) if not cfg.n_codebooks else (B, S, cfg.n_codebooks)
        toks = np.zeros(tok_shape, np.int32)
        for i, r in enumerate(batch):
            toks[i, S - len(r.prompt):] = r.prompt
        feed = {"tokens": jnp.asarray(toks)}
        if cfg.n_vision_tokens:
            feed["vision_embeds"] = jnp.zeros(
                (B, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        logits, state = prefill(self.params, feed, cfg, max_len=self.max_len)
        temps = np.array([r.temperature for r in batch])
        budget = max(r.max_new_tokens for r in batch)
        pos = S
        cur = self._sample(np.asarray(logits, np.float32), temps)
        for i, r in enumerate(batch):
            r.out_tokens.append(cur[i].tolist())
        for _ in range(budget - 1):
            tok = jnp.asarray(cur.reshape((B, 1) + cur.shape[1:]))
            logits, state = self._decode(self.params, tok, state,
                                         jnp.int32(pos))
            pos += 1
            cur = self._sample(np.asarray(logits, np.float32), temps)
            for i, r in enumerate(batch):
                if r.done:
                    continue
                t = cur[i].tolist()
                r.out_tokens.append(t)
                if len(r.out_tokens) >= r.max_new_tokens or (
                    r.eos_id is not None and t == r.eos_id
                ):
                    r.done = True
            if all(r.done for r in batch):
                break
        for r in batch:
            r.done = True
