"""Fault-tolerant continuous serving: checkpoint/restore of in-flight
solver state, elastic device-count changes, and the step watchdog.

A preempted :class:`~repro.serve.elasticity_service.ElasticityService`
used to lose every in-flight solve; :class:`ServiceRecovery` makes the
engine restartable by snapshotting, at step boundaries (the natural
barriers — chunked resumption is exact, see
:func:`repro.solvers.batched.bpcg_chunk`), everything the engine needs
to resume:

* per flight: the resumable :class:`~repro.solvers.batched.BpcgState`
  and prep pytree (host-gathered bitwise through
  ``BatchedGMGSolver.state_to_host``/``prep_to_host``, including the
  mixed-precision ``lam_w_solve``/``mu_w_solve`` twins), the folded
  material/traction/tolerance rows, the prep-reuse digests and the
  scheduling mirrors (``row_iters``, retire history) the adaptive chunk
  policies feed on — so a restored engine makes the SAME scheduling
  decisions;
* the queue, ticket counter, fallback-ticket set, step index and any
  undrained completed reports.

Everything rides one :class:`repro.checkpoint.manager.CheckpointManager`
checkpoint (atomic rename, manifest-last, per-leaf CRC), as a flat
``{name: array}`` dict plus one pickled host-metadata blob, restored via
``restore_latest_items`` — torn or corrupt checkpoints are skipped
newest-first.

Restore semantics:

* **same device count** — the flight keeps its exact bucket and every
  array restores bitwise, so the resumed service finishes every
  in-flight request with bitwise-identical solutions and iteration
  counts to an uninterrupted run (the crash/restore differential suite
  asserts this, solutions included).
* **elastic rescale** — the checkpoint carries no device layout, only
  host rows.  Restoring onto a service whose scenario mesh has a
  different device count re-pins every leaf onto the new mesh
  (``device_put`` with axis-0 ``NamedSharding``).  When the old bucket
  still divides the new mesh the row layout is identity (bitwise
  resume); otherwise the rows are re-bucketed through
  ``BatchedGMGSolver.take_rows`` to the smallest device-aligned bucket,
  filler rows are marked for reset (born-converged padding), and the
  solve resumes under a different compiled program shape — iteration
  counts and flags stay exact, solutions agree to the usual
  cross-bucket-shape ~ulp fusion wobble.  Queues are never drained:
  waiting tickets restore as-is and admit onto the new mesh.

The hang detector lives on the service itself
(``ElasticityService.attach_watchdog`` wraps ``step()`` in a
:class:`repro.distributed.elastic.StepWatchdog`); fires land in the same
metrics registry (``service_watchdog_fires_total``) and span stream as
the ``checkpoint_write``/``restore`` spans recorded here.  Catalog:
``docs/FAULT_TOLERANCE.md``.
"""

from __future__ import annotations

import dataclasses
import pickle

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.serve.elasticity_service import (
    _STAT_HELP,
    ElasticityService,
    SolveReport,
    SolveRequest,
    _Flight,
    _Slot,
)

__all__ = ["ServiceRecovery"]

_FORMAT = 1


def _host_request(req: SolveRequest) -> SolveRequest:
    """A pickle-safe copy of a request: per-element material fields may
    arrive as jax arrays; the checkpoint stores host numpy."""
    m = req.materials
    if m is not None and not isinstance(m, dict):
        lam_e, mu_e = m
        m = (np.asarray(lam_e), np.asarray(mu_e))
        return dataclasses.replace(req, materials=m)
    return req


def _host_report(rep: SolveReport) -> SolveReport:
    return dataclasses.replace(
        rep,
        request=_host_request(rep.request),
        x=None if rep.x is None else np.asarray(rep.x),
    )


def _object_row(values) -> np.ndarray:
    """(n,) object array from a python list (digest bytes / 0 fillers)
    without numpy trying to deep-convert the elements."""
    out = np.zeros((len(values),), dtype=object)
    for i, v in enumerate(values):
        out[i] = v
    return out


class ServiceRecovery:
    """Periodic in-flight checkpoints + startup restore for one
    :class:`ElasticityService`.

    Usage (the ``serve_solve --checkpoint-dir/--resume`` loop)::

        recovery = ServiceRecovery(service, ckpt_dir, every=4)
        if resume:
            recovery.restore()          # False when no usable checkpoint
        ...
        while not service.idle():
            service.step()
            recovery.maybe_checkpoint()

    ``every`` is in engine steps; ``keep`` bounds disk use (forwarded to
    the :class:`CheckpointManager`).  Checkpointing never changes
    numerics: the only engine state it touches is the early fold of the
    pending consumed vector (``_finalize_chunk``), which the next retire
    pass would perform identically.
    """

    def __init__(
        self,
        service: ElasticityService,
        directory: str,
        *,
        every: int = 1,
        keep: int = 3,
    ):
        if every < 1:
            raise ValueError(f"checkpoint every must be >= 1, got {every}")
        self.service = service
        self.manager = CheckpointManager(directory, keep=keep)
        self.every = every
        self.last_step: int | None = None  # step of the last local save

    # -- observability -------------------------------------------------------
    def _inc(self, stat: str) -> None:
        svc = self.service
        svc.registry.counter(
            f"service_{stat}_total",
            _STAT_HELP[stat],
            policy=svc.chunk_policy.name,
            devices=svc.n_shards,
        ).inc()

    def summary(self) -> dict:
        """The ``recovery`` section of the CLI stats line."""
        svc = self.service
        return {
            "checkpoints_written": svc.stats["checkpoints_written"],
            "restores": svc.stats["restores"],
            "watchdog_fires": svc.stats["watchdog_fires"],
            "last_step": self.last_step,
            "directory": self.manager.directory,
        }

    # -- write ---------------------------------------------------------------
    def maybe_checkpoint(self) -> str | None:
        """Checkpoint when ``every`` steps have passed since the last
        local save (call once per ``step()``)."""
        step = self.service._step_index
        if self.last_step is not None and step - self.last_step < self.every:
            return None
        return self.checkpoint()

    def checkpoint(self) -> str:
        """Snapshot the full serving state at the current step boundary
        and commit it atomically.  Returns the checkpoint directory."""
        svc = self.service
        rec = svc.spans
        t0 = svc.clock() if rec is not None else 0.0
        arrays: dict[str, np.ndarray] = {}
        flights = []
        for i, (key, fl) in enumerate(svc._flights.items()):
            # Fold the in-flight chunk's consumed vector now (blocks on
            # the chunk; the next retire pass would do the same fold).
            svc._finalize_chunk(fl)
            flights.append(
                {
                    "key": key,
                    "bucket": fl.bucket,
                    "chunks": fl.chunks,
                    "slots": [
                        None
                        if s is None
                        else (s.ticket, _host_request(s.request))
                        for s in fl.slots
                    ],
                    "retire_history": list(fl.retire_history),
                    "mat_digest": list(fl.mat_digest),
                    "prep_digest": list(fl.prep_digest),
                    "prep_valid": fl.prep_valid.tolist(),
                }
            )
            pre = f"flight{i}/"
            for name, arr in fl.solver.state_to_host(fl.state).items():
                arrays[pre + "state/" + name] = arr
            for name, arr in fl.solver.prep_to_host(fl.prep).items():
                arrays[pre + "prep/" + name] = arr
            arrays[pre + "lam"] = fl.lam
            arrays[pre + "mu"] = fl.mu
            arrays[pre + "tr"] = fl.tr
            arrays[pre + "tol"] = fl.tol
            arrays[pre + "row_iters"] = fl.row_iters
            arrays[pre + "prep_lam"] = fl.prep_lam
            arrays[pre + "prep_mu"] = fl.prep_mu
        blob = {
            "format": _FORMAT,
            "flights": flights,
            "queue": [
                (t, _host_request(r)) for t, r in svc._queue
            ],
            "completed": {
                t: _host_report(r) for t, r in svc._completed.items()
            },
            "fallback_tickets": sorted(svc._fallback_tickets),
            "next_ticket": svc._next_ticket,
            "step_index": svc._step_index,
        }
        arrays["host"] = np.frombuffer(
            pickle.dumps(blob, protocol=pickle.HIGHEST_PROTOCOL),
            dtype=np.uint8,
        )
        path = self.manager.save(
            svc._step_index,
            arrays,
            extra={
                "format": _FORMAT,
                "max_batch": svc.max_batch,
                "devices": svc.n_shards,
                "n_flights": len(flights),
                "n_queued": len(svc._queue),
            },
        )
        self.last_step = svc._step_index
        self._inc("checkpoints_written")
        if rec is not None:
            rec.emit(
                "checkpoint_write",
                cat="recovery",
                tid=0,
                start=t0,
                end=svc.clock(),
                step=svc._step_index,
                flights=len(flights),
                leaves=len(arrays),
            )
        return path

    # -- read ----------------------------------------------------------------
    def restore(self, step: int | None = None) -> bool:
        """Restore the newest verifiable checkpoint (or ``step``) into
        the (empty) service.  Returns False when none exists; raises on
        a config mismatch the engine cannot absorb (``max_batch``).
        Device-count changes are absorbed elastically — see the module
        docstring for the identity-vs-re-bucket rule."""
        svc = self.service
        if svc._flights or svc._queue or svc._completed or svc._next_ticket:
            raise RuntimeError(
                "ServiceRecovery.restore() needs an empty service "
                "(restore before the first submit/step)"
            )
        if step is None:
            got = self.manager.restore_latest_items()
            if got is None:
                return False
            items, extra, step = got
        else:
            items, extra = self.manager.restore_items(step)
        if extra.get("format") != _FORMAT:
            raise ValueError(
                f"checkpoint format {extra.get('format')!r} != {_FORMAT}"
            )
        if extra.get("max_batch") != svc.max_batch:
            raise ValueError(
                f"checkpoint max_batch {extra.get('max_batch')} != "
                f"service max_batch {svc.max_batch}"
            )
        rec = svc.spans
        t0 = svc.clock() if rec is not None else 0.0
        blob = pickle.loads(items["host"].tobytes())
        now = svc.clock()
        for i, fb in enumerate(blob["flights"]):
            self._restore_flight(i, fb, items, now)
        svc._queue = [(t, r) for t, r in blob["queue"]]
        svc._t_submit = {t: now for t, _ in svc._queue}
        svc._completed = dict(blob["completed"])
        svc._fallback_tickets = set(blob["fallback_tickets"])
        svc._next_ticket = blob["next_ticket"]
        svc._step_index = blob["step_index"]
        self.last_step = blob["step_index"]
        self._inc("restores")
        if rec is not None:
            rec.emit(
                "restore",
                cat="recovery",
                tid=0,
                start=t0,
                end=svc.clock(),
                step=int(step),
                flights=len(blob["flights"]),
                from_devices=extra.get("devices"),
                to_devices=svc.n_shards,
            )
        return True

    def _restore_flight(
        self, i: int, fb: dict, items: dict, now: float
    ) -> None:
        svc = self.service
        key = fb["key"]
        slots = fb["slots"]
        live = [r for r, s in enumerate(slots) if s is not None]
        # Any live slot's request rebuilds (or cache-hits) the solver.
        req = slots[live[0]][1]
        solver, hit, t_setup = svc._solver_for(key, req)
        fl = _Flight(key, solver, hit, t_setup, tid_base=svc._flight_tid())
        if svc.spans is not None:
            svc.spans.thread_name(
                fl.tid_base, f"flight p={key[0]} refine={key[1]}"
            )

        pre = f"flight{i}/"
        sd = {
            k[len(pre) + 6 :]: v
            for k, v in items.items()
            if k.startswith(pre + "state/")
        }
        pd = {
            k[len(pre) + 5 :]: v
            for k, v in items.items()
            if k.startswith(pre + "prep/")
        }
        lam = items[pre + "lam"]
        mu = items[pre + "mu"]
        tr = items[pre + "tr"]
        tol = items[pre + "tol"]
        row_iters = items[pre + "row_iters"].astype(np.int64)
        prep_lam = items[pre + "prep_lam"]
        prep_mu = items[pre + "prep_mu"]
        mat_digest = _object_row(fb["mat_digest"])
        prep_digest = _object_row(fb["prep_digest"])
        prep_valid = np.asarray(fb["prep_valid"], dtype=bool)
        old_bucket = fb["bucket"]

        if old_bucket % svc.n_shards == 0:
            # Identity layout: the old bucket still divides the (new)
            # mesh, so every row restores in place — bitwise resume, the
            # exact compiled program shapes of the uninterrupted run.
            fl.state = solver.state_from_host(sd)
            fl.prep = solver.prep_from_host(pd)
            fl.bucket = old_bucket
            fl.slots = [
                None if s is None else _Slot(s[0], s[1], now, t_submit=now)
                for s in slots
            ]
            fl.pending_reset = None
        else:
            # Elastic re-bucket: compact the live rows onto the smallest
            # device-aligned bucket of the new mesh; filler rows (copies
            # of the first live row) are marked for reset, so the next
            # admit/launch turns them into born-converged padding.
            bucket = svc.bucket_for(max(len(live), 1))
            rows = live + [live[0]] * (bucket - len(live))
            state, prep = solver.take_rows(
                solver.state_from_host(sd, place=False),
                solver.prep_from_host(pd, place=False),
                rows,
            )
            idx = np.asarray(rows)
            n_live = len(live)
            fl.state, fl.prep = state, prep
            fl.bucket = bucket
            fl.slots = [
                _Slot(slots[r][0], slots[r][1], now, t_submit=now)
                for r in live
            ] + [None] * (bucket - n_live)
            lam, mu, tr, tol = lam[idx], mu[idx], tr[idx], tol[idx]
            row_iters = row_iters[idx]
            prep_lam, prep_mu = prep_lam[idx], prep_mu[idx]
            mat_digest = _object_row([fb["mat_digest"][r] for r in rows])
            prep_digest = _object_row([fb["prep_digest"][r] for r in rows])
            prep_valid = prep_valid[idx]
            tr[n_live:] = 0.0  # filler rows: zero RHS -> born converged
            tol[n_live:] = 1e-6
            row_iters[n_live:] = 0
            pending = np.zeros((bucket,), dtype=bool)
            pending[n_live:] = True
            fl.pending_reset = pending
            svc._inc("rebuckets", key)

        fl.lam, fl.mu, fl.tr, fl.tol = lam, mu, tr, tol
        fl.row_iters = row_iters
        fl.mat_digest, fl.prep_digest = mat_digest, prep_digest
        fl.prep_lam, fl.prep_mu = prep_lam, prep_mu
        fl.prep_valid = prep_valid
        fl.chunks = fb["chunks"]
        fl.retire_history.extend(fb["retire_history"])
        svc._flights[key] = fl
