"""Chunk scheduling policies for the continuous elasticity engine.

The continuous engine (:class:`repro.serve.elasticity_service.
ElasticityService`) advances each in-flight batch by a bounded chunk of
PCG iterations per ``step()``.  The chunk length is the serving layer's
hot-path knob: too long and near-converged rows idle inside the chunk
(wasted iterations) while freed slots wait for the chunk boundary to be
refilled; too short and the host pays a retire/refill round-trip per
handful of iterations.  Retire cadence varies strongly with the
polynomial degree and the tolerance mix of the in-flight batch, so a
fixed default is the wrong length for most mixes.

This module makes the choice a *policy*:

* :class:`FixedChunkPolicy` — today's behavior, bit-for-bit: every
  chunk has the same length (``chunk_iters``).
* :class:`AdaptiveChunkPolicy` — predict the next retirement from the
  observed iterations-to-retire cadence of the in-flight mix (a ring
  buffer of recent retire cadences) and chunk up to exactly that point,
  clamped to ``[min_chunk, max_chunk]``.
* :class:`ShardAdaptiveChunkPolicy` — with the scenario axis sharded, a
  retire only frees *device-aligned* capacity when its shard drains, so
  this policy (a) computes the cadence estimate per device and chunks to
  the earliest per-device retirement, and (b) places refills on the
  device with the fewest live rows, keeping shards evenly drained.

THE invariant every policy must preserve (and the differential suite in
``tests/test_chunk_policy.py`` enforces): **scheduling never changes
numerics**.  ``bpcg`` chunk boundaries are bitwise invisible to the
iteration and batch rows never couple, so any policy yields the same
iteration counts, convergence flags and (to machine precision)
solutions as the fixed default — only *when* rows retire and refill
differs.  A policy whose decision sequence coincides with fixed (e.g.
adaptive clamped to ``min_chunk == max_chunk``) reproduces it bitwise;
genuinely different schedules route rows through different bucket-shape
programs, which XLA fuses with the usual ~1 ulp wobble (the same bound
the sharded differential suite pins).

Every decision is recorded in a :class:`SchedulerTrace` — the observed
cadence, the chosen chunk, the refill placements and (after the chunk
ran) the per-row iterations consumed — so decisions are deterministic
and replayable: :meth:`SchedulerTrace.replay` re-derives every chunk
choice from the recorded observations alone.
:func:`simulate_cadence_trace` drives a policy against a recorded or
synthetic cadence trace with **no solver in the loop**, which is what
the deterministic scheduler-trace harness (and the executable examples
in ``docs/SCHEDULING.md``) build on.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

__all__ = [
    "HISTORY_LEN",
    "ChunkObservation",
    "ChunkPolicy",
    "FixedChunkPolicy",
    "AdaptiveChunkPolicy",
    "ShardAdaptiveChunkPolicy",
    "ChunkDecision",
    "RefillPlacement",
    "SchedulerTrace",
    "check_chunk_bounds",
    "make_chunk_policy",
    "simulate_cadence_trace",
]

# Ring-buffer length of the per-flight retire history.  Shared by the
# service and the trace simulator so harness decisions match production.
HISTORY_LEN = 32


def _check_positive_int(name: str, v, where: str) -> None:
    """ONE spelling of "must be an integer >= 1" for every policy
    parameter, so the message always names exactly the parameter the
    caller passed (never a derived value)."""
    if isinstance(v, bool) or not isinstance(v, (int, np.integer)):
        raise TypeError(
            f"{where}: {name} must be an integer >= 1, got {v!r}"
        )
    if v < 1:
        raise ValueError(f"{where}: {name} must be >= 1, got {v}")


def check_chunk_bounds(min_chunk, max_chunk, where: str) -> None:
    """Policy-bound validation (the generalization of the old
    ``chunk_iters < 1`` check): both bounds must be integers >= 1 and
    ordered.  Error messages name the offending bound and value."""
    _check_positive_int("min_chunk", min_chunk, where)
    _check_positive_int("max_chunk", max_chunk, where)
    if min_chunk > max_chunk:
        raise ValueError(
            f"{where}: min_chunk ({min_chunk}) must be <= "
            f"max_chunk ({max_chunk})"
        )


@dataclasses.dataclass(frozen=True)
class ChunkObservation:
    """What a policy sees when choosing the next chunk for one flight.

    Everything here is plain host data — no device arrays — so a
    recorded observation replays bit-for-bit with no solver in the loop.

    ``live_iters[i]`` is live row i's iteration count since its
    (re)start, ``live_devices[i]`` the device that owns its shard (all
    zeros single-device), and ``history`` the flight's ring buffer of
    recent retire cadences (total iterations at retirement, oldest
    first)."""

    live_iters: tuple[int, ...]
    live_devices: tuple[int, ...]
    history: tuple[int, ...]
    bucket: int
    n_devices: int = 1


class ChunkPolicy:
    """Base policy: bounds + the two scheduling decisions.

    ``chunk_for`` picks the next chunk length from an observation;
    ``placement`` orders the free slots refills should fill (default:
    ascending slot index — exactly the pre-policy engine behavior).
    Both must be pure functions of their arguments: the service records
    every observation, and the trace harness replays them."""

    name = "chunk-policy"

    def __init__(self, min_chunk: int, max_chunk: int):
        check_chunk_bounds(min_chunk, max_chunk, f"{self.name} policy")
        self.min_chunk = int(min_chunk)
        self.max_chunk = int(max_chunk)

    def clamp(self, k: int) -> int:
        return max(self.min_chunk, min(self.max_chunk, int(k)))

    def chunk_for(self, obs: ChunkObservation) -> int:
        raise NotImplementedError

    def placement(
        self,
        free_slots: Sequence[int],
        slot_devices: Sequence[int],
        live_devices: Sequence[int],
    ) -> list[int]:
        """Order in which ``free_slots`` should be refilled.
        ``slot_devices[s]`` maps ANY slot index to its owning device;
        ``live_devices`` lists the devices of currently-live rows."""
        del slot_devices, live_devices
        return list(free_slots)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"{type(self).__name__}(min_chunk={self.min_chunk}, "
            f"max_chunk={self.max_chunk})"
        )


class FixedChunkPolicy(ChunkPolicy):
    """Every chunk has the same length — the pre-policy engine,
    bit-for-bit (same chunk choices, same ascending-slot refills)."""

    name = "fixed"

    def __init__(self, chunk_iters: int):
        _check_positive_int("chunk_iters", chunk_iters, "fixed policy")
        super().__init__(int(chunk_iters), int(chunk_iters))

    def chunk_for(self, obs: ChunkObservation) -> int:
        del obs
        return self.min_chunk


def _next_retire_distance(
    live_iters: Sequence[int], history: Sequence[int]
) -> int | None:
    """Predicted iterations until the next retirement: for each live row
    at iteration ``it``, the nearest historical cadence strictly ahead of
    it (``h - it`` for the smallest ``h > it``); the minimum over rows.
    None when the history offers no prediction (empty, or every cadence
    already behind every live row)."""
    best: int | None = None
    for it in live_iters:
        ahead = [h - it for h in history if h > it]
        if ahead:
            d = min(ahead)
            best = d if best is None else min(best, d)
    return best


class AdaptiveChunkPolicy(ChunkPolicy):
    """Chunk to the predicted next retirement of the in-flight mix.

    The estimate comes from the flight's retire-history ring buffer:
    rows retiring at ~c iterations teach the policy to cut chunks at the
    c-iteration boundary, so a near-converged row neither idles inside a
    long chunk nor delays the refill of its slot.  With no usable
    history the policy falls back to ``default_chunk`` (the fixed
    default), and every choice is clamped to ``[min_chunk, max_chunk]``
    — so ``min_chunk == max_chunk`` reproduces
    :class:`FixedChunkPolicy` decision-for-decision."""

    name = "adaptive"

    def __init__(
        self,
        min_chunk: int = 1,
        max_chunk: int = 32,
        default_chunk: int = 8,
    ):
        super().__init__(min_chunk, max_chunk)
        _check_positive_int(
            "default_chunk", default_chunk, f"{self.name} policy"
        )
        self.default_chunk = int(default_chunk)

    def chunk_for(self, obs: ChunkObservation) -> int:
        d = _next_retire_distance(obs.live_iters, obs.history)
        return self.clamp(self.default_chunk if d is None else d)


class ShardAdaptiveChunkPolicy(AdaptiveChunkPolicy):
    """Adaptive chunking + placement driven by the per-device live mix.

    With the scenario axis sharded, bucket capacity is device-aligned: a
    retire only lets the bucket shrink (or a refill land without
    growing it) when its *shard* drains.  Two shard-aware choices:

    * **chunk length** — the cadence estimate runs per device over that
      device's live rows; the chunk stops at the earliest per-device
      predicted retirement (devices whose rows have no usable history
      contribute the fixed default), so no shard sits on a retired row
      waiting for another shard's long chunk.
    * **refill placement** — freed slots are filled on the device with
      the fewest live rows first (ties to the lower device, then the
      lower slot index), keeping shards evenly loaded so retires free
      whole shards as early as possible.

    Single-device this degenerates to :class:`AdaptiveChunkPolicy`
    decisions with the same ascending-slot placement."""

    name = "shard-adaptive"

    def chunk_for(self, obs: ChunkObservation) -> int:
        per_dev: dict[int, list[int]] = {}
        for it, dev in zip(obs.live_iters, obs.live_devices):
            per_dev.setdefault(dev, []).append(it)
        if not per_dev:
            return self.clamp(self.default_chunk)
        dists = []
        for dev in sorted(per_dev):
            d = _next_retire_distance(per_dev[dev], obs.history)
            dists.append(self.default_chunk if d is None else d)
        return self.clamp(min(dists))

    def placement(
        self,
        free_slots: Sequence[int],
        slot_devices: Sequence[int],
        live_devices: Sequence[int],
    ) -> list[int]:
        load: dict[int, int] = {}
        for dev in live_devices:
            load[dev] = load.get(dev, 0) + 1
        remaining = list(free_slots)
        order: list[int] = []
        while remaining:
            slot = min(
                remaining,
                key=lambda s: (
                    load.get(slot_devices[s], 0),
                    slot_devices[s],
                    s,
                ),
            )
            remaining.remove(slot)
            order.append(slot)
            dev = slot_devices[slot]
            load[dev] = load.get(dev, 0) + 1
        return order


_POLICIES = {
    "fixed": FixedChunkPolicy,
    "adaptive": AdaptiveChunkPolicy,
    "shard-adaptive": ShardAdaptiveChunkPolicy,
}


def make_chunk_policy(
    spec,
    *,
    chunk_iters: int = 8,
    min_chunk: int | None = None,
    max_chunk: int | None = None,
) -> ChunkPolicy:
    """Build a policy from its CLI/constructor spelling.

    ``spec`` is None or ``"fixed"`` (→ :class:`FixedChunkPolicy` at
    ``chunk_iters``, the pre-policy default), ``"adaptive"``,
    ``"shard-adaptive"``, or an already-built :class:`ChunkPolicy`
    (returned as-is; a prebuilt policy carries its own chunk
    configuration, so ``chunk_iters`` does not apply to it — but it is
    still validated, so a bad value cannot hide behind one).  For the
    adaptive policies ``chunk_iters`` is the no-history fallback and
    the bounds default to ``[1, 4 * chunk_iters]``.  The bounds only
    exist on the adaptive policies, so passing one with a fixed (or
    prebuilt) policy is an error, not a silent no-op."""
    if isinstance(spec, ChunkPolicy) or spec is None or spec == "fixed":
        if min_chunk is not None or max_chunk is not None:
            name = spec.name if isinstance(spec, ChunkPolicy) else "fixed"
            raise ValueError(
                f"min_chunk/max_chunk only apply to the adaptive "
                f"policies, but the chunk policy is {name!r} — drop the "
                f"bounds or pick 'adaptive'/'shard-adaptive' (the fixed "
                f"chunk length is chunk_iters)"
            )
        if isinstance(spec, ChunkPolicy):
            _check_positive_int(
                "chunk_iters", chunk_iters, f"{spec.name} policy"
            )
            return spec
        return FixedChunkPolicy(chunk_iters)
    if spec in ("adaptive", "shard-adaptive"):
        # Validate chunk_iters BEFORE deriving the default upper bound
        # from it, so a bad chunk_iters is blamed on chunk_iters — not
        # on a max_chunk value the caller never passed.
        _check_positive_int("chunk_iters", chunk_iters, f"{spec} policy")
        lo = 1 if min_chunk is None else min_chunk
        hi = 4 * chunk_iters if max_chunk is None else max_chunk
        return _POLICIES[spec](lo, hi, default_chunk=chunk_iters)
    raise ValueError(
        f"unknown chunk policy {spec!r} (expected one of "
        f"{sorted(_POLICIES)} or a ChunkPolicy instance)"
    )


# -- scheduler trace ---------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RefillPlacement:
    """One refill decision: which ticket landed in which slot, and the
    device that owns that slot's shard."""

    ticket: int
    slot: int
    device: int


@dataclasses.dataclass
class ChunkDecision:
    """One scheduling decision (one dispatched chunk) and its outcome.

    ``observation``/``chunk``/``refills`` are written when the chunk is
    dispatched; ``consumed`` (per-bucket-row iterations actually
    executed) and ``wasted`` are filled in after the chunk returns.
    ``wasted`` counts slot-iterations live rows idled inside the chunk:
    rows that retired (or froze) before the chunk's last executed
    iteration sat on capacity a shorter chunk would have freed."""

    step: int
    key: Any
    policy: str
    bucket: int
    observation: ChunkObservation
    chunk: int
    refills: tuple[RefillPlacement, ...] = ()
    live_slots: tuple[int, ...] = ()
    consumed: tuple[int, ...] = ()
    wasted: int = 0


def wasted_iterations(
    consumed: Sequence[int], live_slots: Sequence[int]
) -> int:
    """Slot-iterations wasted by one chunk: the chunk ran for
    ``max(consumed)`` iterations (rows still active at the end consumed
    every one of them), so each live row that stopped earlier idled for
    the difference.  Rows inactive at dispatch (consumed == 0) never
    entered the chunk and are not counted; padding rows are excluded by
    passing only live slots."""
    live = [int(consumed[i]) for i in live_slots]
    steps_run = max((c for c in live), default=0)
    return sum(steps_run - c for c in live if c > 0)


class SchedulerTrace:
    """Record of the scheduling decisions of a service (or a
    simulation).  Decisions are pure host data, so the trace is the
    replayable ground truth the harness and the stats counters are
    checked against.

    The record is BOUNDED: only the most recent ``maxlen`` decisions are
    retained (default 4096 — the same kind of cap as the retire-history
    ring buffer), so a long-lived service cannot grow without bound.
    ``summary()``/``replay()`` therefore cover the retained window; the
    cumulative ``ElasticityService.stats`` counters are independent of
    the trimming (pass ``maxlen=None`` for an unbounded record)."""

    def __init__(self, maxlen: int | None = 4096) -> None:
        if maxlen is not None and maxlen < 1:
            raise ValueError(f"trace maxlen must be >= 1, got {maxlen}")
        self.maxlen = maxlen
        self.decisions: list[ChunkDecision] = []

    def append(self, decision: ChunkDecision) -> None:
        self.decisions.append(decision)
        if self.maxlen is not None and len(self.decisions) > self.maxlen:
            del self.decisions[: len(self.decisions) - self.maxlen]

    def clear(self) -> None:
        """Drop recorded decisions (the aggregate service counters are
        cumulative and unaffected) — e.g. between workloads."""
        self.decisions.clear()

    def __len__(self) -> int:
        return len(self.decisions)

    def chunks(self) -> list[int]:
        return [d.chunk for d in self.decisions]

    def replay(self, policy: ChunkPolicy) -> list[int]:
        """Re-derive every chunk choice from the recorded observations.
        A policy is deterministic iff this equals :meth:`chunks` for the
        policy that produced the trace."""
        return [policy.chunk_for(d.observation) for d in self.decisions]

    def summary(self) -> dict:
        """Aggregate scheduler stats, in the same vocabulary as
        ``ElasticityService.stats``: chunks dispatched, mean chosen
        chunk length, wasted slot-iterations, refills placed."""
        n = len(self.decisions)
        return {
            "chunks": n,
            "mean_chunk": (
                float(np.mean([d.chunk for d in self.decisions]))
                if n
                else 0.0
            ),
            "wasted_iters": int(sum(d.wasted for d in self.decisions)),
            "refills": int(sum(len(d.refills) for d in self.decisions)),
        }


# -- solver-free trace simulation --------------------------------------------
def simulate_cadence_trace(policy: ChunkPolicy, trace: dict) -> SchedulerTrace:
    """Drive ``policy`` against a recorded/synthetic cadence trace with
    no solver in the loop — the deterministic scheduler-trace harness.

    ``trace`` is a plain dict (the ``tests/data/sched_traces/*.json``
    format)::

        {
          "bucket": 8,          # fixed slot count of the abstract flight
          "n_devices": 2,       # bucket must be a device multiple
          "requests": [[arrival_step, iters_to_retire], ...]
        }

    The abstract engine mirrors the service's scheduling loop on one
    flight with a fixed bucket: each step retires rows whose recorded
    iterations-to-retire have been consumed (appending the cadence to
    the shared history ring buffer), refills free slots from the arrived
    queue in the policy's placement order, asks the policy for the next
    chunk length, and advances every live row by ``min(chunk, max
    remaining)`` — the same early-exit the compiled ``bpcg`` loop has.
    Rows map to devices in contiguous shards of ``bucket / n_devices``
    rows, matching axis-0 NamedSharding.  Returns the full
    :class:`SchedulerTrace` (decisions, consumed, wasted)."""
    from collections import deque

    bucket = int(trace["bucket"])
    n_devices = int(trace.get("n_devices", 1))
    if bucket < 1 or n_devices < 1 or bucket % n_devices:
        raise ValueError(
            f"trace: bucket ({bucket}) must be a positive multiple of "
            f"n_devices ({n_devices})"
        )
    requests = [
        (int(a), int(need)) for a, need in trace["requests"]
    ]
    for i, (a, need) in enumerate(requests):
        if a < 0 or need < 1:
            raise ValueError(
                f"trace request {i}: arrival_step must be >= 0 and "
                f"iters_to_retire >= 1, got {(a, need)}"
            )
    slot_devices = [s // (bucket // n_devices) for s in range(bucket)]

    # slot -> [ticket, iters_done, iters_to_retire] or None
    slots: list[list[int] | None] = [None] * bucket
    queue = deque(
        (t, a, need) for t, (a, need) in enumerate(requests)
    )
    history: deque[int] = deque(maxlen=HISTORY_LEN)
    out = SchedulerTrace()
    step = 0
    while True:
        # retire
        for s, row in enumerate(slots):
            if row is not None and row[1] >= row[2]:
                history.append(row[2])
                slots[s] = None
        # admit (policy placement over the arrived queue)
        free = [s for s, r in enumerate(slots) if r is None]
        arrived = [q for q in queue if q[1] <= step]
        live_devs = [
            slot_devices[s] for s, r in enumerate(slots) if r is not None
        ]
        order = policy.placement(free, slot_devices, live_devs)
        refills = []
        for (ticket, _, need), s in zip(arrived, order):
            slots[s] = [ticket, 0, need]
            refills.append(
                RefillPlacement(ticket=ticket, slot=s, device=slot_devices[s])
            )
            queue.remove((ticket, _, need))
        live = [s for s, r in enumerate(slots) if r is not None]
        if not live:
            if not queue:
                return out
            step += 1  # idle until the next arrival
            continue
        obs = ChunkObservation(
            live_iters=tuple(slots[s][1] for s in live),
            live_devices=tuple(slot_devices[s] for s in live),
            history=tuple(history),
            bucket=bucket,
            n_devices=n_devices,
        )
        k = policy.chunk_for(obs)
        assert policy.min_chunk <= k <= policy.max_chunk
        steps_run = min(k, max(slots[s][2] - slots[s][1] for s in live))
        consumed = [0] * bucket
        for s in live:
            consumed[s] = min(slots[s][2] - slots[s][1], steps_run)
            slots[s][1] += consumed[s]
        out.append(
            ChunkDecision(
                step=step,
                key="trace",
                policy=policy.name,
                bucket=bucket,
                observation=obs,
                chunk=k,
                refills=tuple(refills),
                live_slots=tuple(live),
                consumed=tuple(consumed),
                wasted=wasted_iterations(consumed, live),
            )
        )
        step += 1
