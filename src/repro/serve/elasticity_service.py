"""Production-shaped batched elasticity solve service.

The solver-side sibling of :class:`repro.serve.engine.ServeEngine`:
requests describing parameterized elasticity scenarios (materials,
traction, tolerance) arrive in a queue, are grouped by *discretization
key* ``(p, n_h_refine, coarse_mesh.shape)``, and each group is solved by
compiled batched GMG-PCG programs
(:class:`repro.solvers.batched.BatchedGMGSolver`).  Two scheduling
policies share the cache and report plumbing:

* **continuous batching** (``submit`` / ``step`` / ``drain``) — the
  production path.  Each in-flight key holds a resumable
  :class:`~repro.solvers.batched.BpcgState`; every ``step`` advances it
  by a bounded chunk of PCG iterations, retires converged rows
  immediately (their :class:`SolveReport`\\ s become drainable), refills
  the freed slots from the queue by resetting *just those state rows*
  (new materials folded into the operators' per-scenario fields in
  place), and admits requests submitted mid-flight.  One slow scenario
  no longer idles a whole generation — exactly the prefill-boundary
  inefficiency continuous batching removes in LM serving engines.

* **generational batching** (``solve``) — drain everything in
  fixed batches; kept for one-shot workloads and as the baseline the
  ``--continuous`` benchmark compares against.

Shared machinery:

* the geometric hierarchy + compiled programs per key live in an LRU
  cache, so repeat traffic skips all setup (the paper's "Prec." phase)
  and retracing entirely;
* **bucketed padding**: batches are padded to the smallest sufficient
  bucket (1/2/4/.../max_batch), not always to ``max_batch``, so one
  compiled step program per ``(key, bucket)`` serves all nearby batch
  sizes and a draining tail of tight-tolerance scenarios shrinks to a
  cheaper program instead of dragging full-width padding along;
* padding rows (zero traction — born converged, 0 iterations) are
  internal: they are never surfaced to callers, and real zero-RHS
  requests are flagged ``born_converged`` so they can't be mistaken
  for a padded slot;
* every request gets a per-request :class:`SolveReport` with its own
  iteration count, convergence flag and residual norm;
* **heterogeneous materials**: ``SolveRequest.materials`` is either an
  attribute -> (lambda, mu) dict or a per-element ``(lam_e, mu_e)``
  array pair on the fine mesh; both are folded into (S, nelem)
  per-element fields on admission, so dict and array requests batch
  together, share compiled programs, and participate equally in
  prep-row reuse (keyed on a content digest of the folded fields);
* **scenario sharding**: with ``mesh`` set (a 1-D jax.sharding mesh over
  the scenario axis, or an int = "first n devices"), every compiled
  solver shards the batch rows across devices.  Buckets are rounded up
  to a multiple of the device count with born-converged padding rows, so
  the host-side retire/refill logic runs unchanged — ``step()`` fetches
  the (S,) convergence vectors of a sharded state exactly as before
  (jax gathers them transparently), and device-padding rows are never
  surfaced.  ``SolveReport.padded_rows`` records the compiled program's
  total row count so throughput accounting can exclude padding;
* **chunk scheduling**: how many PCG iterations each continuous chunk
  runs (and which free slot a refill lands in) is delegated to a
  :class:`~repro.serve.chunk_policy.ChunkPolicy` — ``fixed`` (the
  default, today's constant ``chunk_iters``), ``adaptive`` (chunk to
  the retire cadence observed in the flight's history ring buffer) or
  ``shard-adaptive`` (cadence per device + refills placed on the
  least-loaded shard).  Policies NEVER change numerics — any policy
  produces the same iteration counts, flags and (to machine precision)
  solutions as ``fixed``, bitwise so when its decisions coincide; only
  *when* rows retire/refill differs.  Every decision is recorded in
  ``ElasticityService.trace`` (a replayable
  :class:`~repro.serve.chunk_policy.SchedulerTrace`), and ``stats``
  carries the scheduler counters (``chunks``, ``chunk_iters_dispatched``,
  ``wasted_iters``, ``refills``);
* **observability**: every counter lives on a typed
  :class:`repro.obs.metrics.MetricsRegistry` (labeled by
  ``(p, refine, policy, devices)``; ``stats`` is a read-only legacy
  view), request latency and queue wait feed registry histograms
  (``latency_summary()`` reports the merged quantiles), and attaching a
  :class:`repro.obs.spans.SpanRecorder` (``attach_spans``) records the
  full request lifecycle — submit→admit→prep→chunk*→retire — with
  device-fenced per-chunk timing, exportable as a Chrome trace and a
  JSON-lines event log.  The service clock is injectable for
  deterministic tests.  Catalog: ``docs/OBSERVABILITY.md``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from collections.abc import Mapping
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import (
    MATERIALS_BEAM,
    check_material_dict,
    check_material_fields,
)
from repro.core.precision import PrecisionPolicy, resolve_precision
from repro.distributed.sharding import scenario_row_devices
from repro.fem.mesh import HexMesh, beam_hex
from repro.serve.chunk_policy import (
    HISTORY_LEN,
    ChunkDecision,
    ChunkObservation,
    RefillPlacement,
    SchedulerTrace,
    make_chunk_policy,
    wasted_iterations,
)
from repro.obs.metrics import MetricsRegistry
from repro.solvers.batched import BatchedGMGSolver, BpcgState

__all__ = ["SolveRequest", "SolveReport", "ElasticityService"]

# Help text for the service counter families.  The keys double as the
# legacy ``ElasticityService.stats`` vocabulary: each maps to the
# ``service_<key>_total`` counter family on the registry, labeled by
# (p, refine, policy, devices).
_STAT_HELP = {
    "cache_hits": "Solver LRU cache hits.",
    "cache_misses": "Solver LRU cache misses (hierarchy + program builds).",
    "generations": "Generational batches solved.",
    "chunks": "Continuous chunks dispatched.",
    "chunk_iters_dispatched": "PCG iterations dispatched across chunks.",
    "wasted_iters": "Dispatched slot-iterations no live row consumed.",
    "refills": "Freed slots refilled from the queue.",
    "rebuckets": "In-flight state re-bucketings.",
    "prep_calls": "prepare() calls (power iterations + refactorization).",
    "prep_row_copies": "Prep rows reused via content-digest match.",
    "precision_fallbacks": (
        "Rows a reduced-precision flight re-queued onto the f64 path "
        "after stagnation detection."
    ),
    # Recovery subsystem (repro.serve.recovery + attach_watchdog).
    # These are labeled (policy, devices) only — a checkpoint/restore
    # spans every flight key and a watchdog fire has none.
    "checkpoints_written": (
        "Recovery checkpoints committed to disk (atomic renames)."
    ),
    "restores": "Service restores from a recovery checkpoint.",
    "watchdog_fires": "step() calls the watchdog flagged past timeout.",
}


class _StatsView(Mapping):
    """Read-only legacy view of the service counters.

    ``ElasticityService.stats`` used to be a plain dict of ints; it is
    now this Mapping over the metrics registry — same keys, same int
    values (each key summed across every (p, refine, policy, devices)
    label set), so ``svc.stats["chunks"]`` and ``dict(svc.stats)`` read
    exactly as before.  Writes go through the registry, never here."""

    _KEYS = tuple(_STAT_HELP)

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry

    def __getitem__(self, key: str) -> int:
        if key not in self._KEYS:
            raise KeyError(key)
        return int(self._registry.total(f"service_{key}_total"))

    def __iter__(self):
        return iter(self._KEYS)

    def __len__(self) -> int:
        return len(self._KEYS)

    def __repr__(self) -> str:
        return repr(dict(self))


@dataclasses.dataclass
class SolveRequest:
    """One parameterized beam-benchmark scenario.

    ``materials`` accepts two forms (``None`` = the paper's beam
    materials):

    * an attribute -> (lambda, mu) dict — piecewise-constant by mesh
      attribute, e.g. ``{1: (50.0, 50.0), 2: (1.0, 1.0)}``;
    * a ``(lam_e, mu_e)`` pair of per-element coefficient arrays, each
      of shape (nelem_fine,) where ``nelem_fine =
      coarse_mesh.nelem * 8**refine`` — one (lambda, mu) per element of
      the *fine* (solve) mesh, enabling graded / composite /
      random-field scenarios.  Coarser GMG levels see the field through
      an exact descendant average, so a piecewise-constant array
      reproduces the equivalent dict request bit-for-bit.

    Both forms are validated at ``submit()`` (coverage/positivity for
    dicts; shape/positivity per element for arrays) so invalid requests
    fail before any batch state is touched.  ``rel_tol`` is the
    MFEM-style relative residual tolerance; ``keep_solution`` attaches
    the (nscalar, 3) solution vector to the report.

    ``precision`` selects the request's
    :class:`~repro.core.precision.PrecisionPolicy` by name (``"f64"``,
    ``"f32"``, ``"mixed"``, ``"mixed-bf16"``); ``None`` inherits the
    service default.  The resolved policy participates in the
    compile-cache/flight key — requests of different policies never
    share a compiled program — and is recorded on the report.  Rows a
    reduced-precision flight flags as stagnated are automatically
    re-queued (same ticket, original submit time) onto the ``f64``
    path; their reports carry ``fallback=True``."""

    p: int = 2
    refine: int = 1
    materials: dict[int, tuple[float, float]] | tuple[Any, Any] | None = None
    traction: tuple[float, float, float] = (0.0, 0.0, -1e-2)
    rel_tol: float = 1e-6
    coarse_mesh: HexMesh | None = None
    keep_solution: bool = False
    precision: str | None = None


def _req_materials(req: SolveRequest):
    """The request's materials with the beam default applied."""
    return req.materials if req.materials is not None else MATERIALS_BEAM


def _material_digest(
    lam_row: np.ndarray, mu_row: np.ndarray, precision: str = "f64"
) -> bytes:
    """Content digest of one folded (lam_e, mu_e) row pair.  The
    continuous engine keys prep-row reuse on this digest: two rows with
    equal digests carry bitwise-equal per-element fields (verified
    against the snapshot on match), so heterogeneous-field requests
    short-circuit power iterations exactly like repeated dicts.  The
    precision-policy name is folded in — prep computed at one policy's
    dtypes (f32 weighted fields, f32 Cholesky) is not the same derived
    data as another's, even for identical materials."""
    h = hashlib.blake2b(digest_size=16)
    h.update(precision.encode())
    h.update(np.ascontiguousarray(lam_row))
    h.update(np.ascontiguousarray(mu_row))
    return h.digest()


@dataclasses.dataclass
class SolveReport:
    """Per-request outcome (one row of a batched solve).

    ``generation`` is the generation index for the generational path and
    the retiring chunk index for the continuous path; ``batch_size`` is
    the number of live (non-padding) rows sharing the program when this
    request finished; ``t_solve`` is the generation's device time for
    the generational path and the request's admission-to-retirement
    latency for the continuous path."""

    request: SolveRequest
    key: tuple
    iterations: int
    converged: bool
    final_rel_norm: float
    ndof: int
    batch_size: int  # live scenarios in this batch (excl. padding)
    generation: int  # generation index / retiring chunk index
    cache_hit: bool  # hierarchy + compiled solve came from the LRU cache
    t_setup: float  # seconds building the solver program (0 on cache hit)
    t_solve: float  # see class docstring
    born_converged: bool = False  # zero RHS: converged before iteration 1
    # Total rows of the compiled program this request rode in, INCLUDING
    # bucket/device padding (batch_size counts only real requests).
    # Honest throughput math divides real requests — never padded_rows —
    # by wall-clock.
    padded_rows: int = 0
    # Precision policy the FINISHING solve ran under; ``fallback`` marks
    # a row the reduced-precision pass flagged as stagnated and the
    # service re-solved on the f64 path (precision then reads "f64").
    precision: str = "f64"
    fallback: bool = False
    # The continuous engine's submit() ticket this report answers (-1 on
    # the generational path, which returns reports positionally).  The
    # stable join key for crash/restore differentials: a resumed
    # service's reports carry the same tickets the dead process issued.
    ticket: int = -1
    x: Any = None


@dataclasses.dataclass
class _Slot:
    """A live batch row: which request occupies it and since when.

    ``t_submit`` carries the ticket's enqueue time so retirement can
    attribute queue wait; ``t_compute`` / ``t_padding`` accumulate this
    row's share of device-fenced chunk time and of the padding fraction
    of it (only maintained while a fencing SpanRecorder is attached)."""

    ticket: int
    request: SolveRequest
    t_admit: float
    t_submit: float = 0.0
    t_compute: float = 0.0
    t_padding: float = 0.0


class _Flight:
    """In-flight continuous batch for one discretization key: the
    resumable solver state plus host-side slot bookkeeping."""

    def __init__(self, key, solver, cache_hit, t_setup, tid_base=0):
        self.key = key
        self.solver = solver
        self.cache_hit = cache_hit
        self.t_setup = t_setup
        # Chrome-trace track block: the flight's prep/chunk spans go on
        # ``tid_base``; slot i's queue_wait/solve spans on tid_base+1+i.
        self.tid_base = tid_base
        self.bucket = 0
        self.slots: list[_Slot | None] = []
        # Folded (bucket, nelem_fine) per-element material fields —
        # attribute dicts are expanded on admission, so dict and array
        # requests are indistinguishable from here down.
        ne = solver.fine_space.nelem
        self.lam = np.zeros((0, ne))
        self.mu = np.zeros((0, ne))
        self.mat_digest = np.zeros((0,), dtype=object)
        self.tr = np.zeros((0, 3))
        self.tol = np.zeros((0,))
        self.state: BpcgState | None = None
        self.prep: dict | None = None
        # Materials each prep row was computed for (prep_valid rows
        # only), as a content digest + field snapshot.  Kept separately
        # from lam/mu — a retiring row's prep stays valid for its OLD
        # materials until overwritten, so it can donate its derived data
        # to a refill with a matching config.
        self.prep_valid = np.zeros((0,), dtype=bool)
        self.prep_digest = np.zeros((0,), dtype=object)
        self.prep_lam = np.zeros((0, ne))
        self.prep_mu = np.zeros((0, ne))
        self.pending_reset: np.ndarray | None = None
        self.chunks = 0
        # Scheduling state the chunk policies feed on, all host-side:
        # a ring buffer of recent retire cadences (iterations at
        # retirement) and a per-row iteration mirror maintained from the
        # consumed vectors run_chunk returns (reset rows go back to 0),
        # so building a ChunkObservation costs no device fetch.  The
        # consumed vector of the last dispatched chunk stays on device
        # (pending_consumed) until the next retire pass — which fetches
        # state anyway — so the policy adds no extra mid-flight syncs;
        # last_decision is the trace record awaiting that outcome.
        self.retire_history: deque[int] = deque(maxlen=HISTORY_LEN)
        self.row_iters = np.zeros((0,), dtype=np.int64)
        self.pending_refills: tuple[RefillPlacement, ...] = ()
        self.pending_consumed: Any = None
        self.last_decision: ChunkDecision | None = None

    def live_rows(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]


class ElasticityService:
    """Queue + LRU-cached compiled solvers + continuous/generational
    batching."""

    def __init__(
        self,
        *,
        max_batch: int = 8,
        cache_size: int = 4,
        assembly: str = "paop",
        dtype=None,
        precision: str | PrecisionPolicy | None = None,
        maxiter: int = 200,
        pallas_interpret: bool | None = None,
        pallas_lane: str | None = None,
        chunk_iters: int = 8,
        chunk_policy=None,
        min_chunk: int | None = None,
        max_chunk: int | None = None,
        mesh=None,
        registry: MetricsRegistry | None = None,
        spans=None,
        clock=time.perf_counter,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.assembly = assembly
        # Service-default precision policy (requests override per row
        # via SolveRequest.precision).  ``dtype`` is the legacy uniform
        # spelling; ``self.dtype`` stays the resolved solve dtype.
        self.precision = resolve_precision(precision, dtype)
        self.dtype = self.precision.solve_dtype
        self.maxiter = maxiter
        # Pallas lane for every solver this service builds, resolved at
        # construction ("compiled" or "interpret"; "auto" — the default
        # — picks compiled when the backend can lower Pallas and falls
        # back to interpret otherwise).  ``pallas_interpret`` is the
        # legacy bool spelling: True pins the interpreter.  The resolved
        # value is the service's report of which lane actually runs.
        from repro.kernels.pa_elasticity.ops import resolve_lane

        self.pallas_lane = resolve_lane(pallas_lane, interpret=pallas_interpret)
        self.pallas_interpret = self.pallas_lane == "interpret"
        self.chunk_iters = chunk_iters
        # Chunk scheduling policy for the continuous path.  The old
        # ``chunk_iters < 1`` check generalizes to the policy-bound
        # validation inside make_chunk_policy (min_chunk <= max_chunk,
        # both >= 1), so a bad bound fails HERE with a message naming
        # the offending parameter, not mid-flight.
        self.chunk_policy = make_chunk_policy(
            chunk_policy,
            chunk_iters=chunk_iters,
            min_chunk=min_chunk,
            max_chunk=max_chunk,
        )
        # Replayable record of recent scheduling decisions, bounded to
        # the last 4096 (see repro.serve.chunk_policy.SchedulerTrace);
        # the cumulative stats counters don't depend on the trimming.
        self.trace = SchedulerTrace()
        self._step_index = 0
        # Scenario-axis device mesh shared by every solver this service
        # builds (int = "first n devices"); see repro.distributed.sharding.
        from repro.distributed.sharding import normalize_scenario_mesh

        self.mesh, self.n_shards = normalize_scenario_mesh(mesh)
        self._solvers: OrderedDict[tuple, BatchedGMGSolver] = OrderedDict()
        self._queue: list[tuple[int, SolveRequest]] = []
        self._flights: dict[tuple, _Flight] = {}
        self._completed: dict[int, SolveReport] = {}
        # Tickets the continuous engine re-queued onto the f64 path
        # after a reduced-precision flight flagged them as stagnated;
        # their eventual reports carry fallback=True.
        self._fallback_tickets: set[int] = set()
        self._next_ticket = 0
        # Observability: every counter the service used to keep in a
        # plain ``stats`` dict now lives on a typed metrics registry,
        # labeled by (p, refine, policy, devices); ``stats`` is a
        # read-only view so existing readers see the same keys/values.
        # ``clock`` is injectable for deterministic span/latency tests.
        self.clock = clock
        self.registry = (
            registry if registry is not None else MetricsRegistry()
        )
        self.stats = _StatsView(self.registry)
        self.spans = None
        self.watchdog = None
        self._t_submit: dict[int, float] = {}
        self._next_flight_idx = 0
        if spans is not None:
            self.attach_spans(spans)

    # -- observability -------------------------------------------------------
    def attach_watchdog(self, timeout_s: float, on_timeout=None):
        """Arm a :class:`repro.distributed.elastic.StepWatchdog` as a
        hang detector on ``step()``: a step exceeding ``timeout_s``
        increments the ``watchdog_fires`` counter (labeled policy/
        devices) and emits a ``watchdog_fire`` span on the engine track,
        then calls ``on_timeout(elapsed_s)`` if given (escalation hook —
        at pod scale, evicting the straggler).  Returns the watchdog so
        callers can read ``timeouts``/``slowest``."""
        from repro.distributed.elastic import StepWatchdog

        def fire(elapsed: float) -> None:
            self.registry.counter(
                "service_watchdog_fires_total",
                _STAT_HELP["watchdog_fires"],
                policy=self.chunk_policy.name,
                devices=self.n_shards,
            ).inc()
            if self.spans is not None:
                t = self.clock()
                self.spans.emit(
                    "watchdog_fire", cat="engine", tid=0, start=t, end=t,
                    elapsed_s=elapsed, step=self._step_index,
                )
            if on_timeout is not None:
                on_timeout(elapsed)

        self.watchdog = StepWatchdog(timeout_s, on_timeout=fire)
        return self.watchdog

    def attach_spans(self, recorder) -> None:
        """Install a :class:`repro.obs.spans.SpanRecorder`.  With
        ``recorder.fence`` set, every continuous chunk is fenced with
        ``jax.block_until_ready`` on the returned state — separating
        host dispatch from device compute WITHOUT fetching the deferred
        consumed vector (fencing waits; the fetch still rides the next
        retire pass).  With no recorder attached the service adds no
        fences and no per-chunk timing at all."""
        self.spans = recorder
        recorder.thread_name(0, "engine")

    def _labels(self, key: tuple) -> dict:
        """The uniform service label set for a flight key."""
        return {
            "p": key[0],
            "refine": key[1],
            "policy": self.chunk_policy.name,
            "devices": self.n_shards,
            "precision": key[-1],
        }

    def _inc(self, stat: str, key: tuple, n: int = 1) -> None:
        self.registry.counter(
            f"service_{stat}_total", _STAT_HELP[stat], **self._labels(key)
        ).inc(n)

    def _observe(self, name: str, help: str, key: tuple, v: float) -> None:
        self.registry.histogram(name, help, **self._labels(key)).observe(v)

    def latency_summary(
        self, qs: tuple[float, ...] = (0.5, 0.9, 0.99)
    ) -> dict[str, float]:
        """Request-latency quantiles merged across every label set —
        the one percentile implementation the benchmark and the CLI
        summary both report (empty dict before any request finished)."""
        h = self.registry.merged_histogram("request_latency_seconds")
        if h is None or h.count == 0:
            return {}
        out = {f"p{round(q * 100):02d}": h.quantile(q) for q in qs}
        out["mean"] = h.sum / h.count
        out["count"] = float(h.count)
        return out

    # -- queue ---------------------------------------------------------------
    def _policy_for(self, req: SolveRequest) -> PrecisionPolicy:
        """The request's resolved precision policy (service default when
        the request doesn't name one)."""
        if req.precision is None:
            return self.precision
        return resolve_precision(req.precision)

    def group_key(self, req: SolveRequest) -> tuple:
        """Flight/compile-cache key.  Leads with (p, refine, shape) but
        also covers everything else a compiled program is specialized
        on — lengths, attribute layout, the affine map, and (last) the
        resolved precision-policy name: two meshes of equal shape but
        different geometry never share a solver, and neither do two
        policies (their programs differ in every dtype)."""
        mesh = req.coarse_mesh if req.coarse_mesh is not None else beam_hex()
        lm = mesh.linear_map
        return (
            req.p,
            req.refine,
            mesh.shape,
            mesh.lengths,
            tuple(int(a) for a in mesh.attributes()),
            None if lm is None else tuple(map(tuple, np.asarray(lm).tolist())),
            self._policy_for(req).name,
        )

    def submit(self, request: SolveRequest) -> int:
        """Non-blocking intake: enqueue a request and return its ticket.

        Safe to call while flights are mid-chunk — the next ``step``
        admits it into the first free slot of its discretization key.
        Invalid requests fail HERE, before any batch state is touched:
        attribute dicts must cover every mesh attribute with positive
        coefficients, and per-element ``(lam_e, mu_e)`` array pairs must
        have shape (nelem_fine,) = (coarse_mesh.nelem * 8**refine,) with
        every entry positive.  Error messages name the offending
        attribute / element index and the expected shape."""
        if request.materials is not None:
            mesh = (
                request.coarse_mesh
                if request.coarse_mesh is not None
                else beam_hex()
            )
            m = request.materials
            if isinstance(m, dict):
                check_material_dict(
                    m, mesh.attributes(), where="request materials"
                )
            else:
                try:
                    lam_e, mu_e = m
                except (TypeError, ValueError):
                    raise TypeError(
                        f"request materials: expected an attribute->"
                        f"(lambda, mu) dict or a (lam_e, mu_e) array "
                        f"pair, got {type(m).__name__!r}"
                    ) from None
                nelem_fine = mesh.nelem * 8**request.refine
                check_material_fields(
                    lam_e,
                    mu_e,
                    nelem_fine,
                    where=(
                        f"request materials (p={request.p}, "
                        f"refine={request.refine}, coarse mesh "
                        f"{mesh.shape})"
                    ),
                )
        self._policy_for(request)  # unknown precision names fail at intake
        ticket = self._next_ticket
        self._next_ticket += 1
        self._t_submit[ticket] = self.clock()
        self._queue.append((ticket, request))
        return ticket

    def bucket_for(self, n: int) -> int:
        """Smallest padding bucket (1/2/4/.../max_batch) holding n rows,
        rounded up to a multiple of the scenario-mesh device count (the
        sharded axis must divide the mesh; the extra rows are
        born-converged padding and are never surfaced)."""
        b = 1
        while b < n and b < self.max_batch:
            b *= 2
        b = min(b, self.max_batch)
        m = self.n_shards
        return -(-b // m) * m

    # -- cache ---------------------------------------------------------------
    def _solver_for(self, key: tuple, req: SolveRequest):
        """(solver, cache_hit, t_setup) for a discretization key."""
        if key in self._solvers:
            self._solvers.move_to_end(key)
            self._inc("cache_hits", key)
            return self._solvers[key], True, 0.0
        t0 = self.clock()
        cmesh = req.coarse_mesh if req.coarse_mesh is not None else beam_hex()
        solver = BatchedGMGSolver(
            cmesh,
            req.refine,
            req.p,
            assembly=self.assembly,
            precision=self._policy_for(req),
            maxiter=self.maxiter,
            pallas_lane=self.pallas_lane,
            mesh=self.mesh,
        )
        self._solvers[key] = solver
        self._inc("cache_misses", key)
        while len(self._solvers) > self.cache_size:
            evicted, _ = self._solvers.popitem(last=False)  # LRU eviction
            if evicted in self._flights:
                # Never evict a solver with rows in flight: reinsert it as
                # most-recently-used and drop the next-oldest idle entry.
                self._solvers[evicted] = self._flights[evicted].solver
                self._solvers.move_to_end(evicted, last=False)
                for k in list(self._solvers):
                    if k not in self._flights:
                        del self._solvers[k]
                        break
        return solver, False, self.clock() - t0

    # -- continuous batching -------------------------------------------------
    def step(self) -> int:
        """Advance the continuous engine by one bounded chunk per
        in-flight discretization key: retire converged rows (their
        reports become drainable), refill freed slots from the queue,
        admit mid-flight submissions, and re-bucket each step program to
        the smallest sufficient batch size.  The chunk length (and, for
        the shard-adaptive policy, the refill placement) comes from
        ``self.chunk_policy``; every flight with live rows dispatches
        exactly one chunk per step — no flight is ever starved — and
        every decision lands in ``self.trace``.  Returns the number of
        requests completed by this step.

        With a watchdog attached (:meth:`attach_watchdog`) the whole
        step body runs under its monitor: a step that exceeds the
        timeout — a wedged device, a pathological compile — fires the
        ``watchdog_fires`` counter and a span without interrupting the
        step itself (detection, not preemption; escalation is the
        callback's job)."""
        if self.watchdog is not None:
            with self.watchdog.step():
                return self._step_body()
        return self._step_body()

    def _step_body(self) -> int:
        self._step_index += 1
        rec = self.spans
        t_step0 = self.clock() if rec is not None else 0.0
        done_before = len(self._completed)
        qgroups: OrderedDict[tuple, list[tuple[int, SolveRequest]]] = (
            OrderedDict()
        )
        for t, req in self._queue:
            qgroups.setdefault(self.group_key(req), []).append((t, req))
        keys = list(self._flights)
        keys += [k for k in qgroups if k not in self._flights]
        admitted: set[int] = set()
        for key in keys:
            flight = self._flights.get(key)
            queued = qgroups.get(key, [])
            if flight is None:
                solver, hit, t_setup = self._solver_for(key, queued[0][1])
                flight = _Flight(
                    key, solver, hit, t_setup, tid_base=self._flight_tid()
                )
                self._flights[key] = flight
                if rec is not None:
                    rec.thread_name(
                        flight.tid_base,
                        f"flight p={key[0]} refine={key[1]}",
                    )
            self._retire(flight)
            if not flight.live_rows() and not queued:
                del self._flights[key]
                continue
            admitted |= self._admit(flight, queued)
            if flight.live_rows():
                self._launch_chunk(flight)
            else:
                del self._flights[key]
        if admitted:
            self._queue = [
                (t, r) for t, r in self._queue if t not in admitted
            ]
        completed = len(self._completed) - done_before
        if rec is not None:
            rec.emit(
                "step",
                cat="engine",
                tid=0,
                start=t_step0,
                end=self.clock(),
                step=self._step_index,
                completed=completed,
            )
        return completed

    def _flight_tid(self) -> int:
        """Next flight's Chrome-trace track block: tid 0 is the engine;
        each flight takes a block of consecutive tids (the flight track
        plus one per possible slot, slots bounded by the device-aligned
        bucket, which may exceed max_batch by up to n_shards-1)."""
        idx = self._next_flight_idx
        self._next_flight_idx += 1
        return 1 + idx * (self.max_batch + self.n_shards + 1)

    def idle(self) -> bool:
        """True when no requests are queued or in flight."""
        return not self._queue and not self._flights

    def run_until_idle(self, max_steps: int = 1_000_000) -> None:
        """Drive ``step`` until every submitted request has completed."""
        steps = 0
        while not self.idle():
            self.step()
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(
                    f"continuous engine did not drain in {max_steps} steps"
                )

    def drain(self) -> list[SolveReport]:
        """Non-blocking: pop every completed report (submission order).
        Pairs with ``submit`` — what's still in flight stays in flight;
        a report is never yielded twice, and padding/device-alignment
        rows never appear here at all."""
        out = [self._completed.pop(t) for t in sorted(self._completed)]
        return out

    def solve_continuous(
        self, requests: list[SolveRequest]
    ) -> list[SolveReport]:
        """Submit ``requests``, run the continuous engine until idle, and
        return their reports in submission order (other tickets, if any,
        stay drainable)."""
        tickets = [self.submit(r) for r in requests]
        self.run_until_idle()
        return [self._completed.pop(t) for t in tickets]

    def _finalize_chunk(self, flight: _Flight) -> None:
        """Fold the last chunk's consumed vector into the host-side
        scheduling state: advance the per-row iteration mirror and patch
        the awaiting trace record (consumed, wasted slot-iterations).
        Runs at the retire pass — the first point the host touches the
        device state anyway — so the policy costs no extra syncs."""
        if flight.pending_consumed is None:
            return
        consumed = np.asarray(flight.pending_consumed)
        flight.pending_consumed = None
        flight.row_iters += consumed.astype(np.int64)
        d = flight.last_decision
        flight.last_decision = None
        if d is not None:
            d.consumed = tuple(int(c) for c in consumed)
            d.wasted = wasted_iterations(consumed, d.live_slots)
            self._inc("wasted_iters", flight.key, d.wasted)

    def _retire(self, flight: _Flight) -> None:
        """Emit reports for rows that stopped iterating (converged or hit
        maxiter) during the previous chunk and free their slots,
        recording each real row's retire cadence in the flight's history
        ring buffer (the adaptive policies' signal)."""
        self._finalize_chunk(flight)
        if flight.chunks == 0 or flight.state is None:
            return
        active = np.asarray(flight.state.active)
        nom = np.asarray(flight.state.nom)
        nom0 = np.asarray(flight.state.nom0)
        thr = np.asarray(flight.state.threshold)
        iters = np.asarray(flight.state.iters)
        stalled = np.asarray(flight.state.stalled)
        reduced = resolve_precision(flight.key[-1]).reduced
        live = flight.live_rows()
        ndof = flight.solver.fine_space.ndof
        now = self.clock()
        rec = self.spans
        for i in live:
            if active[i]:
                continue
            slot = flight.slots[i]
            req = slot.request
            converged = bool(nom[i] <= thr[i])
            if reduced and bool(stalled[i]) and not converged:
                # Stagnated under the reduced policy (or failed the true-
                # residual audit): re-queue the SAME ticket onto the f64
                # path with its original submit time, so the fallback is
                # a scheduling event, not a failed report.  The eventual
                # f64 report carries ``fallback=True``.
                self._queue.append(
                    (slot.ticket,
                     dataclasses.replace(req, precision="f64"))
                )
                self._t_submit[slot.ticket] = slot.t_submit
                self._fallback_tickets.add(slot.ticket)
                self._inc("precision_fallbacks", flight.key)
                flight.slots[i] = None
                continue
            rel = (
                float(np.sqrt(nom[i]) / np.sqrt(nom0[i]))
                if nom0[i] > 0
                else 0.0
            )
            wall = now - slot.t_admit
            self._observe(
                "request_latency_seconds",
                "Admission-to-retirement latency per request.",
                flight.key,
                wall,
            )
            if rec is not None:
                # Lifecycle identity per ticket: queue_wait + compute +
                # overhead == submit-to-retire wall, exactly (compute is
                # this row's share of device-fenced chunk time; overhead
                # is everything else — host scheduling, dispatch,
                # retire/refill bookkeeping).
                rec.emit(
                    "solve",
                    cat="request",
                    tid=flight.tid_base + 1 + i,
                    start=slot.t_admit,
                    end=now,
                    ticket=slot.ticket,
                    iterations=int(iters[i]),
                    converged=converged,
                    queue_wait=slot.t_admit - slot.t_submit,
                    compute=slot.t_compute,
                    overhead=wall - slot.t_compute,
                    padding_overhead=slot.t_padding,
                )
            fell_back = slot.ticket in self._fallback_tickets
            self._fallback_tickets.discard(slot.ticket)
            self._completed[slot.ticket] = SolveReport(
                request=req,
                key=flight.key,
                iterations=int(iters[i]),
                converged=converged,
                final_rel_norm=rel,
                ndof=ndof,
                batch_size=len(live),
                generation=flight.chunks - 1,
                cache_hit=flight.cache_hit,
                t_setup=flight.t_setup,
                t_solve=now - slot.t_admit,
                born_converged=bool(
                    iters[i] == 0 and converged and nom0[i] == 0
                ),
                padded_rows=flight.bucket,
                precision=flight.key[-1],
                fallback=fell_back,
                ticket=slot.ticket,
                x=np.asarray(flight.state.x[i])
                if req.keep_solution
                else None,
            )
            flight.slots[i] = None
            # Retire cadence for the policies: total iterations this row
            # ran before retiring.  Born-converged rows (0 iterations)
            # teach nothing about cadence and are skipped.
            if iters[i] > 0:
                flight.retire_history.append(int(iters[i]))

    def _admit(
        self, flight: _Flight, queued: list[tuple[int, SolveRequest]]
    ) -> set[int]:
        """Refill free slots from the queue, re-bucketing the pinned
        state to the smallest sufficient batch size first.  Returns the
        admitted tickets; leaves ``flight.pending_reset`` marking every
        row the next chunk must (re)initialize."""
        solver = flight.solver
        live = flight.live_rows()
        n_live = len(live)
        take = queued[: self.max_batch - n_live]
        bucket = self.bucket_for(max(n_live + len(take), 1))

        if flight.state is None:
            flight.state = solver.empty_state(bucket)
            flight.prep = solver.empty_prep(bucket)
            flight.slots = [None] * bucket
            ne = solver.fine_space.nelem
            flight.lam = np.zeros((bucket, ne))
            flight.mu = np.zeros((bucket, ne))
            flight.mat_digest = np.zeros((bucket,), dtype=object)
            flight.tr = np.zeros((bucket, 3))
            flight.tol = np.full((bucket,), 1e-6)
            flight.prep_valid = np.zeros((bucket,), dtype=bool)
            flight.prep_digest = np.zeros((bucket,), dtype=object)
            flight.prep_lam = np.zeros((bucket, ne))
            flight.prep_mu = np.zeros((bucket, ne))
            flight.row_iters = np.zeros((bucket,), dtype=np.int64)
            flight.bucket = bucket
            reset = np.ones((bucket,), dtype=bool)
        elif bucket != flight.bucket:
            # Re-bucket: keep live rows (bitwise), fill the rest with
            # placeholder copies of an existing row — every placeholder
            # is reset below before the next chunk reads it.
            filler = live[0] if live else 0
            rows = live + [filler] * (bucket - n_live)
            flight.state, flight.prep = solver.take_rows(
                flight.state, flight.prep, rows
            )
            flight.slots = [flight.slots[i] for i in live] + [None] * (
                bucket - n_live
            )
            idx = np.asarray(rows)
            flight.lam = flight.lam[idx]
            flight.mu = flight.mu[idx]
            flight.mat_digest = flight.mat_digest[idx]
            flight.tr = flight.tr[idx]
            flight.tol = flight.tol[idx]
            flight.prep_valid = flight.prep_valid[idx]
            flight.prep_digest = flight.prep_digest[idx]
            flight.prep_lam = flight.prep_lam[idx]
            flight.prep_mu = flight.prep_mu[idx]
            flight.row_iters = flight.row_iters[idx]
            flight.bucket = bucket
            reset = np.zeros((bucket,), dtype=bool)
            reset[n_live:] = True
            self._inc("rebuckets", flight.key)
        else:
            reset = np.zeros((bucket,), dtype=bool)
        if (
            flight.pending_reset is not None
            and len(flight.pending_reset) == bucket
        ):
            # A pre-marked reset from outside the admit cycle — e.g. an
            # elastic restore whose re-bucketed filler rows must be
            # re-initialized before the next chunk reads them.  OR it in
            # rather than overwrite; a re-bucketing above (length
            # mismatch) already resets every non-live row, subsuming it.
            reset |= flight.pending_reset

        admitted: set[int] = set()
        free = [i for i, s in enumerate(flight.slots) if s is None]
        # Refill placement is a policy decision: the default policies
        # fill ascending slot indices (the pre-policy behavior); the
        # shard-adaptive policy targets the least-loaded device so
        # retires drain whole shards as early as possible.  Placement
        # never changes numerics — rows are slot-independent.
        slot_devs = scenario_row_devices(flight.bucket, self.n_shards)
        order = self.chunk_policy.placement(
            free,
            [int(d) for d in slot_devs],
            [int(slot_devs[i]) for i in flight.live_rows()],
        )
        refills: list[RefillPlacement] = []
        now = self.clock()
        rec = self.spans
        for (ticket, req), row in zip(take, order):
            if flight.slots[row] is not None:  # pragma: no cover
                raise AssertionError(f"slot {row} double-assigned")
            t_submit = self._t_submit.pop(ticket, now)
            flight.slots[row] = _Slot(ticket, req, now, t_submit=t_submit)
            self._observe(
                "request_queue_wait_seconds",
                "Submit-to-admission wait per request.",
                flight.key,
                now - t_submit,
            )
            if rec is not None:
                tid = flight.tid_base + 1 + row
                rec.thread_name(tid, f"p={flight.key[0]} slot {row}")
                rec.emit(
                    "queue_wait",
                    cat="request",
                    tid=tid,
                    start=t_submit,
                    end=now,
                    ticket=ticket,
                )
            lam, mu = solver.pack_materials([_req_materials(req)])
            flight.lam[row] = np.asarray(lam[0])
            flight.mu[row] = np.asarray(mu[0])
            flight.mat_digest[row] = _material_digest(
                flight.lam[row], flight.mu[row], precision=flight.key[-1]
            )
            flight.tr[row] = req.traction
            flight.tol[row] = req.rel_tol
            reset[row] = True
            admitted.add(ticket)
            refills.append(
                RefillPlacement(
                    ticket=ticket, slot=row, device=int(slot_devs[row])
                )
            )
            self._inc("refills", flight.key)
        # Padding rows being reset borrow a real row's materials (keeps
        # the batched operators SPD) with a zero traction: b == 0 makes
        # them born-converged, so they cost 0 bpcg iterations and are
        # never surfaced to callers.
        occupied = flight.live_rows()
        if occupied:
            src = occupied[0]
            for row in range(flight.bucket):
                if flight.slots[row] is None and reset[row]:
                    flight.lam[row] = flight.lam[src]
                    flight.mu[row] = flight.mu[src]
                    flight.mat_digest[row] = flight.mat_digest[src]
                    flight.tr[row] = 0.0
                    flight.tol[row] = 1e-6
        flight.pending_reset = reset if reset.any() else None
        flight.pending_refills = tuple(refills)
        return admitted

    def _refresh_prep(self, flight: _Flight, reset: np.ndarray) -> None:
        """Make every reset row's prep match its (new) materials.  Rows
        whose folded per-element fields content-match an already-valid
        row — digest equality first (O(1) per candidate, heterogeneous
        fields included), confirmed bitwise against the snapshot — reuse
        that row's derived data with a cheap device gather (prep depends
        only on materials); only genuinely new material configurations
        pay the ``prepare`` power iterations + refactorization."""
        solver = flight.solver
        rec = self.spans
        t_prep0 = self.clock() if rec is not None else 0.0
        src_rows, dst_rows, unresolved = [], [], []
        sources = [s for s in range(flight.bucket) if flight.prep_valid[s]]
        for r in np.flatnonzero(reset):
            dig = flight.mat_digest[r]
            match = next(
                (
                    s
                    for s in sources
                    if flight.prep_digest[s] == dig
                    and np.array_equal(flight.prep_lam[s], flight.lam[r])
                    and np.array_equal(flight.prep_mu[s], flight.mu[r])
                ),
                None,
            )
            if match is None:
                unresolved.append(int(r))
            else:
                src_rows.append(match)
                dst_rows.append(int(r))
        if dst_rows:
            # copy_prep_rows gathers every source before any destination
            # is written, so a retiring row can donate its old prep even
            # while being refilled itself.
            flight.prep = solver.copy_prep_rows(
                flight.prep, src_rows, dst_rows
            )
            self._inc("prep_row_copies", flight.key, len(dst_rows))
        if unresolved:
            mask = np.zeros((flight.bucket,), dtype=bool)
            mask[unresolved] = True
            flight.prep = solver.prepare(
                jnp.asarray(flight.lam, solver.dtype),
                jnp.asarray(flight.mu, solver.dtype),
                mask,
                flight.prep,
            )
            self._inc("prep_calls", flight.key)
        flight.prep_valid[reset] = True
        flight.prep_digest[reset] = flight.mat_digest[reset]
        flight.prep_lam[reset] = flight.lam[reset]
        flight.prep_mu[reset] = flight.mu[reset]
        if rec is not None:
            rec.emit(
                "prep",
                cat="flight",
                tid=flight.tid_base,
                start=t_prep0,
                end=self.clock(),
                rows_reset=int(reset.sum()),
                rows_copied=len(dst_rows),
                rows_prepared=len(unresolved),
            )

    def _launch_chunk(self, flight: _Flight) -> None:
        """One bounded advance of the flight's compiled step program,
        re-initializing any rows flagged by the last admit.  The chunk
        length comes from the policy's view of the in-flight mix (the
        host-side iteration mirror, the per-device row map and the
        retire-history ring buffer); the decision is appended to
        ``self.trace`` and completed by the next retire pass."""
        solver = flight.solver
        reset = flight.pending_reset
        do_reset = reset is not None
        if do_reset:
            self._refresh_prep(flight, reset)
            flight.row_iters[reset] = 0
        mask = (
            reset if do_reset else np.zeros((flight.bucket,), dtype=bool)
        )
        live = flight.live_rows()
        slot_devs = scenario_row_devices(flight.bucket, self.n_shards)
        obs = ChunkObservation(
            live_iters=tuple(int(flight.row_iters[i]) for i in live),
            live_devices=tuple(int(slot_devs[i]) for i in live),
            history=tuple(flight.retire_history),
            bucket=flight.bucket,
            n_devices=self.n_shards,
        )
        k = self.chunk_policy.chunk_for(obs)
        rec = self.spans
        t0 = self.clock() if rec is not None else 0.0
        flight.state, flight.pending_consumed = solver.run_chunk(
            flight.tr,
            flight.tol,
            mask,
            flight.state,
            flight.prep,
            k,
            do_reset=do_reset,
        )
        if rec is not None:
            t_dispatched = self.clock()
            rec.emit(
                "chunk_dispatch",
                cat="chunk",
                tid=flight.tid_base,
                start=t0,
                end=t_dispatched,
                chunk=k,
                bucket=flight.bucket,
                live=len(live),
            )
            if rec.fence:
                # Fence, don't fetch: block_until_ready waits for the
                # chunk's computation (state AND the consumed vector it
                # shares a program with) without transferring anything —
                # the deferred consumed fetch still happens at the next
                # retire pass, exactly as without instrumentation.
                jax.block_until_ready(flight.state)
                t_done = self.clock()
                dt_dev = t_done - t_dispatched
                rec.emit(
                    "chunk_device",
                    cat="chunk",
                    tid=flight.tid_base,
                    start=t_dispatched,
                    end=t_done,
                    chunk=k,
                    bucket=flight.bucket,
                    live=len(live),
                )
                self._observe(
                    "chunk_device_seconds",
                    "Device-fenced wall time per continuous chunk.",
                    flight.key,
                    dt_dev,
                )
                # Attribute this chunk's device time to the rows that
                # rode it: each live ticket accrues the full chunk wall
                # as compute, plus its per-ticket share of the padding
                # fraction (padded rows / bucket) as padding overhead.
                n_live = len(live)
                pad_share = (
                    dt_dev * (flight.bucket - n_live) / flight.bucket / n_live
                    if n_live
                    else 0.0
                )
                for i in live:
                    flight.slots[i].t_compute += dt_dev
                    flight.slots[i].t_padding += pad_share
        decision = ChunkDecision(
            step=self._step_index,
            key=flight.key,
            policy=self.chunk_policy.name,
            bucket=flight.bucket,
            observation=obs,
            chunk=k,
            refills=flight.pending_refills,
            live_slots=tuple(live),
        )
        self.trace.append(decision)
        flight.last_decision = decision
        flight.pending_refills = ()
        flight.pending_reset = None
        flight.chunks += 1
        self._inc("chunks", flight.key)
        self._inc("chunk_iters_dispatched", flight.key, k)

    # -- generational batching -----------------------------------------------
    def solve(self, requests: list[SolveRequest] | None = None) -> list[SolveReport]:
        """Generational path: drain the queue (plus ``requests``) and
        return one report per request, in submission order.

        Each discretization key's requests are solved in fixed batches
        padded to the smallest sufficient (device-aligned) bucket;
        padding rows are internal and never surfaced.  Materials may be
        attribute dicts or per-element array pairs, mixed freely within
        a batch.  Do not mix with in-flight continuous work — use
        ``solve_continuous`` there."""
        if requests:
            for r in requests:
                self.submit(r)
        pending = [r for _, r in self._queue]
        for t, _ in self._queue:
            self._t_submit.pop(t, None)
        self._queue = []

        # Group by discretization key, preserving submission order.
        groups: OrderedDict[tuple, list[tuple[int, SolveRequest]]] = OrderedDict()
        for i, req in enumerate(pending):
            groups.setdefault(self.group_key(req), []).append((i, req))

        reports: list[SolveReport | None] = [None] * len(pending)
        for key, members in groups.items():
            solver, hit, t_setup = self._solver_for(key, members[0][1])
            for gen, start in enumerate(range(0, len(members), self.max_batch)):
                chunk = members[start : start + self.max_batch]
                gen_reports = self._run_generation(
                    solver, key, chunk, hit or gen > 0, t_setup if gen == 0 else 0.0, gen
                )
                for (i, _), rep in zip(chunk, gen_reports):
                    reports[i] = rep
        return reports  # type: ignore[return-value]

    def _run_generation(
        self,
        solver: BatchedGMGSolver,
        key: tuple,
        chunk: list[tuple[int, SolveRequest]],
        cache_hit: bool,
        t_setup: float,
        generation: int,
    ) -> list[SolveReport]:
        reqs = [r for _, r in chunk]
        n_real = len(reqs)
        # Bucketed padding: the smallest sufficient (device-aligned)
        # bucket, not max_batch, so short generations reuse a cheaper
        # compiled program.  The padding rows themselves (first row's
        # materials, zero traction -> born converged) come from the one
        # shared convention in BatchedGMGSolver.pad_scenarios.
        n_pad = self.bucket_for(n_real) - n_real
        materials, tractions, rel_tols, _ = solver.pad_scenarios(
            [_req_materials(r) for r in reqs],
            [r.traction for r in reqs],
            [r.rel_tol for r in reqs],
            n=n_real + n_pad,
        )

        t0 = self.clock()
        res = solver.solve(materials, tractions, rel_tols)
        x = res.x.block_until_ready()
        t_solve = self.clock() - t0
        self._inc("generations", key)
        for _ in reqs:
            self._observe(
                "request_latency_seconds",
                "Admission-to-retirement latency per request.",
                key,
                t_solve,
            )
        if self.spans is not None:
            self.spans.emit(
                "generation",
                cat="generation",
                tid=0,
                start=t0,
                end=t0 + t_solve,
                generation=generation,
                batch=n_real,
                padded_rows=n_real + n_pad,
            )

        iters = np.asarray(res.iterations)
        conv = np.asarray(res.converged)
        fin = np.asarray(res.final_norm)
        ini = np.asarray(res.initial_norm)
        fell_back = np.asarray(res.fallback)
        ndof = solver.fine_space.ndof
        out = []
        # Padding rows (s >= n_real) are internal and never reported.
        for s, req in enumerate(reqs):
            rel = float(fin[s] / ini[s]) if ini[s] > 0 else 0.0
            if fell_back[s]:
                self._inc("precision_fallbacks", key)
            out.append(
                SolveReport(
                    request=req,
                    key=key,
                    iterations=int(iters[s]),
                    converged=bool(conv[s]),
                    final_rel_norm=rel,
                    ndof=ndof,
                    batch_size=n_real,
                    generation=generation,
                    cache_hit=cache_hit,
                    t_setup=t_setup,
                    t_solve=t_solve,
                    born_converged=bool(iters[s] == 0 and conv[s] and ini[s] == 0),
                    padded_rows=n_real + n_pad,
                    precision=solver.precision.name,
                    fallback=bool(fell_back[s]),
                    x=np.asarray(x[s]) if req.keep_solution else None,
                )
            )
        return out
