"""Production-shaped batched elasticity solve service.

The solver-side sibling of :class:`repro.serve.engine.ServeEngine`:
requests describing parameterized elasticity scenarios (materials,
traction, tolerance) arrive in a queue, are grouped by *discretization
key* ``(p, n_h_refine, coarse_mesh.shape)``, and each group is solved in
generations of up to ``max_batch`` scenarios by ONE compiled batched
GMG-PCG program (:class:`repro.solvers.batched.BatchedGMGSolver`):

* the geometric hierarchy + compiled solve per key live in an LRU cache,
  so the second batch with the same key skips all setup (the paper's
  "Prec." phase) and retracing entirely;
* within a generation, scenarios that converge are retired by the bpcg
  active mask while the rest keep iterating; between generations, slots
  are refilled from the queue (generational continuous batching, exactly
  the engine's prefill-boundary policy);
* short generations are padded to ``max_batch`` with zero-traction rows
  — born converged, 0 iterations — so one program shape serves every
  generation of a key without recompiling;
* every request gets a per-request :class:`SolveReport` with its own
  iteration count, convergence flag and residual norm.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import MATERIALS_BEAM
from repro.fem.mesh import HexMesh, beam_hex
from repro.solvers.batched import BatchedGMGSolver

__all__ = ["SolveRequest", "SolveReport", "ElasticityService"]


@dataclasses.dataclass
class SolveRequest:
    """One parameterized beam-benchmark scenario."""

    p: int = 2
    refine: int = 1
    materials: dict[int, tuple[float, float]] | None = None
    traction: tuple[float, float, float] = (0.0, 0.0, -1e-2)
    rel_tol: float = 1e-6
    coarse_mesh: HexMesh | None = None
    keep_solution: bool = False


@dataclasses.dataclass
class SolveReport:
    """Per-request outcome (one row of a batched generation)."""

    request: SolveRequest
    key: tuple
    iterations: int
    converged: bool
    final_rel_norm: float
    ndof: int
    batch_size: int  # scenarios in this generation (excl. padding)
    generation: int  # generation index within its group
    cache_hit: bool  # hierarchy + compiled solve came from the LRU cache
    t_setup: float  # seconds building the solver program (0 on cache hit)
    t_solve: float  # seconds for this request's generation, shared
    x: Any = None


class ElasticityService:
    """Queue + LRU-cached compiled solvers + generational batching."""

    def __init__(
        self,
        *,
        max_batch: int = 8,
        cache_size: int = 4,
        assembly: str = "paop",
        dtype=jnp.float64,
        maxiter: int = 200,
        pallas_interpret: bool = True,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.assembly = assembly
        self.dtype = dtype
        self.maxiter = maxiter
        self.pallas_interpret = pallas_interpret
        self._solvers: OrderedDict[tuple, BatchedGMGSolver] = OrderedDict()
        self._queue: list[SolveRequest] = []
        self.stats = {"cache_hits": 0, "cache_misses": 0, "generations": 0}

    # -- queue ---------------------------------------------------------------
    @staticmethod
    def group_key(req: SolveRequest) -> tuple:
        """Discretization key.  Leads with (p, refine, shape) but also
        covers everything else a compiled program is specialized on —
        lengths, attribute layout and the affine map — so two meshes of
        equal shape but different geometry never share a solver."""
        mesh = req.coarse_mesh if req.coarse_mesh is not None else beam_hex()
        lm = mesh.linear_map
        return (
            req.p,
            req.refine,
            mesh.shape,
            mesh.lengths,
            tuple(int(a) for a in mesh.attributes()),
            None if lm is None else tuple(map(tuple, np.asarray(lm).tolist())),
        )

    def submit(self, request: SolveRequest) -> None:
        self._queue.append(request)

    # -- cache ---------------------------------------------------------------
    def _solver_for(self, key: tuple, req: SolveRequest):
        """(solver, cache_hit, t_setup) for a discretization key."""
        if key in self._solvers:
            self._solvers.move_to_end(key)
            self.stats["cache_hits"] += 1
            return self._solvers[key], True, 0.0
        t0 = time.perf_counter()
        mesh = req.coarse_mesh if req.coarse_mesh is not None else beam_hex()
        solver = BatchedGMGSolver(
            mesh,
            req.refine,
            req.p,
            assembly=self.assembly,
            dtype=self.dtype,
            maxiter=self.maxiter,
            pallas_interpret=self.pallas_interpret,
        )
        self._solvers[key] = solver
        self.stats["cache_misses"] += 1
        while len(self._solvers) > self.cache_size:
            self._solvers.popitem(last=False)  # evict least-recently-used
        return solver, False, time.perf_counter() - t0

    # -- batched solve -------------------------------------------------------
    def solve(self, requests: list[SolveRequest] | None = None) -> list[SolveReport]:
        """Drain the queue (plus ``requests``) and return one report per
        request, in submission order."""
        if requests:
            for r in requests:
                self.submit(r)
        pending = self._queue
        self._queue = []

        # Group by discretization key, preserving submission order.
        groups: OrderedDict[tuple, list[tuple[int, SolveRequest]]] = OrderedDict()
        for i, req in enumerate(pending):
            groups.setdefault(self.group_key(req), []).append((i, req))

        reports: list[SolveReport | None] = [None] * len(pending)
        for key, members in groups.items():
            solver, hit, t_setup = self._solver_for(key, members[0][1])
            for gen, start in enumerate(range(0, len(members), self.max_batch)):
                chunk = members[start : start + self.max_batch]
                gen_reports = self._run_generation(
                    solver, key, chunk, hit or gen > 0, t_setup if gen == 0 else 0.0, gen
                )
                for (i, _), rep in zip(chunk, gen_reports):
                    reports[i] = rep
        return reports  # type: ignore[return-value]

    def _run_generation(
        self,
        solver: BatchedGMGSolver,
        key: tuple,
        chunk: list[tuple[int, SolveRequest]],
        cache_hit: bool,
        t_setup: float,
        generation: int,
    ) -> list[SolveReport]:
        reqs = [r for _, r in chunk]
        n_real = len(reqs)
        n_pad = self.max_batch - n_real

        materials = [r.materials or MATERIALS_BEAM for r in reqs]
        tractions = np.asarray([r.traction for r in reqs], dtype=np.float64)
        rel_tols = np.asarray([r.rel_tol for r in reqs], dtype=np.float64)
        if n_pad > 0:
            # Padding rows reuse the first scenario's materials (keeps the
            # batched operators SPD) with a zero traction: b == 0 makes
            # them born-converged, so they cost 0 bpcg iterations.
            materials += [materials[0]] * n_pad
            tractions = np.concatenate(
                [tractions, np.zeros((n_pad, 3))], axis=0
            )
            rel_tols = np.concatenate([rel_tols, np.full(n_pad, 1e-6)])

        t0 = time.perf_counter()
        res = solver.solve(materials, tractions, rel_tols)
        x = res.x.block_until_ready()
        t_solve = time.perf_counter() - t0
        self.stats["generations"] += 1

        iters = np.asarray(res.iterations)
        conv = np.asarray(res.converged)
        fin = np.asarray(res.final_norm)
        ini = np.asarray(res.initial_norm)
        ndof = solver.fine_space.ndof
        out = []
        for s, req in enumerate(reqs):
            rel = float(fin[s] / ini[s]) if ini[s] > 0 else 0.0
            out.append(
                SolveReport(
                    request=req,
                    key=key,
                    iterations=int(iters[s]),
                    converged=bool(conv[s]),
                    final_rel_norm=rel,
                    ndof=ndof,
                    batch_size=n_real,
                    generation=generation,
                    cache_hit=cache_hit,
                    t_setup=t_setup,
                    t_solve=t_solve,
                    x=np.asarray(x[s]) if req.keep_solution else None,
                )
            )
        return out
