"""Domain-decomposed PAop AddMult: shard_map + nearest-neighbour halo
exchange (the beyond-paper distribution optimization).

The baseline dry-run cell lets GSPMD distribute the operator: elements
are sharded, but the L-vector interface is replicated, so every AddMult
ends in an all-reduce of the FULL L-vector (~200 MB at 51M DoFs) — the
collective term dominates the roofline by ~65x over the memory term.

This module makes the structured-mesh locality explicit instead: a 2D
(x, y) pencil decomposition of the element grid under ``jax.shard_map``.
Each shard owns a contiguous element block plus the overlapping node
planes; after the local fused PAop apply + local scatter, only the
*shared boundary node planes* are exchanged, with two bidirectional
``ppermute`` (collective_permute) rounds — x first, then y, which also
completes the corner sums.  Inter-device traffic per AddMult drops from
O(ndof) to O(boundary) — the classic owner-computes halo pattern on
TPU-native nearest-neighbour ICI.

The DD block format carries consistent (duplicated) values on shared
planes; ``to_blocks``/``from_blocks`` convert at the boundary of the
hot loop.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.basis import basis_tables
from repro.core.geometry import MATERIALS_BEAM, make_quadrature_data
from repro.core.paop import paop_apply
from repro.distributed.sharding import shard_map
from repro.fem.mesh import HexMesh
from repro.fem.space import H1Space

__all__ = ["SlabDecomposition", "choose_grid"]


def choose_grid(nx: int, ny: int, n_shards: int) -> tuple[int, int]:
    """(gx, gy) with gx*gy == n_shards, gx | nx, gy | ny; prefers square-ish."""
    best = None
    for gx in range(1, n_shards + 1):
        if n_shards % gx or nx % gx:
            continue
        gy = n_shards // gx
        if ny % gy:
            continue
        score = abs(np.log(gx / gy))
        if best is None or score < best[0]:
            best = (score, gx, gy)
    if best is None:
        raise ValueError(f"no (gx, gy) grid for nx={nx} ny={ny} n={n_shards}")
    return best[1], best[2]


@dataclasses.dataclass
class SlabDecomposition:
    """2D-pencil DD of the PAop operator on a structured beam mesh."""

    space: H1Space
    mesh: jax.sharding.Mesh
    axes: tuple[str, ...]  # mesh axes flattened into the shard axis
    dtype: object = jnp.float32
    materials: dict | None = None

    def __post_init__(self):
        sp = self.space
        m = sp.mesh
        self.n_shards = int(np.prod([self.mesh.shape[a] for a in self.axes]))
        self.gx, self.gy = choose_grid(m.nx, m.ny, self.n_shards)
        self.bx, self.by = m.nx // self.gx, m.ny // self.gy
        p = sp.p
        self.lnx, self.lny, self.lnz = self.bx * p + 1, self.by * p + 1, m.nz * p + 1

        # local structured space (identical on every shard)
        self.local_space = H1Space(HexMesh(self.bx, self.by, m.nz), p)
        self.local_gather = jnp.asarray(self.local_space.gather_ids)

        # global<->block node index map: (n_shards, local_nscalar)
        Nx, Ny, Nz = sp.node_grid
        ids = []
        for s in range(self.n_shards):
            sx, sy = divmod(s, self.gy)
            ix = np.arange(self.lnx) + sx * self.bx * p
            iy = np.arange(self.lny) + sy * self.by * p
            iz = np.arange(self.lnz)
            IZ, IY, IX = np.meshgrid(iz, iy, ix, indexing="ij")
            ids.append((IX + Nx * (IY + Ny * IZ)).reshape(-1))
        self.block_ids = np.stack(ids)  # (n_shards, LN)

        # per-shard element ids -> quadrature data blocks
        tb = basis_tables(p)
        qd = make_quadrature_data(m, tb, self.materials or MATERIALS_BEAM)
        eids = []
        for s in range(self.n_shards):
            sx, sy = divmod(s, self.gy)
            ex = np.arange(self.bx) + sx * self.bx
            ey = np.arange(self.by) + sy * self.by
            ez = np.arange(m.nz)
            EZ, EY, EX = np.meshgrid(ez, ey, ex, indexing="ij")
            eids.append((EX + m.nx * (EY + m.ny * EZ)).reshape(-1))
        eids = np.stack(eids)  # (n_shards, lne)
        self.lam_blocks = jnp.asarray(
            np.asarray(qd.lambda_w)[eids], dtype=self.dtype)
        self.mu_blocks = jnp.asarray(
            np.asarray(qd.mu_w)[eids], dtype=self.dtype)
        assert qd.jinv.ndim == 2, "DD path assumes the uniform affine beam"
        self.jinv = jnp.asarray(qd.jinv, dtype=self.dtype)
        self.B = jnp.asarray(tb.B, dtype=self.dtype)
        self.G = jnp.asarray(tb.G, dtype=self.dtype)

        self._shard_spec = P((*self.axes,))

    # -- format conversion (outside the hot loop) ---------------------------
    def to_blocks(self, x):
        """(nscalar, 3) -> (n_shards, LN, 3) overlapping node blocks."""
        return x[jnp.asarray(self.block_ids)]

    def from_blocks(self, xb):
        """Inverse of to_blocks (shared planes carry identical values)."""
        out = jnp.zeros((self.space.nscalar, 3), xb.dtype)
        return out.at[jnp.asarray(self.block_ids).reshape(-1)].set(
            xb.reshape(-1, 3)
        )

    # -- the DD AddMult -------------------------------------------------------
    def apply_blocks(self, xb):
        """y_blocks = A x_blocks with halo exchange (shard_map)."""
        gx, gy = self.gx, self.gy
        lnx, lny, lnz = self.lnx, self.lny, self.lnz
        gather = self.local_gather
        jinv, B, G = self.jinv, self.B, self.G
        axes = self.axes

        fwd_x = [(sx * gy + sy, (sx + 1) * gy + sy)
                 for sx in range(gx - 1) for sy in range(gy)]
        bwd_x = [(b, a) for a, b in fwd_x]
        fwd_y = [(sx * gy + sy, sx * gy + sy + 1)
                 for sx in range(gx) for sy in range(gy - 1)]
        bwd_y = [(b, a) for a, b in fwd_y]

        def body(xb, lam, mu):
            x = xb[0]  # (LN, 3)
            x_e = jnp.moveaxis(x[gather], -1, 1)  # (lne, 3, D,D,D)
            y_e = paop_apply(x_e, lam[0], mu[0], jinv, B, G)
            yflat = jnp.moveaxis(y_e, 1, -1).reshape(-1, 3)
            y = jax.ops.segment_sum(
                yflat, gather.reshape(-1), num_segments=lnx * lny * lnz
            )
            y3 = y.reshape(lnz, lny, lnx, 3)

            # x-direction halo: both copies of each shared x-plane add the
            # neighbour's partial sum (non-paired shards receive zeros).
            hi_x = jax.lax.ppermute(y3[:, :, -1, :], axes, fwd_x)
            lo_x = jax.lax.ppermute(y3[:, :, 0, :], axes, bwd_x)
            y3 = y3.at[:, :, 0, :].add(hi_x).at[:, :, -1, :].add(lo_x)

            # y-direction halo (after x: corner nodes complete transitively)
            if gy > 1:
                hi_y = jax.lax.ppermute(y3[:, -1, :, :], axes, fwd_y)
                lo_y = jax.lax.ppermute(y3[:, 0, :, :], axes, bwd_y)
                y3 = y3.at[:, 0, :, :].add(hi_y).at[:, -1, :, :].add(lo_y)
            return y3.reshape(1, -1, 3)

        fn = shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._shard_spec, self._shard_spec, self._shard_spec),
            out_specs=self._shard_spec,
            check_vma=False,
        )
        return fn(xb, self.lam_blocks, self.mu_blocks)

    def apply(self, x):
        """Global-interface convenience wrapper (block roundtrip)."""
        return self.from_blocks(self.apply_blocks(self.to_blocks(x)))
