"""PAop: the fully fused, sum-factorized, Voigt-form element kernel
(paper Sec. 4.2-4.5), expressed element-locally.

``paop_element`` is the single-element fused dataflow — interpolate the
gradient, evaluate the six-component weighted Voigt stress pointwise,
pull the rows back to reference directions, and apply the transpose
contractions — with no whole-mesh intermediate anywhere.  ``paop_apply``
vmaps it over elements; under jit the per-element chain is what XLA sees
as one producer-consumer region (macro-kernel fusion).  The Pallas TPU
kernel (repro.kernels.pa_elasticity) implements the same dataflow with
explicit VMEM tiling; this function is its numerical oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.contract import backward_grad_t, forward_grad
from repro.core.voigt import VOIGT_INDEX, stress_voigt

__all__ = ["paop_element", "paop_apply", "paop_apply_scenarios"]


def paop_element(x_e, lam_w, mu_w, jinv, B, G):
    """Fused PAop action for one element.

    x_e:   (3, D1D, D1D, D1D)     element displacement (c, iz, iy, ix)
    lam_w: (Q1D, Q1D, Q1D)        w det(J) lambda at qpoints (mu_w likewise)
    jinv:  (3, 3)                 per-element-constant J^{-1}
    """
    # Forward: sum-factorized reference gradient (3c, 3m, qz, qy, qx).
    grad_ref = forward_grad(x_e, B, G)
    # Physical gradient d_j u_c = sum_m ghat[c, m] Jinv[m, j].
    grad = jnp.einsum("cmzyx,mj->zyxcj", grad_ref, jinv)

    # Pointwise structured Voigt stress (weighted): (qz, qy, qx, 6).
    sv = stress_voigt(grad, lam_w, mu_w)

    # Backward: reconstruct rows of sigma J^{-T} from the symmetric Voigt
    # buffer (sigma_10 reads the same cell as sigma_01) and contract back.
    rows = jnp.stack(
        [
            jnp.stack([sv[..., VOIGT_INDEX[c, j]] for j in range(3)], axis=-1)
            for c in range(3)
        ],
        axis=-2,
    )  # (qz, qy, qx, c, j)
    q = jnp.einsum("zyxcj,mj->cmzyx", rows, jinv)
    return backward_grad_t(q, B, G)


def paop_apply(x_e, lam_w, mu_w, jinv, B, G):
    """Fused PAop action over a batch of elements.

    x_e: (nelem, 3, D1D, D1D, D1D); jinv: (3,3) or (nelem, 3, 3).
    """
    if jinv.ndim == 2:
        fn = lambda x, lw, mw: paop_element(x, lw, mw, jinv, B, G)
        return jax.vmap(fn)(x_e, lam_w, mu_w)
    return jax.vmap(paop_element, in_axes=(0, 0, 0, 0, None, None))(
        x_e, lam_w, mu_w, jinv, B, G
    )


def paop_apply_scenarios(x_se, lam_w, mu_w, jinv, B, G):
    """Fused PAop action over a batch of scenarios sharing one mesh.

    x_se:          (S, nelem, 3, D1D, D1D, D1D)
    lam_w / mu_w:  (S, nelem, Q1D, Q1D, Q1D)   per-scenario material data
    jinv:          (3, 3)                       shared affine geometry

    The scenario axis is folded into the element axis, so the element
    kernel (and, one level up, the Pallas grid) runs unchanged — just
    S times larger.  This is how batched operators keep the paper's
    single-kernel dataflow while amortizing launch/compile overhead
    across scenarios.
    """
    s, ne = x_se.shape[:2]
    y = paop_apply(
        x_se.reshape((s * ne,) + x_se.shape[2:]),
        lam_w.reshape((s * ne,) + lam_w.shape[2:]),
        mu_w.reshape((s * ne,) + mu_w.shape[2:]),
        jinv,
        B,
        G,
    )
    return y.reshape((s, ne) + y.shape[1:])
