"""Precision policies for the GMG-PCG stack (mixed-precision axis).

The PAop operator is bandwidth-bound across the whole p = 1..8 sweep
(every committed ``BENCH_operator_sweep.json`` row lands on the memory
side of the roofline), so halving bytes-per-apply is the biggest
remaining kernel-time lever — the direction "Towards a Higher Roofline
for Matrix-Vector Multiplication in Matrix-Free HOSFEM" takes.  A
:class:`PrecisionPolicy` names which dtype each tier of the solve runs
in:

* ``solve_dtype`` — the outer Krylov iteration: the ``BpcgState``
  vectors (x, r, z, d), the operator apply inside the CG recurrence,
  and — critically — the residual norms and tolerance thresholds.
  Keeping this at f64 is what makes the ``mixed`` policy safe: the
  stopping test is always evaluated in f64 arithmetic against the
  caller's tolerance, regardless of how sloppy the preconditioner is.
* ``precond_dtype`` — everything inside the GMG V-cycle: the per-level
  weighted material fields (the bytes the element kernel actually
  streams), the Chebyshev smoother (dinv, lambda_max, recurrence),
  and the inter-grid transfers.  A preconditioner is only required to
  be a fixed SPD operator — reduced precision here perturbs the
  convergence *rate*, never the answer the outer loop accepts.
* ``coarse_dtype`` — the coarsest-level probe + dense Cholesky factor
  and the per-chunk triangular solves.  Kept separate because bf16
  has too few mantissa bits to factor even well-conditioned coarse
  blocks (``mixed-bf16`` holds the coarse solve at f32).

Built-in policies (see :data:`PRECISION_POLICIES`):

==============  ===========  =============  ============
name            solve_dtype  precond_dtype  coarse_dtype
==============  ===========  =============  ============
``f64``         float64      float64        float64
``f32``         float32      float32        float32
``mixed``       float64      float32        float32
``mixed-bf16``  float64      bfloat16       float32
==============  ===========  =============  ============

The policy rides the prep pytree implicitly: a
:class:`~repro.solvers.batched.BatchedGMGSolver` resolves its policy at
construction and every prep leaf it produces carries the corresponding
dtype (the reduced policies additionally carry a ``solve_dtype`` copy
of the *fine-level* weighted fields, because the outer Krylov streams
the fine operator at full precision while the smoother streams it
reduced).  ``policy.name`` participates in the service compile-cache
key and the prep-reuse content digest, is recorded in every BENCH row
(``precision_policy``) and labels the service metrics.

Safety story: reduced-precision cycles can stagnate when the requested
tolerance sits below the reduced dtype's attainable residual floor.
The batched solver detects this per scenario (masked, exactly like
per-scenario convergence) and the solve/serving layers re-solve only
the affected rows under the ``f64`` policy — see
:func:`repro.solvers.batched.bpcg_chunk` (stall counters) and
``docs/PRECISION.md`` for the contract.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["PrecisionPolicy", "PRECISION_POLICIES", "resolve_precision"]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Dtype assignment for the tiers of one GMG-PCG solve (static
    metadata — hashable, usable in compile-cache keys)."""

    name: str
    solve_dtype: Any  # outer Krylov vectors + residual/tolerance accounting
    precond_dtype: Any  # smoother, transfers, element kernel in the V-cycle
    coarse_dtype: Any  # coarse probe + Cholesky factor/solve

    @property
    def uniform(self) -> bool:
        """True when every tier runs one dtype (no cast boundaries)."""
        return (
            self.solve_dtype == self.precond_dtype
            and self.solve_dtype == self.coarse_dtype
        )

    @property
    def reduced(self) -> bool:
        """True when any tier runs below float64 — exactly the policies
        covered by the stagnation-detection + f64-fallback contract."""
        return not (
            self.solve_dtype == jnp.float64
            and self.precond_dtype == jnp.float64
            and self.coarse_dtype == jnp.float64
        )


PRECISION_POLICIES: dict[str, PrecisionPolicy] = {
    "f64": PrecisionPolicy("f64", jnp.float64, jnp.float64, jnp.float64),
    "f32": PrecisionPolicy("f32", jnp.float32, jnp.float32, jnp.float32),
    "mixed": PrecisionPolicy("mixed", jnp.float64, jnp.float32, jnp.float32),
    "mixed-bf16": PrecisionPolicy(
        "mixed-bf16", jnp.float64, jnp.bfloat16, jnp.float32
    ),
}


def resolve_precision(
    precision: str | PrecisionPolicy | None, dtype=None
) -> PrecisionPolicy:
    """Resolve a precision request to a :class:`PrecisionPolicy`.

    ``precision`` is a policy name (``"f64"``, ``"f32"``, ``"mixed"``,
    ``"mixed-bf16"``), an explicit policy object, or None — meaning
    "derive from the legacy ``dtype`` argument": f64 (or no dtype)
    resolves to the ``f64`` policy, f32 to ``f32``, and any other
    uniform dtype to an ad-hoc uniform policy named after it.  Passing
    both a policy and a conflicting ``dtype`` is an error — the policy
    is the single source of dtype truth."""
    if isinstance(precision, PrecisionPolicy):
        pol = precision
    elif precision is None:
        if dtype is None or jnp.dtype(dtype) == jnp.dtype(jnp.float64):
            return PRECISION_POLICIES["f64"]
        for pol in PRECISION_POLICIES.values():
            if pol.uniform and jnp.dtype(pol.solve_dtype) == jnp.dtype(dtype):
                return pol
        return PrecisionPolicy(str(jnp.dtype(dtype)), dtype, dtype, dtype)
    else:
        try:
            pol = PRECISION_POLICIES[precision]
        except KeyError:
            raise ValueError(
                f"unknown precision policy {precision!r}; expected one "
                f"of {tuple(PRECISION_POLICIES)} or a PrecisionPolicy"
            ) from None
    if dtype is not None and jnp.dtype(dtype) != jnp.dtype(pol.solve_dtype):
        raise ValueError(
            f"precision policy {pol.name!r} solves in "
            f"{jnp.dtype(pol.solve_dtype)} but dtype="
            f"{jnp.dtype(dtype)} was also requested; pass one or the "
            f"other"
        )
    return pol
