"""Voigt notation utilities (paper Sec. 4.3).

Zero-based buffer order [00, 11, 22, 01, 02, 12] (the paper's
implementation ordering [s11, s22, s33, s12, s13, s23] in one-based
notation).  The constitutive relation is evaluated with the structured
arithmetic of Sec. 4.5 — never as a dense 6x6 matvec.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["VOIGT_PAIRS", "VOIGT_INDEX", "to_voigt", "from_voigt", "stress_voigt"]

# voigt slot -> (i, j) tensor indices
VOIGT_PAIRS = ((0, 0), (1, 1), (2, 2), (0, 1), (0, 2), (1, 2))

# (i, j) tensor indices -> voigt slot (symmetric)
VOIGT_INDEX = np.array([[0, 3, 4], [3, 1, 5], [4, 5, 2]])


def to_voigt(sym):
    """(..., 3, 3) symmetric tensor -> (..., 6) Voigt components."""
    return jnp.stack([sym[..., i, j] for (i, j) in VOIGT_PAIRS], axis=-1)


def from_voigt(v):
    """(..., 6) Voigt -> (..., 3, 3) symmetric tensor."""
    rows = [
        jnp.stack([v[..., VOIGT_INDEX[i, j]] for j in range(3)], axis=-1)
        for i in range(3)
    ]
    return jnp.stack(rows, axis=-2)


def stress_voigt(grad, lam_w, mu_w):
    """Structured Voigt stress arithmetic (paper Sec. 4.5).

    ``grad[..., c, j]`` is the (weight-free) physical displacement gradient
    d_j u_c; ``lam_w``/``mu_w`` carry w_q * det(J) * {lambda, mu}.  Returns
    the 6 weighted Voigt components stacked on the last axis.  ~24 flops per
    point under the paper's multiply/add counting convention, vs. the 81-term
    dense C_ijkl contraction.
    """
    div = grad[..., 0, 0] + grad[..., 1, 1] + grad[..., 2, 2]
    ld = lam_w * div
    s00 = ld + 2.0 * mu_w * grad[..., 0, 0]
    s11 = ld + 2.0 * mu_w * grad[..., 1, 1]
    s22 = ld + 2.0 * mu_w * grad[..., 2, 2]
    s01 = mu_w * (grad[..., 0, 1] + grad[..., 1, 0])
    s02 = mu_w * (grad[..., 0, 2] + grad[..., 2, 0])
    s12 = mu_w * (grad[..., 1, 2] + grad[..., 2, 1])
    return jnp.stack([s00, s11, s22, s01, s02, s12], axis=-1)
