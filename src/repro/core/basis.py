"""1D basis and quadrature tables for tensor-product elements.

The paper (Sec. 4.4) uses D1D = p+1 Gauss-Lobatto-Legendre (GLL) nodal
points for the degree-p Lagrange basis and Q1D = p+2 Gauss-Legendre
quadrature points (MFEM's default over-integration rule).  The 1D
interpolation table ``B[q, i] = phi_i(xi_q)`` and derivative table
``G[q, i] = phi_i'(xi_q)`` are the quadrature-sampled matrix
representations of the one-dimensional operators B_1D / G_1D; everything
in the sum-factorized operator is built from them.

Tables are computed in float64 numpy (setup-time, never traced) and cast
to the operator dtype at use sites.
"""

from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "gll_nodes",
    "gauss_points",
    "lagrange_tables",
    "BasisTables",
    "basis_tables",
]


def gll_nodes(p: int) -> np.ndarray:
    """Gauss-Lobatto-Legendre nodes on [-1, 1] for degree ``p`` (p+1 points).

    Interior nodes are the roots of P'_p (derivative of the Legendre
    polynomial), found via the eigenvalues of the Jacobi matrix of the
    (1,1)-Jacobi polynomials; endpoints are +-1.
    """
    if p < 1:
        raise ValueError(f"degree must be >= 1, got {p}")
    if p == 1:
        return np.array([-1.0, 1.0])
    # Roots of P'_p == roots of Jacobi polynomial P^{(1,1)}_{p-1}.
    # Golub-Welsch on the Jacobi(1,1) recurrence.
    n = p - 1
    k = np.arange(1, n)
    # Jacobi(1,1) three-term recurrence off-diagonal terms.
    b = np.sqrt(k * (k + 2) / ((2 * k + 1) * (2 * k + 3)))
    J = np.diag(b, 1) + np.diag(b, -1)
    interior = np.sort(np.linalg.eigvalsh(J))
    nodes = np.concatenate([[-1.0], interior, [1.0]])
    # Polish with a couple of Newton steps on (1-x^2) P'_p(x).
    for _ in range(2):
        Pp, dPp = _legendre_deriv(p, nodes[1:-1])
        f = dPp  # roots of P'_p
        # d/dx P'_p = P''_p ; from Legendre ODE: (1-x^2) P'' = 2x P' - p(p+1) P
        x = nodes[1:-1]
        d2 = (2 * x * dPp - p * (p + 1) * Pp) / (1 - x * x)
        nodes[1:-1] = x - f / d2
    return nodes


def _legendre_deriv(p: int, x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return (P_p(x), P'_p(x)) via the stable three-term recurrence."""
    P0 = np.ones_like(x)
    P1 = x.copy()
    if p == 0:
        return P0, np.zeros_like(x)
    for k in range(2, p + 1):
        P0, P1 = P1, ((2 * k - 1) * x * P1 - (k - 1) * P0) / k
    # derivative identity: (1-x^2) P'_p = p (P_{p-1} - x P_p)
    dP = p * (P0 - x * P1) / (1 - x * x)
    return P1, dP


def gauss_points(q: int) -> tuple[np.ndarray, np.ndarray]:
    """Gauss-Legendre points and weights on [-1, 1]."""
    pts, wts = np.polynomial.legendre.leggauss(q)
    return pts, wts


def lagrange_tables(nodes: np.ndarray, pts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Values/derivatives of the Lagrange basis on ``nodes`` at ``pts``.

    Returns ``B[q, i] = phi_i(pts[q])`` and ``G[q, i] = phi_i'(pts[q])``
    using barycentric formulas (stable for GLL nodes up to high degree).
    """
    n = len(nodes)
    # Barycentric weights.
    diff = nodes[:, None] - nodes[None, :]
    np.fill_diagonal(diff, 1.0)
    w = 1.0 / np.prod(diff, axis=1)

    B = np.empty((len(pts), n))
    G = np.empty((len(pts), n))
    for q, x in enumerate(pts):
        d = x - nodes
        if np.any(d == 0.0):
            # Evaluation point coincides with a node (GLL-collocated rules).
            i0 = int(np.argmin(np.abs(d)))
            B[q] = 0.0
            B[q, i0] = 1.0
            # Derivative at node i0: differentiation-matrix row
            #   D[i0, j] = (w_j / w_i0) / (x_i0 - x_j),  D[i0, i0] = -sum_j.
            row = np.zeros(n)
            mask = np.arange(n) != i0
            row[mask] = (w[mask] / w[i0]) / (nodes[i0] - nodes[mask])
            row[i0] = -np.sum(row[mask])
            G[q] = row
            continue
        # Barycentric: with t_j = w_j/(x - x_j), s = sum t:  phi_i = t_i/s and
        # phi_i' = phi_i * (sum_j t_j/d_j / s  -  1/d_i).
        t = w / d
        s = np.sum(t)
        B[q] = t / s
        s2 = np.sum(t / d)
        G[q] = B[q] * (s2 / s - 1.0 / d)
    return B, G


class BasisTables:
    """Container for the 1D tables of a (p, q) tensor-product element."""

    __slots__ = ("p", "d1d", "q1d", "nodes", "qpts", "qwts", "B", "G")

    def __init__(self, p: int, q1d: int | None = None):
        self.p = p
        self.d1d = p + 1
        self.q1d = q1d if q1d is not None else p + 2  # MFEM over-integration
        self.nodes = gll_nodes(p)
        self.qpts, self.qwts = gauss_points(self.q1d)
        self.B, self.G = lagrange_tables(self.nodes, self.qpts)

    def __repr__(self) -> str:  # pragma: no cover
        return f"BasisTables(p={self.p}, D1D={self.d1d}, Q1D={self.q1d})"


@functools.lru_cache(maxsize=None)
def basis_tables(p: int, q1d: int | None = None) -> BasisTables:
    return BasisTables(p, q1d)
