"""Ablation stages C1 / C2: sum-factorized but *unfused* PA operators.

C1 (paper Sec. 4.4): replaces the dense O((p+1)^6) contraction of the
baseline by three 1D contraction sweeps per direction — but, like the
pre-fusion MFEM layout, it remains organized as whole-mesh passes whose
full-volume intermediates (reference gradients, the 3x3 stress ``QVec``)
are materialized between kernels.

C2 (paper Sec. 4.3): C1 + Voigt notation — the whole-mesh stress
intermediate shrinks from 9 to 6 components and the constitutive update
uses the structured arithmetic.  The paper observes (Table 7) that its
marginal benefit is small until fusion removes the round trip; keeping
the stage separate lets the benchmark harness reproduce that.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.contract import backward_grad_t, forward_grad
from repro.core.voigt import VOIGT_INDEX, stress_voigt

__all__ = ["pa_sumfact_apply", "pa_sumfact_voigt_apply"]


def _phys_grad(grad_ref, jinv):
    """(ne, 3c, 3m, qz, qy, qx) reference -> physical: d_j u_c."""
    if jinv.ndim == 2:
        return jnp.einsum("ecmzyx,mj->ecjzyx", grad_ref, jinv)
    return jnp.einsum("ecmzyx,emj->ecjzyx", grad_ref, jinv)


def _pullback(sigma_rows, jinv):
    """Q[c, m] = sum_j sigma[c, j] Jinv[m, j]."""
    if jinv.ndim == 2:
        return jnp.einsum("ecjzyx,mj->ecmzyx", sigma_rows, jinv)
    return jnp.einsum("ecjzyx,emj->ecmzyx", sigma_rows, jinv)


def pa_sumfact_apply(x_e, lam_w, mu_w, jinv, B, G):
    """C1: sum-factorized sweeps, full 3x3 stress intermediate."""
    grad_ref = forward_grad(x_e, B, G)  # (ne, 3, 3, qz, qy, qx)
    grad = _phys_grad(grad_ref, jinv)

    div = grad[:, 0, 0] + grad[:, 1, 1] + grad[:, 2, 2]
    eye = jnp.eye(3, dtype=x_e.dtype)
    sym = grad + jnp.swapaxes(grad, 1, 2)
    lw = lam_w[:, None, None]
    mw = mu_w[:, None, None]
    sigma = lw * div[:, None, None] * eye[None, :, :, None, None, None] + mw * sym

    q = _pullback(sigma, jinv)
    return backward_grad_t(q, B, G)


def pa_sumfact_voigt_apply(x_e, lam_w, mu_w, jinv, B, G):
    """C2: C1 + six-component Voigt stress with structured arithmetic."""
    grad_ref = forward_grad(x_e, B, G)
    grad = _phys_grad(grad_ref, jinv)  # (ne, c, j, z, y, x)

    # stress_voigt wants (..., c, j) trailing: move the small axes last.
    g = jnp.moveaxis(grad, (1, 2), (-2, -1))  # (ne, z, y, x, c, j)
    sv = stress_voigt(g, lam_w, mu_w)  # (ne, z, y, x, 6)
    rows = _voigt_rows(sv)  # (ne, z, y, x, c, j)
    sigma = jnp.moveaxis(rows, (-2, -1), (1, 2))  # (ne, c, j, z, y, x)

    q = _pullback(sigma, jinv)
    return backward_grad_t(q, B, G)


def _voigt_rows(sv):
    """Reconstruct sigma rows (..., c, j) from Voigt components (..., 6)
    via the symmetric index map (sigma_10 reads the same cell as sigma_01)."""
    rows = [
        jnp.stack([sv[..., VOIGT_INDEX[c, j]] for j in range(3)], axis=-1)
        for c in range(3)
    ]
    return jnp.stack(rows, axis=-2)
