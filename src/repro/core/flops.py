"""Analytic FLOP counts for the elasticity operator (paper Table 5)."""

from __future__ import annotations

__all__ = ["paop_flops_per_elem", "dense_flops_per_elem"]


def paop_flops_per_elem(p: int) -> float:
    """Closed-form multiply+add count of the PAop kernel per element
    (d=3 vector elasticity; forward + pointwise Voigt + backward)."""
    D, Q = p + 1, p + 2
    fwd = 3 * 2 * (
        2 * (Q * D * D * D)     # X contraction: u, v channels
        + 3 * (Q * Q * D * D)   # Y: d_xi, d_eta, u_xy
        + 3 * (Q * Q * Q * D)   # Z
    )
    geom = 2 * 9 * Q**3 * 2     # J^-T pullback, forward + backward
    stress = 24 * Q**3          # structured Voigt arithmetic (Sec. 4.3)
    bwd = 3 * 2 * (
        3 * (Q * Q * Q * D) + 3 * (Q * Q * D * D) + 3 * (Q * D * D * D)
    )
    return float(fwd + geom + stress + bwd)


def dense_flops_per_elem(p: int) -> float:
    """Dense G3D contraction cost (the MFEM v4.8 baseline's O((p+1)^6))."""
    D, Q = p + 1, p + 2
    return float(2 * 2 * (3 * D**3) * (3 * 3 * Q**3))
