"""Analytic FLOP counts for the elasticity operator (paper Table 5).

:func:`default_q1d` is the single source of truth for the 1D quadrature
count — the streaming-bytes model (``repro.obs.throughput``), the
roofline script (``benchmarks/fig6_roofline``) and the kernel's VMEM
budgeting (``repro.kernels.pa_elasticity.ops``) all derive Q from it,
so the analytic models cannot drift from what the kernel actually
streams.  Call sites that know the *real* q1d (read off ``lam_w``'s
trailing axis) pass it explicitly.
"""

from __future__ import annotations

__all__ = ["default_q1d", "paop_flops_per_elem", "dense_flops_per_elem"]


def default_q1d(p: int) -> int:
    """1D quadrature-point count for degree ``p``: the paper's p+2
    Gauss rule (exact for the bilinear-form integrand on affine cells)."""
    return p + 2


def paop_flops_per_elem(p: int, q1d: int | None = None) -> float:
    """Closed-form multiply+add count of the PAop kernel per element
    (d=3 vector elasticity; forward + pointwise Voigt + backward)."""
    D = p + 1
    Q = default_q1d(p) if q1d is None else q1d
    fwd = 3 * 2 * (
        2 * (Q * D * D * D)     # X contraction: u, v channels
        + 3 * (Q * Q * D * D)   # Y: d_xi, d_eta, u_xy
        + 3 * (Q * Q * Q * D)   # Z
    )
    geom = 2 * 9 * Q**3 * 2     # J^-T pullback, forward + backward
    stress = 24 * Q**3          # structured Voigt arithmetic (Sec. 4.3)
    bwd = 3 * 2 * (
        3 * (Q * Q * Q * D) + 3 * (Q * Q * D * D) + 3 * (Q * D * D * D)
    )
    return float(fwd + geom + stress + bwd)


def dense_flops_per_elem(p: int, q1d: int | None = None) -> float:
    """Dense G3D contraction cost (the MFEM v4.8 baseline's O((p+1)^6))."""
    D = p + 1
    Q = default_q1d(p) if q1d is None else q1d
    return float(2 * 2 * (3 * D**3) * (3 * 3 * Q**3))
