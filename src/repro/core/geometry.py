"""Affine geometry factors and quadrature-point material data (the "D" of
the operator chain A = P^T G^T B^T D B G P).

For affine tensor-product hexahedra (the paper's regime) J, det(J) and
J^{-1} are constant per element and precomputed once (Sec. 4.4).  The
quadrature-point material data lambda_w = w_q det(J) lambda(q, e) and
mu_w = w_q det(J) mu(q, e) is stored per (element, qpoint) — the paper
keeps per-qpoint material generality even though the benchmark uses
piecewise-constant materials.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.basis import BasisTables
from repro.fem.mesh import HexMesh

__all__ = [
    "QuadratureData",
    "QuadratureGeometry",
    "quadrature_geometry",
    "material_fields",
    "check_material_dict",
    "check_material_fields",
    "make_quadrature_data",
    "MATERIALS_BEAM",
]

# Paper Sec. 5.1.4: attribute 1 -> lambda = mu = 50, attribute 2 -> 1.
MATERIALS_BEAM = {1: (50.0, 50.0), 2: (1.0, 1.0)}


@dataclasses.dataclass
class QuadratureData:
    """Precomputed PA setup data (the stored quadrature-point operator
    data D plus per-element geometry)."""

    # (nelem, Q1D, Q1D, Q1D): w_q * det(J) * lambda / mu  at each qpoint.
    lambda_w: Any
    mu_w: Any
    # (3, 3): J^{-1}, constant per element on a uniform affine box (the
    # paper's per-element constant; uniform refinement makes it global here,
    # but operators accept per-element (nelem, 3, 3) too).
    jinv: Any
    detj: float


@dataclasses.dataclass
class QuadratureGeometry:
    """Material-independent part of the stored PA data: the weighted
    reference->physical geometry factors shared by every scenario."""

    # (Q1D, Q1D, Q1D): w_q * det(J), separable quadrature weights times
    # the (per-element-constant, here globally constant) Jacobian det.
    w_detj: Any
    jinv: Any  # (3, 3)
    detj: float


def quadrature_geometry(
    mesh: HexMesh, tables: BasisTables, dtype=np.float64
) -> QuadratureGeometry:
    """Geometry factors of the D-data for an affine box mesh.  Splitting
    these from the material coefficients lets batched operators rebind
    per-scenario (lambda, mu) fields without redoing any geometry."""
    J = mesh.jacobian()
    detj = float(np.linalg.det(J))
    if detj <= 0:
        raise ValueError("mesh Jacobian must have positive determinant")
    jinv = np.linalg.inv(J)
    # Separable quadrature weights w(qz, qy, qx) = w_z w_y w_x.
    w = tables.qwts
    w3 = w[:, None, None] * w[None, :, None] * w[None, None, :]  # (Q,Q,Q)
    return QuadratureGeometry(
        w_detj=(w3 * detj).astype(dtype), jinv=jinv.astype(dtype), detj=detj
    )


def material_fields(
    mesh: HexMesh,
    materials: dict[int, tuple[float, float]] | None = None,
    dtype=np.float64,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-element (lambda_e, mu_e) coefficient fields from an
    attribute -> (lambda, mu) table, each of shape (nelem,)."""
    materials = materials or MATERIALS_BEAM
    attr = mesh.attributes()
    lam_e = np.empty(mesh.nelem, dtype=dtype)
    mu_e = np.empty(mesh.nelem, dtype=dtype)
    for a, (lam, mu) in materials.items():
        sel = attr == a
        lam_e[sel] = lam
        mu_e[sel] = mu
    known = np.isin(attr, list(materials))
    if not known.all():
        raise ValueError(f"elements with unknown attributes: {set(attr[~known])}")
    return lam_e, mu_e


def check_material_dict(materials: dict, attrs, *, where: str = "materials") -> None:
    """Validate an attribute -> (lambda, mu) dict against a mesh's
    attribute set: every mesh attribute must be covered and every
    coefficient must be positive.  Raises ValueError naming the missing
    attributes or the first offending attribute and its values."""
    attr_set = {int(a) for a in np.unique(np.asarray(attrs))}
    missing = attr_set - {int(a) for a in materials}
    if missing:
        raise ValueError(
            f"{where}: missing mesh attributes {sorted(missing)} "
            f"(mesh has {tuple(sorted(attr_set))})"
        )
    for a in sorted(materials):
        try:
            lam, mu = materials[a]
            lam, mu = float(lam), float(mu)
        except (TypeError, ValueError):
            raise ValueError(
                f"{where}: attribute {a} must map to a (lambda, mu) "
                f"pair, got {materials[a]!r}"
            ) from None
        if not (lam > 0 and mu > 0):  # also catches NaN
            raise ValueError(
                f"{where}: attribute {a} has non-positive coefficients "
                f"(lambda, mu) = ({lam}, {mu}); both must be > 0"
            )


def check_material_fields(
    lam_e, mu_e, nelem: int, *, where: str = "materials"
) -> tuple[np.ndarray, np.ndarray]:
    """Validate a per-element (lam_e, mu_e) coefficient pair: both of
    shape (nelem,) on the fine mesh, every entry positive.  Raises
    ValueError naming the mismatched shape (with the expected one) or
    the first offending element index and value; returns the pair as
    float64 numpy arrays."""
    lam_e = np.asarray(lam_e, dtype=np.float64)
    mu_e = np.asarray(mu_e, dtype=np.float64)
    for name, f in (("lam_e", lam_e), ("mu_e", mu_e)):
        if f.shape != (nelem,):
            raise ValueError(
                f"{where}: {name} has shape {f.shape}, expected ({nelem},) "
                f"— one coefficient per fine-mesh element"
            )
        bad = np.flatnonzero(~(f > 0))  # ~(x > 0) also catches NaN
        if bad.size:
            e = int(bad[0])
            n = int(bad.size)
            raise ValueError(
                f"{where}: {name}[{e}] = {f[e]} is not positive "
                f"({n} non-positive entr{'y' if n == 1 else 'ies'}; "
                f"all coefficients must be > 0)"
            )
    return lam_e, mu_e


def make_quadrature_data(
    mesh: HexMesh,
    tables: BasisTables,
    materials: dict[int, tuple[float, float]] | None = None,
    dtype=np.float64,
) -> QuadratureData:
    """Build the stored PA data for an affine box mesh."""
    q1d = tables.q1d
    geom = quadrature_geometry(mesh, tables, dtype=dtype)
    lam_e, mu_e = material_fields(mesh, materials, dtype=dtype)
    lam_w = (lam_e[:, None, None, None] * geom.w_detj).astype(dtype)
    mu_w = (mu_e[:, None, None, None] * geom.w_detj).astype(dtype)
    assert lam_w.shape == (mesh.nelem, q1d, q1d, q1d)
    return QuadratureData(
        lambda_w=lam_w, mu_w=mu_w, jinv=geom.jinv, detj=geom.detj
    )
