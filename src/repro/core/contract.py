"""Sum-factorized 1D tensor contractions (paper Sec. 4.4 / 4.5).

The forward sweep evaluates reference-space gradients at quadrature points
through three sequential 1D contractions (X, then Y, then Z); the backward
sweep is its exact transpose.  All functions take arrays whose trailing
axes are the tensor-product axes ``(..., iz, iy, ix)`` so the same code
serves whole-mesh (C1/C2 ablation stages), per-element fused (vmap /
Pallas reference) and batched-element (Pallas kernel block) callers.

Index conventions match the paper: ``B[q, i] = phi_i(xi_q)``,
``G[q, i] = phi_i'(xi_q)``; D1D dof points, Q1D quadrature points.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["forward_grad", "backward_grad_t", "interp3d", "interp3d_t"]


def forward_grad(x, B, G):
    """Reference gradient at quadrature points.

    x: (..., D1D, D1D, D1D) laid out (iz, iy, ix).
    Returns (..., 3, Q1D, Q1D, Q1D) with axis -4 the reference direction
    (d_xi, d_eta, d_zeta) and trailing axes (qz, qy, qx).
    """
    # X contraction: two channels (sm0[0/1] of the paper).
    u = jnp.einsum("...zyx,qx->...zyq", x, B)
    v = jnp.einsum("...zyx,qx->...zyq", x, G)
    # Y contraction: three channels (sm1[0/1/2]).
    d_xi = jnp.einsum("...zyq,ry->...zrq", v, B)
    d_eta = jnp.einsum("...zyq,ry->...zrq", u, G)
    u_xy = jnp.einsum("...zyq,ry->...zrq", u, B)
    # Z contraction.
    g_xi = jnp.einsum("...zrq,sz->...srq", d_xi, B)
    g_eta = jnp.einsum("...zrq,sz->...srq", d_eta, B)
    g_zeta = jnp.einsum("...zrq,sz->...srq", u_xy, G)
    return jnp.stack([g_xi, g_eta, g_zeta], axis=-4)


def backward_grad_t(q, B, G):
    """Transpose of :func:`forward_grad` (the test-function contraction).

    q: (..., 3, Q1D, Q1D, Q1D) — rows of the weighted stress pulled back to
    reference directions.  Returns (..., D1D, D1D, D1D): the divergence-type
    contraction sum_m d_m(.) applied slice-wise (G along direction m, B along
    the other two), summed over the three m-channels.
    """

    def sweep(t, tx, ty, tz):
        t = jnp.einsum("...srq,sz->...zrq", t, tz)  # Z: tmpZ
        t = jnp.einsum("...zrq,ry->...zyq", t, ty)  # Y: tmpY
        return jnp.einsum("...zyq,qx->...zyx", t, tx)  # X + accumulate

    return (
        sweep(q[..., 0, :, :, :], G, B, B)
        + sweep(q[..., 1, :, :, :], B, G, B)
        + sweep(q[..., 2, :, :, :], B, B, G)
    )


def interp3d(x, B):
    """Pure interpolation to quadrature points (used by mass-type terms)."""
    x = jnp.einsum("...zyx,qx->...zyq", x, B)
    x = jnp.einsum("...zyq,ry->...zrq", x, B)
    return jnp.einsum("...zrq,sz->...srq", x, B)


def interp3d_t(x, B):
    """Transpose of :func:`interp3d`."""
    x = jnp.einsum("...srq,sz->...zrq", x, B)
    x = jnp.einsum("...zrq,ry->...zyq", x, B)
    return jnp.einsum("...zyq,qx->...zyx", x, B)
