"""ElasticityOperator: the paper's contribution as a composable module.

One operator object per (mesh, degree) pair exposes every assembly level
of the ablation (Table 7) behind a single interface consumed by the
solvers:

    assembly in {"fa", "pa_baseline", "pa_sumfact", "pa_sumfact_voigt",
                 "paop", "paop_pallas"}

``apply(x)`` acts on the unconstrained L-vector (nscalar, 3);
``constrained()`` wraps it with MFEM ConstrainedOperator semantics and
the matrix-free diagonal for the Chebyshev-Jacobi smoother.

Scenario batching: ``materials`` may also be a *sequence* of scenario
entries — attribute->(lambda, mu) dicts and/or per-element
``(lam_e, mu_e)`` pairs, mixed freely — or a raw coefficient-array pair
of shape (nelem,) or (S, nelem).  With a
leading scenario axis the operator acts on (S, nscalar, 3) L-vectors;
internally the scenario axis is folded into the element axis so every
PA kernel — including the Pallas one — runs unchanged on a grid S times
larger.  ``with_materials`` rebinds the (traceable) material fields
without redoing any geometry, which is what lets a jitted batched solve
take materials as runtime arguments.

Multi-device scenarios: with ``shard_mesh`` set (a 1-D jax.sharding
mesh over the scenario axis), the batched apply/diagonal paths pin both
the (S, nscalar, 3) L-vectors and the folded (S*nelem, ...) E-vectors
to axis-0 sharding via with_sharding_constraint.  Because S divides the
mesh, each shard holds whole scenarios and the element-local kernels
run unchanged per device with zero cross-device traffic (the L-vector
gather/scatter indices are per-scenario too).
"""

from __future__ import annotations

import copy
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diagonal as _diag
from repro.core import fa as _fa
from repro.core import pa_baseline as _base
from repro.core import pa_sumfact as _sf
from repro.core import paop as _paop
from repro.core.basis import basis_tables
from repro.kernels.pa_elasticity.ops import resolve_lane
from repro.core.geometry import (
    MATERIALS_BEAM,
    make_quadrature_data,
    material_fields,
    quadrature_geometry,
)
from repro.distributed.sharding import pin_scenario
from repro.fem.bc import ConstrainedOperator
from repro.fem.space import H1Space

__all__ = ["ElasticityOperator", "ASSEMBLY_LEVELS", "DEFER_MATERIALS"]

# Sentinel: build the operator as a geometry/tables carrier only; material
# fields are bound later via with_materials (e.g. inside a jitted batched
# solve).  Skips allocating placeholder (nelem, Q^3) quadrature buffers.
DEFER_MATERIALS = "defer"

ASSEMBLY_LEVELS = (
    "fa",
    "pa_baseline",
    "pa_sumfact",
    "pa_sumfact_voigt",
    "paop",
    "paop_pallas",
)


class ElasticityOperator:
    def __init__(
        self,
        space: H1Space,
        assembly: str = "paop",
        materials: dict[int, tuple[float, float]] | None = None,
        dtype=jnp.float64,
        ess_faces=("x0",),
        pallas_interpret: bool | None = None,
        pallas_lane: str | None = None,
        shard_mesh=None,
    ):
        if assembly not in ASSEMBLY_LEVELS:
            raise ValueError(f"unknown assembly level {assembly!r}")
        if shard_mesh is not None and assembly == "fa":
            raise ValueError("shard_mesh is matrix-free only (not 'fa')")
        self.space = space
        self.assembly = assembly
        self.dtype = dtype
        self.tables = space.tables
        # Resolved at construction, so this attribute is the report of
        # which Pallas lane actually runs ("compiled" or "interpret"):
        # an explicit pallas_lane wins, the legacy pallas_interpret bool
        # is honored (True pins the interpreter), and the default is
        # "auto" — compiled when the backend can lower Pallas, interpret
        # fallback otherwise.  Only consulted by assembly="paop_pallas".
        self.pallas_lane = resolve_lane(pallas_lane, interpret=pallas_interpret)
        self.shard_mesh = shard_mesh

        geom = quadrature_geometry(space.mesh, self.tables)
        self.w_detj = jnp.asarray(geom.w_detj, dtype=dtype)  # (Q,Q,Q)
        self.jinv = jnp.asarray(geom.jinv, dtype=dtype)
        self.detj = geom.detj
        self.B = jnp.asarray(self.tables.B, dtype=dtype)
        self.G = jnp.asarray(self.tables.G, dtype=dtype)
        self.ess_mask = space.essential_mask(ess_faces)

        if isinstance(materials, str) and materials == DEFER_MATERIALS:
            if assembly == "fa":
                raise ValueError("assembly='fa' cannot defer materials")
            self.materials = None
            self.nbatch = None
            self.lam_w = self.mu_w = None
        else:
            self.materials = (
                materials if materials is not None else MATERIALS_BEAM
            )
            lam_e, mu_e = self._normalize_materials(self.materials)
            self._bind_materials(lam_e, mu_e)

        self._sparse: _fa.SparseMatrix | None = None
        if assembly == "fa":
            if self.nbatch is not None or not isinstance(self.materials, dict):
                raise ValueError(
                    "assembly='fa' supports only a single attribute->"
                    "(lambda, mu) dict; use a matrix-free level for "
                    "scenario-batched or per-element materials"
                )
            qd = make_quadrature_data(
                space.mesh, self.tables, self.materials
            )  # setup in float64 regardless of operator dtype
            self._sparse = _fa.assemble_sparse(
                space, qd, self.materials, ess_mask=None, dtype=dtype
            )

    # -- materials -----------------------------------------------------------
    @staticmethod
    def _is_field_pair(m) -> bool:
        """A (lam_e, mu_e) scenario entry: two 1-D array-likes."""
        return (
            isinstance(m, (tuple, list))
            and len(m) == 2
            and np.ndim(m[0]) == 1
            and np.ndim(m[1]) == 1
        )

    def _normalize_materials(self, materials):
        """Normalize to per-element coefficient fields (lam_e, mu_e) of
        shape (nelem,) or (S, nelem).

        Accepted forms: one attribute->(lambda, mu) dict; one
        (lam_e, mu_e) pair of (nelem,) arrays; a scenario *sequence*
        whose entries are dicts and/or such pairs, mixed freely (each
        entry one scenario row); or a raw pre-stacked (S, nelem) pair.
        A sequence of pairs is recognized per entry — it is never
        mis-read as one stacked pair."""
        mesh = self.space.mesh
        if isinstance(materials, dict):
            return material_fields(mesh, materials)
        if (
            isinstance(materials, (list, tuple))
            and len(materials) == 2
            and all(self._is_field_pair(m) for m in materials)
        ):
            # Genuinely ambiguous: ([a, b], [c, d]) with 1-D rows reads
            # both as a raw stacked (2, nelem) pair and as two
            # (lam_e, mu_e) scenario entries — and the two readings
            # cross lambda/mu differently.  Refuse loudly instead of
            # guessing wrong physics.
            raise ValueError(
                "ambiguous materials: a length-2 sequence of 1-D array "
                "pairs reads both as one stacked (2, nelem) (lam, mu) "
                "pair and as two per-scenario (lam_e, mu_e) pairs; pass "
                "numpy arrays of shape (2, nelem) for the stacked form, "
                "or include a dict entry / use another batch size for "
                "the scenario-sequence form"
            )
        if (
            isinstance(materials, (list, tuple))
            and materials
            and not self._is_field_pair(materials)
            and all(
                isinstance(m, dict) or self._is_field_pair(m)
                for m in materials
            )
        ):
            fields = [
                material_fields(mesh, m)
                if isinstance(m, dict)
                else (np.asarray(m[0]), np.asarray(m[1]))
                for m in materials
            ]
            return (
                np.stack([f[0] for f in fields]),
                np.stack([f[1] for f in fields]),
            )
        try:
            lam_e, mu_e = materials
        except (TypeError, ValueError):
            raise TypeError(
                "materials must be a dict, a (lam_e, mu_e) array pair, "
                "or a sequence of dicts / pairs (one per scenario); "
                f"got {type(materials)!r}"
            ) from None
        return lam_e, mu_e

    def _bind_materials(self, lam_e, mu_e):
        """Set lam_w/mu_w from coefficient fields (traceable: fields may be
        jax tracers inside a jitted batched solve)."""
        lam_e = jnp.asarray(lam_e, dtype=self.dtype)
        mu_e = jnp.asarray(mu_e, dtype=self.dtype)
        if lam_e.shape != mu_e.shape or lam_e.shape[-1] != self.space.nelem:
            raise ValueError(
                f"material fields {lam_e.shape}/{mu_e.shape} do not match "
                f"nelem={self.space.nelem}"
            )
        if lam_e.ndim == 2:  # (S, nelem): fold scenarios into elements
            self.nbatch = lam_e.shape[0]
            lam_e = lam_e.reshape(-1)
            mu_e = mu_e.reshape(-1)
        elif lam_e.ndim == 1:
            self.nbatch = None
        else:
            raise ValueError(f"material fields must be 1D or 2D: {lam_e.shape}")
        self.lam_w = lam_e[:, None, None, None] * self.w_detj
        self.mu_w = mu_e[:, None, None, None] * self.w_detj

    def with_materials(self, lam_e, mu_e) -> "ElasticityOperator":
        """A shallow copy with new material coefficient fields ((nelem,) or
        (S, nelem)); geometry, tables and masks are shared.  Safe to call
        under jit with traced fields (matrix-free levels only)."""
        if self.assembly == "fa":
            raise ValueError("with_materials is matrix-free only (not 'fa')")
        new = copy.copy(self)
        new.materials = None
        new._bind_materials(lam_e, mu_e)
        return new

    def with_material_weights(
        self, lam_w, mu_w, nbatch: int | None
    ) -> "ElasticityOperator":
        """A shallow copy binding precomputed weighted fields
        (``lam_e * w_detj``) directly, skipping the quadrature-weight
        multiply.  The resumable batched solve keeps these per-scenario
        fields alive across chunk boundaries (in its prep pytree) and
        rebinds them on every chunk; for a scenario batch ``lam_w`` is
        the folded ``(S * nelem, Q, Q, Q)`` array and ``nbatch`` is S."""
        if self.assembly == "fa":
            raise ValueError("with_material_weights is matrix-free only")
        new = copy.copy(self)
        new.materials = None
        new.nbatch = nbatch
        new.lam_w = lam_w
        new.mu_w = mu_w
        return new

    def with_materials_rows(self, lam_e, mu_e, row_mask) -> "ElasticityOperator":
        """In-place per-scenario-row field update (functional): rows of the
        batched material fields selected by ``row_mask`` (S,) take freshly
        weighted fields from the ``(S, nelem)`` candidates; unselected rows
        keep this operator's current fields *bitwise* — refilling a batch
        slot must not perturb the scenarios still in flight.  Traceable."""
        if self.assembly == "fa":
            raise ValueError("with_materials_rows is matrix-free only")
        if self.nbatch is None:
            raise ValueError(
                "with_materials_rows requires a scenario-batched operator"
            )
        s, ne = self.nbatch, self.space.nelem
        lam_e = jnp.asarray(lam_e, dtype=self.dtype)
        mu_e = jnp.asarray(mu_e, dtype=self.dtype)
        if lam_e.shape != (s, ne) or mu_e.shape != (s, ne):
            raise ValueError(
                f"candidate fields {lam_e.shape}/{mu_e.shape} must be "
                f"({s}, {ne})"
            )
        mask = jnp.asarray(row_mask).reshape((s,) + (1,) * 4)

        def merge(old_w, cand_e):
            cand_w = cand_e.reshape(-1)[:, None, None, None] * self.w_detj
            tail = old_w.shape[1:]
            return jnp.where(
                mask,
                cand_w.reshape((s, ne) + tail),
                old_w.reshape((s, ne) + tail),
            ).reshape((s * ne,) + tail)

        new = copy.copy(self)
        new.materials = None
        new.lam_w = merge(self.lam_w, lam_e)
        new.mu_w = merge(self.mu_w, mu_e)
        return new

    # -- raw action ---------------------------------------------------------
    def _apply_evec(self, x_e):
        if self.lam_w is None:
            raise ValueError(
                "materials are deferred; bind them with with_materials first"
            )
        a = self.assembly
        if a == "pa_baseline":
            g3d = _base.dense_grad_table(self.space.p, dtype=self.dtype)
            return _base.pa_baseline_apply(x_e, self.lam_w, self.mu_w, self.jinv, g3d)
        if a == "pa_sumfact":
            return _sf.pa_sumfact_apply(
                x_e, self.lam_w, self.mu_w, self.jinv, self.B, self.G
            )
        if a == "pa_sumfact_voigt":
            return _sf.pa_sumfact_voigt_apply(
                x_e, self.lam_w, self.mu_w, self.jinv, self.B, self.G
            )
        if a == "paop":
            return _paop.paop_apply(
                x_e, self.lam_w, self.mu_w, self.jinv, self.B, self.G
            )
        if a == "paop_pallas":
            from repro.kernels.pa_elasticity import ops as _kops

            return _kops.pa_elasticity(
                x_e,
                self.lam_w,
                self.mu_w,
                self.jinv,
                self.B,
                self.G,
                lane=self.pallas_lane,
            )
        raise AssertionError(a)

    def apply(self, x):
        """Unconstrained y = A x on the L-vector (nscalar, 3), or the
        scenario batch (S, nscalar, 3) for a batched operator."""
        if self.assembly == "fa":
            y = self._sparse.matvec(x.reshape(-1))
            return y.reshape(x.shape)
        if self.nbatch is not None:
            s, ne = self.nbatch, self.space.nelem
            x = pin_scenario(x, self.shard_mesh)
            x_e = jax.vmap(self.space.to_evec)(x)  # (S, ne, 3, D, D, D)
            # Pin the folded (S*ne, ...) E-vector: each shard holds whole
            # scenarios, so the fused PA/Pallas kernel below is purely
            # shard-local.
            x_e = pin_scenario(
                x_e.reshape((s * ne,) + x_e.shape[2:]), self.shard_mesh
            )
            y_e = self._apply_evec(x_e)
            y_e = pin_scenario(y_e, self.shard_mesh)
            y_e = y_e.reshape((s, ne) + y_e.shape[1:])
            return pin_scenario(
                jax.vmap(self.space.scatter_add)(y_e), self.shard_mesh
            )
        x_e = self.space.to_evec(x)
        y_e = self._apply_evec(x_e)
        return self.space.scatter_add(y_e)

    def __call__(self, x):
        return self.apply(x)

    # -- diagonal -------------------------------------------------------------
    def diagonal(self):
        """Assembled operator diagonal as an L-vector (nscalar, 3), with a
        leading scenario axis for a batched operator."""
        if self.assembly == "fa":
            d = jnp.asarray(self._sparse.csr.diagonal(), dtype=self.dtype)
            return d.reshape(-1, 3)
        if self.lam_w is None:
            raise ValueError(
                "materials are deferred; bind them with with_materials first"
            )
        d_e = _diag.element_diagonal(self.lam_w, self.mu_w, self.jinv, self.B, self.G)
        if self.nbatch is not None:
            s, ne = self.nbatch, self.space.nelem
            d_e = pin_scenario(d_e, self.shard_mesh)
            d_e = d_e.reshape((s, ne) + d_e.shape[1:])
            return pin_scenario(
                jax.vmap(self.space.scatter_add)(d_e), self.shard_mesh
            )
        return self.space.scatter_add(d_e)

    # -- constrained view -------------------------------------------------------
    def constrained(self) -> ConstrainedOperator:
        return ConstrainedOperator(self.apply, self.ess_mask, self.diagonal)

    # -- introspection ------------------------------------------------------------
    def memory_bytes(self) -> int:
        """Stored-operator footprint: quadrature data D for PA levels, CSR
        for FA (paper Fig. 4 peak-memory comparison)."""
        if self.assembly == "fa":
            return self._sparse.memory_bytes()
        itemsize = jnp.dtype(self.dtype).itemsize
        return int(self.lam_w.size + self.mu_w.size + self.jinv.size) * itemsize
