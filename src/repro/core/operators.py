"""ElasticityOperator: the paper's contribution as a composable module.

One operator object per (mesh, degree) pair exposes every assembly level
of the ablation (Table 7) behind a single interface consumed by the
solvers:

    assembly in {"fa", "pa_baseline", "pa_sumfact", "pa_sumfact_voigt",
                 "paop", "paop_pallas"}

``apply(x)`` acts on the unconstrained L-vector (nscalar, 3);
``constrained()`` wraps it with MFEM ConstrainedOperator semantics and
the matrix-free diagonal for the Chebyshev-Jacobi smoother.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diagonal as _diag
from repro.core import fa as _fa
from repro.core import pa_baseline as _base
from repro.core import pa_sumfact as _sf
from repro.core import paop as _paop
from repro.core.basis import basis_tables
from repro.core.geometry import MATERIALS_BEAM, make_quadrature_data
from repro.fem.bc import ConstrainedOperator
from repro.fem.space import H1Space

__all__ = ["ElasticityOperator", "ASSEMBLY_LEVELS"]

ASSEMBLY_LEVELS = (
    "fa",
    "pa_baseline",
    "pa_sumfact",
    "pa_sumfact_voigt",
    "paop",
    "paop_pallas",
)


class ElasticityOperator:
    def __init__(
        self,
        space: H1Space,
        assembly: str = "paop",
        materials: dict[int, tuple[float, float]] | None = None,
        dtype=jnp.float64,
        ess_faces=("x0",),
        pallas_interpret: bool = True,
    ):
        if assembly not in ASSEMBLY_LEVELS:
            raise ValueError(f"unknown assembly level {assembly!r}")
        self.space = space
        self.assembly = assembly
        self.dtype = dtype
        self.materials = materials or MATERIALS_BEAM
        self.tables = space.tables
        self._pallas_interpret = pallas_interpret

        qd = make_quadrature_data(space.mesh, self.tables, self.materials)
        self.lam_w = jnp.asarray(qd.lambda_w, dtype=dtype)
        self.mu_w = jnp.asarray(qd.mu_w, dtype=dtype)
        self.jinv = jnp.asarray(qd.jinv, dtype=dtype)
        self.detj = qd.detj
        self.B = jnp.asarray(self.tables.B, dtype=dtype)
        self.G = jnp.asarray(self.tables.G, dtype=dtype)
        self.ess_mask = space.essential_mask(ess_faces)

        self._sparse: _fa.SparseMatrix | None = None
        if assembly == "fa":
            qd64 = qd  # setup in float64 regardless of operator dtype
            self._sparse = _fa.assemble_sparse(
                space, qd64, self.materials, ess_mask=None, dtype=dtype
            )

    # -- raw action ---------------------------------------------------------
    def _apply_evec(self, x_e):
        a = self.assembly
        if a == "pa_baseline":
            g3d = _base.dense_grad_table(self.space.p, dtype=self.dtype)
            return _base.pa_baseline_apply(x_e, self.lam_w, self.mu_w, self.jinv, g3d)
        if a == "pa_sumfact":
            return _sf.pa_sumfact_apply(
                x_e, self.lam_w, self.mu_w, self.jinv, self.B, self.G
            )
        if a == "pa_sumfact_voigt":
            return _sf.pa_sumfact_voigt_apply(
                x_e, self.lam_w, self.mu_w, self.jinv, self.B, self.G
            )
        if a == "paop":
            return _paop.paop_apply(
                x_e, self.lam_w, self.mu_w, self.jinv, self.B, self.G
            )
        if a == "paop_pallas":
            from repro.kernels.pa_elasticity import ops as _kops

            return _kops.pa_elasticity(
                x_e,
                self.lam_w,
                self.mu_w,
                self.jinv,
                self.B,
                self.G,
                interpret=self._pallas_interpret,
            )
        raise AssertionError(a)

    def apply(self, x):
        """Unconstrained y = A x on the L-vector (nscalar, 3)."""
        if self.assembly == "fa":
            y = self._sparse.matvec(x.reshape(-1))
            return y.reshape(x.shape)
        x_e = self.space.to_evec(x)
        y_e = self._apply_evec(x_e)
        return self.space.scatter_add(y_e)

    def __call__(self, x):
        return self.apply(x)

    # -- diagonal -------------------------------------------------------------
    def diagonal(self):
        """Assembled operator diagonal as an L-vector (nscalar, 3)."""
        if self.assembly == "fa":
            d = jnp.asarray(self._sparse.csr.diagonal(), dtype=self.dtype)
            return d.reshape(-1, 3)
        d_e = _diag.element_diagonal(self.lam_w, self.mu_w, self.jinv, self.B, self.G)
        return self.space.scatter_add(d_e)

    # -- constrained view -------------------------------------------------------
    def constrained(self) -> ConstrainedOperator:
        return ConstrainedOperator(self.apply, self.ess_mask, self.diagonal)

    # -- introspection ------------------------------------------------------------
    def memory_bytes(self) -> int:
        """Stored-operator footprint: quadrature data D for PA levels, CSR
        for FA (paper Fig. 4 peak-memory comparison)."""
        if self.assembly == "fa":
            return self._sparse.memory_bytes()
        itemsize = jnp.dtype(self.dtype).itemsize
        return int(self.lam_w.size + self.mu_w.size + self.jinv.size) * itemsize
