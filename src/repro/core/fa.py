"""Full Assembly (FA): the global sparse stiffness matrix the paper
compares against (Sec. 2.2.1) and the coarse-level matrix of the GMG
preconditioner (Sec. 3.2).

Element matrices are built from the dense 3D gradient table by quadrature
(O((p+1)^6) storage per element — the capacity limitation the paper
demonstrates with its OOM rows in Table 4), assembled into CSR with
scipy at setup, and applied either through scipy (host) or through a
jnp gather/segment-sum SpMV (device path used by solvers).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import jax.ops
import numpy as np
import scipy.sparse as sp

from repro.core.basis import BasisTables
from repro.core.geometry import QuadratureData
from repro.core.pa_baseline import _dense_grad_table_np
from repro.fem.space import H1Space

__all__ = ["element_matrix", "assemble_sparse", "SparseMatrix", "fa_memory_bytes"]


def _chat(jinv: np.ndarray, lam: float, mu: float) -> np.ndarray:
    """Reference-pulled-back elasticity tensor
    Chat[i, m, k, n] = sum_{j,l} Jinv[m,j] C_{ijkl} Jinv[n,l]
    for the isotropic C = lam d_ij d_kl + mu (d_ik d_jl + d_il d_jk)."""
    JJt = jinv @ jinv.T
    eye = np.eye(3)
    chat = (
        lam * np.einsum("mi,nk->imkn", jinv, jinv)
        + mu * np.einsum("ik,mn->imkn", eye, JJt)
        + mu * np.einsum("mk,ni->imkn", jinv, jinv)
    )
    return chat


def element_matrix(
    p: int, jinv: np.ndarray, detj: float, lam: float, mu: float
) -> np.ndarray:
    """Dense element stiffness matrix, shape (3*nd, 3*nd) with vdof
    ordering (node-major: dof = 3*node + comp)."""
    tb = BasisTables(p)
    g3 = _dense_grad_table_np(p)  # (3, nq, nd)
    w = tb.qwts
    w3 = (w[:, None, None] * w[None, :, None] * w[None, None, :]).reshape(-1)
    chat = _chat(jinv, lam, mu) * detj  # fold detJ; w folded below
    # K[(L,i),(M,k)] = sum_q w3[q] G3[m,q,L] Chat[i,m,k,n] G3[n,q,M]
    K = np.einsum("mqL,q,imkn,nqM->LiMk", g3, w3, chat, g3, optimize=True)
    nd = g3.shape[2]
    return K.reshape(3 * nd, 3 * nd)


@dataclasses.dataclass
class SparseMatrix:
    """CSR matrix with both a scipy handle (host ops, factorizations) and
    jnp index arrays for an on-device gather/segment-sum SpMV."""

    csr: sp.csr_matrix
    data: Any
    cols: Any
    rows: Any  # COO row per nonzero (sorted by row)
    n: int

    @classmethod
    def from_scipy(cls, m: sp.spmatrix, dtype=jnp.float64) -> "SparseMatrix":
        csr = m.tocsr()
        csr.sum_duplicates()
        coo = csr.tocoo()
        return cls(
            csr=csr,
            data=jnp.asarray(coo.data, dtype=dtype),
            cols=jnp.asarray(coo.col, dtype=jnp.int32),
            rows=jnp.asarray(coo.row, dtype=jnp.int32),
            n=csr.shape[0],
        )

    def matvec(self, x):
        """SpMV y = A x on device; x flat (n,)."""
        contrib = self.data * x[self.cols]
        return jax.ops.segment_sum(contrib, self.rows, num_segments=self.n)

    @property
    def nnz(self) -> int:
        return self.csr.nnz

    def memory_bytes(self) -> int:
        # CSR: data (8B) + col idx (4B) per nnz + row ptr.
        return self.nnz * 12 + (self.n + 1) * 4


def assemble_sparse(
    space: H1Space,
    qdata: QuadratureData,
    materials: dict[int, tuple[float, float]],
    ess_mask: np.ndarray | None = None,
    dtype=jnp.float64,
) -> SparseMatrix:
    """Assemble the global sparse stiffness matrix (vdof = 3*node + comp).

    With ``ess_mask`` the essential rows/cols are eliminated symmetrically
    (row/col zeroed, unit diagonal) — the assembled analog of
    ConstrainedOperator.
    """
    p = space.p
    jinv = np.asarray(qdata.jinv, dtype=np.float64)
    detj = qdata.detj
    kmats = {
        a: element_matrix(p, jinv, detj, lam, mu) for a, (lam, mu) in materials.items()
    }
    gid = space.gather_ids.reshape(space.nelem, -1)  # (ne, nd) node ids
    attr = space.mesh.attributes()
    nd = gid.shape[1]
    vdofs = (3 * gid[:, :, None] + np.arange(3)[None, None, :]).reshape(
        space.nelem, 3 * nd
    )

    blocks = np.empty((space.nelem, 3 * nd, 3 * nd))
    for a, K in kmats.items():
        blocks[attr == a] = K

    rows = np.repeat(vdofs, 3 * nd, axis=1).reshape(-1)
    cols = np.tile(vdofs, (1, 3 * nd)).reshape(-1)
    n = 3 * space.nscalar
    A = sp.coo_matrix((blocks.reshape(-1), (rows, cols)), shape=(n, n)).tocsr()
    A.sum_duplicates()

    if ess_mask is not None:
        ess = np.flatnonzero(ess_mask.reshape(-1))
        keep = np.ones(n, dtype=bool)
        keep[ess] = False
        D = sp.diags(keep.astype(np.float64))
        A = D @ A @ D + sp.diags((~keep).astype(np.float64))
        A = A.tocsr()
        A.eliminate_zeros()
    return SparseMatrix.from_scipy(A, dtype=dtype)


def fa_memory_bytes(space: H1Space) -> int:
    """Analytic FA storage estimate: each scalar row couples to
    O((p+1)^d) neighbours (paper Sec. 2.2.1)."""
    p = space.p
    per_row = 3 * (2 * p + 1) ** 3  # interior-node stencil width, vdim 3
    return space.ndof * per_row * 12
