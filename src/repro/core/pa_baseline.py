"""MFEM v4.8 baseline linear-elasticity PA dataflow (paper Algorithm 1).

Faithful reproduction of the two-kernel baseline:

* Kernel 1 computes the geometrically transformed, weighted stress at all
  quadrature points of all elements and writes it to the operator-wide
  ``QVec`` array (a real whole-mesh intermediate — the memory round trip
  the paper identifies as the first bottleneck).
* Kernel 2 re-reads ``QVec`` in full and contracts it against the dense 3D
  basis-gradient table ``G3D`` of size (3, Q1D^3, D1D^3) — the
  O((p+1)^6)-per-element contraction that keeps the baseline's
  operator-throughput sweet spot near p ~= 2.

Both the forward interpolation and the backward action use the dense table
(no sum factorization), matching the complexity the paper ascribes to the
v4.8 ElasticityAddMultPA path.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.basis import BasisTables

__all__ = ["dense_grad_table", "pa_baseline_apply"]


@functools.lru_cache(maxsize=None)
def _dense_grad_table_np(p: int, q1d: int | None = None) -> np.ndarray:
    tb = BasisTables(p, q1d)
    B, G = tb.B, tb.G
    # G3[m, (qz,qy,qx), (kz,ky,kx)] = prod of B/G with G along direction m.
    def outer3(tz, ty, tx):
        t = np.einsum("sc,rb,qa->srqcba", tz, ty, tx)
        n_q, n_d = tb.q1d ** 3, tb.d1d ** 3
        return t.reshape(n_q, n_d)

    g3 = np.stack([outer3(B, B, G), outer3(B, G, B), outer3(G, B, B)])
    return g3  # (3, nq, nd), float64


def dense_grad_table(p: int, q1d: int | None = None, dtype=jnp.float64):
    """Dense 3D reference-gradient basis table (3, Q1D^3, D1D^3)."""
    return jnp.asarray(_dense_grad_table_np(p, q1d), dtype=dtype)


def pa_baseline_apply(x_e, lam_w, mu_w, jinv, g3d):
    """Algorithm 1: y_e = A_e x_e with the dense-contraction dataflow.

    x_e:    (nelem, 3, D1D, D1D, D1D) element-local displacement
    lam_w:  (nelem, Q1D, Q1D, Q1D) = w det(J) lambda  (mu_w likewise)
    jinv:   (3, 3) or (nelem, 3, 3) per-element-constant J^{-1}
    g3d:    (3, Q1D^3, D1D^3) dense reference-gradient table
    returns (nelem, 3, D1D, D1D, D1D)
    """
    ne = x_e.shape[0]
    nd = g3d.shape[2]
    nq = g3d.shape[1]
    xf = x_e.reshape(ne, 3, nd)

    # ---- PhysDerivatives: dense O(p^6) interpolation of the gradient.
    grad_ref = jnp.einsum("mqL,ecL->ecmq", g3d, xf)  # (ne, 3, 3, nq)
    if jinv.ndim == 2:
        grad = jnp.einsum("ecmq,mj->ecjq", grad_ref, jinv)
    else:
        grad = jnp.einsum("ecmq,emj->ecjq", grad_ref, jinv)

    # ---- Kernel 1: stress at quadrature points -> operator-wide QVec.
    lw = lam_w.reshape(ne, nq)
    mw = mu_w.reshape(ne, nq)
    div = grad[:, 0, 0] + grad[:, 1, 1] + grad[:, 2, 2]  # (ne, nq)
    eye = jnp.eye(3, dtype=x_e.dtype)
    sym = grad + jnp.swapaxes(grad, 1, 2)  # 2 eps
    sigma = (
        lw[:, None, None, :] * div[:, None, None, :] * eye[None, :, :, None]
        + mw[:, None, None, :] * sym
    )
    # Pull back to reference test-directions: QVec[c, m] = sigma[c, j] Jinv[m, j].
    if jinv.ndim == 2:
        qvec = jnp.einsum("ecjq,mj->ecmq", sigma, jinv)
    else:
        qvec = jnp.einsum("ecjq,emj->ecmq", sigma, jinv)

    # ---- Kernel 2: dense O(p^6) operator action, streaming G3D again.
    y = jnp.einsum("ecmq,mqL->ecL", qvec, g3d)
    return y.reshape(x_e.shape)
