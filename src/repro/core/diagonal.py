"""Matrix-free operator diagonal (MFEM AssembleDiagonal analog).

The Chebyshev-Jacobi smoother needs diag(A) without assembling A.  For
the elasticity operator the (c, node)-diagonal of the element matrix is

    diag_e[c, ijk] = sum_{m,n} Chat[c,m,c,n](e,q) *
                     U^mn_x(qx,i) U^mn_y(qy,j) U^mn_z(qz,k)   summed over q

with U^mn_d = T^m_d . T^n_d elementwise products of the 1D tables
(T^m_d = G if d == m else B), because the squared basis-gradient products
stay separable per direction.  Cost is O((p+1)^4) per element — the same
complexity class as one operator application, evaluated once at setup.

Chat is the pulled-back isotropic tensor
    Chat[c,m,c,n] = lam_w Jinv[m,c] Jinv[n,c]
                  + mu_w ((Jinv Jinv^T)[m,n] + Jinv[m,c] Jinv[n,c]).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["element_diagonal"]


def element_diagonal(lam_w, mu_w, jinv, B, G):
    """Per-element diagonal, shape (nelem, 3, D1D, D1D, D1D).

    lam_w / mu_w: (nelem, Q1D, Q1D, Q1D); jinv: (3, 3) (affine, shared) or
    (nelem, 3, 3).
    """
    per_elem_j = jinv.ndim == 3
    jjt = (
        jnp.einsum("emj,enj->emn", jinv, jinv)
        if per_elem_j
        else jinv @ jinv.T
    )

    tables = (G, B)  # index by (d == m)

    def u_table(axis, m, n):
        tm = tables[0] if axis == m else tables[1]
        tn = tables[0] if axis == n else tables[1]
        return tm * tn  # (Q1D, D1D) elementwise

    out = 0.0
    for m in range(3):
        for n in range(3):
            ux = u_table(0, m, n)
            uy = u_table(1, m, n)
            uz = u_table(2, m, n)
            s_lam = jnp.einsum("ezyx,zc,yb,xa->ecba", lam_w, uz, uy, ux)
            s_mu = jnp.einsum("ezyx,zc,yb,xa->ecba", mu_w, uz, uy, ux)
            if per_elem_j:
                coef_c = jinv[:, m, :] * jinv[:, n, :]  # (ne, 3)
                out = out + coef_c[:, :, None, None, None] * (
                    s_lam[:, None] + s_mu[:, None]
                )
                out = out + jjt[:, m, n][:, None, None, None, None] * s_mu[:, None]
            else:
                coef_c = jinv[m] * jinv[n]  # (3,)
                out = out + coef_c[None, :, None, None, None] * (
                    s_lam[:, None] + s_mu[:, None]
                )
                out = out + jjt[m, n] * s_mu[:, None]
    return out
