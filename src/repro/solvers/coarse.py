"""Coarsest-level solver for the GMG hierarchy (paper Sec. 3.2).

The paper assembles only the coarsest-level sparse matrix and solves it
with inexact PCG preconditioned by BoomerAMG (rel_tol = sqrt(1e-4),
max 10 iterations).  Classical AMG setup is CPU-shaped (irregular sparse
graph coarsening); on the TPU target we keep the paper's architecture —
assemble only the coarsest matrix — and swap the inner solver for either

* ``cholesky``: a prefactorized dense Cholesky solve (exact, jit-friendly,
  and cheap because the coarsest level is small by construction), or
* ``pcg_jacobi``: the paper's inexact inner PCG with a Jacobi
  preconditioner (matching tolerances), for larger coarse levels.

The deviation is recorded in DESIGN.md (hardware-adaptation notes).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np
import scipy.linalg as sla

from repro.core.fa import SparseMatrix, assemble_sparse
from repro.core.operators import ElasticityOperator
from repro.distributed.sharding import pin_scenario
from repro.solvers.cg import pcg

__all__ = [
    "make_coarse_solver",
    "make_batched_coarse_solver",
    "probe_coarse_matrix",
    "cholesky_solver",
]


def probe_coarse_matrix(cop, nscalar: int, nbatch: int, dtype, shard_mesh=None):
    """Densify a scenario-batched constrained coarse operator by probing
    it with identity columns: returns the (S, n, n) stack of per-scenario
    coarse matrices (n = nscalar * 3).  Pure jax, so it traces — a jitted
    batched solve can take per-scenario materials as runtime arguments
    and still assemble its coarse level inside the same device program.

    ``shard_mesh`` pins each broadcast probe vector (and the resulting
    matrix stack) to scenario-axis sharding, so every device probes only
    its own scenarios' coarse matrices."""
    n = nscalar * 3

    def col(e):
        xb = jnp.broadcast_to(e.reshape(nscalar, 3), (nbatch, nscalar, 3))
        xb = pin_scenario(xb, shard_mesh)
        return cop(xb).reshape(nbatch, n)

    cols = jax.vmap(col)(jnp.eye(n, dtype=dtype))  # (n_j, S, n_i)
    return pin_scenario(jnp.moveaxis(cols, 0, -1), shard_mesh)  # (S, i, j)


def cholesky_solver(L, shard_mesh=None) -> Callable:
    """solve(b) from a prefactorized batched lower-Cholesky stack
    (S, n, n).  The factor is plain array data, so the resumable batched
    solve can carry it across chunk boundaries in its prep pytree.
    ``shard_mesh`` pins the per-scenario triangular solves shard-local
    (each device factors-solves only its own scenarios)."""

    def solve(b):
        nbatch, n = L.shape[0], L.shape[1]
        flat = pin_scenario(b.reshape(nbatch, n), shard_mesh)
        x = jax.vmap(lambda Ls, bs: jsl.cho_solve((Ls, True), bs))(L, flat)
        return pin_scenario(x, shard_mesh).reshape(b.shape)

    return solve


def make_batched_coarse_solver(cop, nscalar: int, nbatch: int, dtype) -> Callable:
    """Dense Cholesky coarse solve for a scenario-batched constrained
    operator: probe the per-scenario matrices, factor them in-trace
    (batched cholesky), and return the prefactorized solve.  The coarsest
    level is small by construction (paper Sec. 3.2), so the n probing
    applications are cheap relative to one fine-level operator action."""
    K = probe_coarse_matrix(cop, nscalar, nbatch, dtype)
    return cholesky_solver(jnp.linalg.cholesky(K))


def make_coarse_solver(
    op: ElasticityOperator,
    method: str = "cholesky",
    rel_tol: float = 1e-2,
    max_iter: int = 10,
) -> Callable:
    """Return solve(b) -> x for the constrained coarsest-level system."""
    space = op.space
    if op.nbatch is not None:
        # Scenario batch: per-scenario materials need per-scenario factors.
        if method != "cholesky":
            raise NotImplementedError(
                f"batched coarse solve supports only 'cholesky', got {method!r}"
            )
        return make_batched_coarse_solver(
            op.constrained(), space.nscalar, op.nbatch, op.dtype
        )
    ess = np.asarray(op.ess_mask)

    if method == "cholesky":
        if isinstance(op.materials, dict):
            qd_materials = op.materials
            from repro.core.geometry import make_quadrature_data

            qd = make_quadrature_data(space.mesh, space.tables, qd_materials)
            sm: SparseMatrix = assemble_sparse(
                space, qd, qd_materials, ess_mask=ess, dtype=op.dtype
            )
            dense = np.asarray(sm.csr.todense())
        else:
            # Per-element (lam_e, mu_e) fields have no attribute dict for
            # the scipy assembly; probe the constrained operator with
            # identity columns instead (the coarse level is small).
            cop = op.constrained()
            n = space.nscalar * 3
            cols = jax.vmap(
                lambda e: cop(e.reshape(space.nscalar, 3)).reshape(n)
            )(jnp.eye(n, dtype=op.dtype))
            dense = np.asarray(cols).T
        cho = sla.cho_factor(dense)
        c_jnp = jnp.asarray(cho[0], dtype=op.dtype)
        lower = cho[1]

        def solve(b):
            x = jsl.cho_solve((c_jnp, lower), b.reshape(-1))
            return x.reshape(b.shape)

        return solve

    if method == "pcg_jacobi":
        cop = op.constrained()
        dinv = 1.0 / cop.diagonal()

        def solve(b):
            res = pcg(
                cop,
                b,
                M=lambda r: dinv * r,
                rel_tol=rel_tol,
                maxiter=max_iter,
            )
            return res.x

        return solve

    raise ValueError(f"unknown coarse solver {method!r}")
