"""Chebyshev-accelerated Jacobi smoother (MFEM OperatorChebyshevSmoother
analog; paper Sec. 3.1).

Requires only the operator action and its diagonal.  lambda_max of
D^{-1} A is estimated with a fixed number of power iterations (paper: 10)
at setup; the polynomial acts on the interval
[eig_lo_frac * hi, eig_hi_frac * lambda_max] (0.3 / 1.1 — the customary
matrix-free multigrid choice).  Degree k = 2 by default, one pre- and one
post-smoothing per V(1,1) cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["ChebyshevSmoother", "power_iteration_lmax"]


def power_iteration_lmax(A: Callable, dinv, shape, dtype, iters: int = 10):
    """Estimate lambda_max(D^{-1} A) with deterministic power iterations."""
    key = jax.random.PRNGKey(1234)
    v = jax.random.normal(key, shape, dtype=dtype)

    def body(_, carry):
        v, lam = carry
        v = v / jnp.linalg.norm(v.reshape(-1))
        w = dinv * A(v)
        lam = jnp.vdot(v.reshape(-1), w.reshape(-1))
        return (w, lam)

    v, lam = jax.lax.fori_loop(0, iters, body, (v, jnp.asarray(0.0, dtype)))
    return jnp.abs(lam)


@dataclasses.dataclass
class ChebyshevSmoother:
    """x <- x + p_k(D^{-1} A) D^{-1} (b - A x), Chebyshev on [lo, hi]."""

    A: Callable
    dinv: Any
    lmax: Any
    degree: int = 2
    eig_lo_frac: float = 0.3
    eig_hi_frac: float = 1.1

    @classmethod
    def setup(cls, A, diagonal, shape, dtype, degree=2, power_iters=10):
        dinv = 1.0 / diagonal
        lmax = power_iteration_lmax(A, dinv, shape, dtype, power_iters)
        return cls(A=A, dinv=dinv, lmax=lmax, degree=degree)

    def __call__(self, b, x=None):
        """Apply ``degree`` Chebyshev-Jacobi steps to A x = b."""
        hi = self.eig_hi_frac * self.lmax
        lo = self.eig_lo_frac * hi
        theta = 0.5 * (hi + lo)
        delta = 0.5 * (hi - lo)
        sigma = theta / delta

        if x is None:
            x = jnp.zeros_like(b)
            r = b
        else:
            r = b - self.A(x)
        z = self.dinv * r
        d = z / theta
        rho = 1.0 / sigma
        for _ in range(self.degree):
            x = x + d
            r = r - self.A(d)
            z = self.dinv * r
            rho_new = 1.0 / (2.0 * sigma - rho)
            d = rho_new * rho * d + (2.0 * rho_new / delta) * z
            rho = rho_new
        return x
