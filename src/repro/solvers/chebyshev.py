"""Chebyshev-accelerated Jacobi smoother (MFEM OperatorChebyshevSmoother
analog; paper Sec. 3.1).

Requires only the operator action and its diagonal.  lambda_max of
D^{-1} A is estimated with a fixed number of power iterations (paper: 10)
at setup; the polynomial acts on the interval
[eig_lo_frac * hi, eig_hi_frac * lambda_max] (0.3 / 1.1 — the customary
matrix-free multigrid choice).  Degree k = 2 by default, one pre- and one
post-smoothing per V(1,1) cycle.

Scenario batching: with ``batch_dims=1`` the operator, diagonal and
vectors carry a leading scenario axis (S, ...) and lambda_max is
estimated per scenario; the Chebyshev recurrence coefficients become
(S,)-shaped and broadcast over each scenario's vector block, so one
smoother application advances every scenario in lockstep.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import pin_scenario

__all__ = ["ChebyshevSmoother", "power_iteration_lmax"]


def _expand(a, ndim: int):
    """Right-pad ``a`` with singleton axes so it broadcasts against an
    ndim-dimensional vector block ((S,) coefficients vs (S, n, 3))."""
    a = jnp.asarray(a)
    return a.reshape(a.shape + (1,) * (ndim - a.ndim))


def power_iteration_lmax(
    A: Callable, dinv, shape, dtype, iters: int = 10, batch_dims: int = 0,
    shard_mesh=None,
):
    """Estimate lambda_max(D^{-1} A) with deterministic power iterations.

    With ``batch_dims=1`` the leading axis of ``shape`` is a scenario
    batch: normalization and the Rayleigh quotient are taken per scenario
    and the estimate has shape ``shape[:batch_dims]``.  The start vector
    is drawn at the per-scenario shape and broadcast, so each batched row
    runs exactly the iteration its scalar counterpart would.

    ``shard_mesh`` (a scenario-axis device mesh) pins the broadcast start
    vector to axis-0 sharding, keeping the whole iteration shard-local:
    the per-row norms/Rayleigh quotients reduce within a shard, so the
    estimate is bitwise the single-device one.
    """
    key = jax.random.PRNGKey(1234)
    v = jax.random.normal(key, shape[batch_dims:], dtype=dtype)
    v = jnp.broadcast_to(v, shape)
    if batch_dims:  # axis 0 is the scenario batch
        v = pin_scenario(v, shard_mesh)
    axes = tuple(range(batch_dims, v.ndim))

    def body(_, carry):
        v, lam = carry
        nrm = jnp.sqrt(jnp.sum(v * v, axis=axes))
        v = v / _expand(nrm, v.ndim)
        w = dinv * A(v)
        lam = jnp.sum(v * w, axis=axes)
        return (w, lam)

    lam0 = jnp.zeros(shape[:batch_dims], dtype)
    v, lam = jax.lax.fori_loop(0, iters, body, (v, lam0))
    return jnp.abs(lam)


@dataclasses.dataclass
class ChebyshevSmoother:
    """x <- x + p_k(D^{-1} A) D^{-1} (b - A x), Chebyshev on [lo, hi].

    ``lmax`` is a scalar for a single scenario or (S,) for a scenario
    batch (matching a (S, n, 3) vector block).
    """

    A: Callable
    dinv: Any
    lmax: Any
    degree: int = 2
    eig_lo_frac: float = 0.3
    eig_hi_frac: float = 1.1

    @classmethod
    def setup(cls, A, diagonal, shape, dtype, degree=2, power_iters=10,
              batch_dims=0, shard_mesh=None):
        # Essential-BC rows carry an identity diagonal by construction
        # (ConstrainedOperator.diagonal), but a zero slipping through —
        # e.g. a degenerate padded row — must not poison dinv with inf.
        diagonal = jnp.asarray(diagonal)
        safe = jnp.where(diagonal == 0, jnp.ones_like(diagonal), diagonal)
        dinv = 1.0 / safe
        lmax = power_iteration_lmax(
            A, dinv, shape, dtype, power_iters, batch_dims=batch_dims,
            shard_mesh=shard_mesh,
        )
        return cls(A=A, dinv=dinv, lmax=lmax, degree=degree)

    def __call__(self, b, x=None):
        """Apply ``degree`` Chebyshev-Jacobi steps to A x = b."""
        # Coefficients live in the vector-block dtype, not lmax's: an
        # f32 lmax estimated at setup against f64 blocks (or the mixed
        # policy's f64 lmax against f32 blocks) must neither demote the
        # recurrence nor silently promote every d/z update.
        hi = self.eig_hi_frac * jnp.asarray(self.lmax, dtype=b.dtype)
        lo = self.eig_lo_frac * hi
        theta = 0.5 * (hi + lo)
        delta = 0.5 * (hi - lo)
        sigma = theta / delta

        if x is None:
            x = jnp.zeros_like(b)
            r = b
        else:
            r = b - self.A(x)
        z = self.dinv * r
        d = z / _expand(theta, b.ndim)
        rho = 1.0 / sigma
        for _ in range(self.degree):
            x = x + d
            r = r - self.A(d)
            z = self.dinv * r
            rho_new = 1.0 / (2.0 * sigma - rho)
            d = _expand(rho_new * rho, b.ndim) * d + (
                2.0 * _expand(rho_new, b.ndim) / _expand(delta, b.ndim)
            ) * z
            rho = rho_new
        return x
