"""Preconditioned conjugate gradients with MFEM CGSolver semantics.

For preconditioned solves MFEM tests (B r_k, r_k)^{1/2} / (B r_0, r_0)^{1/2}
<= rel_tol (paper Sec. 3.2); iteration capped at ``maxiter`` (5000 in the
paper, never reached).  Implemented with ``jax.lax.while_loop`` so the
whole solve stays on device; also usable un-jitted with Python callables.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["pcg", "PCGResult"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PCGResult:
    x: Any
    iterations: Any
    converged: Any
    final_norm: Any  # sqrt((B r, r)) at exit
    initial_norm: Any


def _dot(a, b):
    return jnp.vdot(a.reshape(-1), b.reshape(-1))


def pcg(
    A: Callable,
    b,
    M: Callable | None = None,
    *,
    x0=None,
    rel_tol: float = 1e-6,
    abs_tol: float = 0.0,
    maxiter: int = 5000,
) -> PCGResult:
    """MFEM-style PCG. ``A`` and ``M`` map L-vectors to L-vectors."""
    if M is None:
        M = lambda r: r
    x = jnp.zeros_like(b) if x0 is None else x0

    r = b - A(x)
    z = M(r)
    nom0 = _dot(z, r)
    # MFEM: r0 = max(nom0 * rel_tol^2, abs_tol^2).  A zero RHS (or an x0
    # that already solves the system) gives nom0 == 0 <= threshold, so the
    # loop below never runs and the solve reports converged immediately.
    threshold = jnp.maximum(nom0 * rel_tol ** 2, abs_tol ** 2)

    def cond(state):
        _, _, _, _, nom, k, stop = state
        return (nom > threshold) & (k < maxiter) & ~stop

    def body(state):
        x, r, _, d, nom, k, _ = state
        ad = A(d)
        den = _dot(d, ad)
        # den <= 0 means a degenerate direction (non-SPD input, or an
        # exactly-converged state): take no step and stop, mirroring
        # MFEM's "PCG: The operator is not positive definite" break,
        # instead of NaN-ing x or walking a negative curvature direction.
        bad = den <= 0
        alpha = jnp.where(bad, 0.0, nom / jnp.where(bad, 1.0, den))
        x = x + alpha * d
        r = r - alpha * ad
        z = M(r)
        betanom = _dot(z, r)
        beta = betanom / jnp.where(nom == 0, 1.0, nom)
        d = jnp.where(bad, d, z + beta * d)
        k = k + jnp.where(bad, 0, 1).astype(jnp.int32)
        return (x, r, z, d, betanom, k, bad)

    state = (
        x, r, z, z, nom0, jnp.asarray(0, dtype=jnp.int32),
        jnp.asarray(False),
    )
    x, r, z, d, nom, k, _ = jax.lax.while_loop(cond, body, state)
    return PCGResult(
        x=x,
        iterations=k,
        converged=nom <= threshold,
        final_norm=jnp.sqrt(jnp.abs(nom)),
        initial_norm=jnp.sqrt(jnp.abs(nom0)),
    )
