"""Batched multi-scenario GMG-PCG: many parameterized elasticity solves
in one device program, resumable in bounded chunks.

The paper's end-to-end solve (fused PAop operator + GMG-preconditioned
CG) runs one scenario at a time; this module amortizes compilation and
hardware occupancy across a *batch* of scenarios (different materials,
tractions, tolerances) the way the LM serving engine batches decode
requests:

* ``bpcg`` — PCG over a leading scenario axis.  Per-scenario convergence
  is tracked with an active mask: converged scenarios' ``x``/``r``/``d``
  are frozen (their step sizes are forced to zero and direction updates
  gated), the loop runs until every scenario converges or hits
  ``maxiter``, and per-scenario iteration counts are reported.

* the resumable step program — ``bpcg`` is split into
  :func:`bpcg_init` (build a pinned-shape :class:`BpcgState`) and
  :func:`bpcg_chunk` (advance all rows by a bounded number of
  iterations).  Because frozen rows never change, running chunks of
  ``k1`` then ``k2`` iterations produces exactly the state of one
  uninterrupted ``k1 + k2`` run, which is what lets a serving layer
  retire converged rows and refill their slots *between* chunks
  (continuous batching) instead of waiting for a whole generation.
  :func:`merge_states` resets just the refilled rows; untouched rows
  keep their state bitwise.

* ``BatchedGMGSolver`` — compiled solve *programs* for one
  discretization ``(coarse_mesh, n_h_refine, p)``.  Geometry (spaces,
  transfers, gather maps, basis tables, traction pattern) is built once
  at construction; materials, tractions and tolerances are **runtime
  arguments**.  Two jitted entry points drive the step program:
  ``prepare`` folds (new) per-scenario materials into the operators'
  per-row weighted fields in place and recomputes the derived
  per-scenario data (smoother diagonals + lambda_max, the coarse
  Cholesky factor) for exactly the reset rows; ``run_chunk`` rebuilds
  the hierarchy from that prep pytree (no power iterations, no
  refactorization), advances the state by ``k`` iterations and reports
  the per-row iterations consumed (the retire-cadence signal the
  adaptive chunk policies in :mod:`repro.serve.chunk_policy` use).  The
  monolithic ``solve`` is the same machinery run to completion in one
  call.  Re-solving with new scenario data hits the compiled programs —
  no retrace, no hierarchy rebuild.

The scenario axis is threaded through ``ChebyshevSmoother``,
``GMGPreconditioner`` and ``Transfer``; operators fold it into the
element axis so the fused PA kernels (including Pallas) run unchanged
on an S-times-larger grid.

Multi-device sharding: ``BatchedGMGSolver(..., mesh=...)`` (a 1-D
``jax.sharding`` mesh over the scenario axis, or an int meaning "the
first n devices") shards the scenario axis S across devices end to
end — the :class:`BpcgState` pytree, the prep pytree (weighted material
fields, smoother dinv/lambda_max, coarse Cholesky factors) and the
operators' folded (S*E, ...) element arrays all carry axis-0
``NamedSharding``.  Scenarios never couple, so each device runs the
exact single-device program on its own rows; the only cross-device
traffic is the (S,)-vector convergence logic of ``bpcg`` (cheap
all-gathers).  ``solve`` pads S up to a multiple of the device count
with born-converged rows (zero traction) and slices them back off, so
sharding is a pure implementation detail: results, iteration counts and
convergence flags are identical to the single-device path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import DEFER_MATERIALS, ElasticityOperator
from repro.kernels.pa_elasticity.ops import resolve_lane
from repro.distributed.sharding import (
    device_put_scenario,
    normalize_scenario_mesh,
    pin_scenario,
)
from repro.core.geometry import (
    check_material_dict,
    check_material_fields,
    material_fields,
)
from repro.fem.mesh import HexMesh, fine_descendants
from repro.fem.space import H1Space
from repro.fem.transfer import make_transfer
from repro.solvers.chebyshev import ChebyshevSmoother, _expand
from repro.solvers.coarse import cholesky_solver, probe_coarse_matrix
from repro.solvers.gmg import GMGPreconditioner, Level, hierarchy_spaces

__all__ = [
    "bpcg",
    "bpcg_init",
    "bpcg_chunk",
    "bpcg_result",
    "merge_states",
    "BpcgState",
    "BPCGResult",
    "BatchedGMGSolver",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BPCGResult:
    x: Any  # (S, ...) solutions
    iterations: Any  # (S,) int32 per-scenario counts
    converged: Any  # (S,) bool
    final_norm: Any  # (S,) sqrt((B r, r)) at exit
    initial_norm: Any  # (S,)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BpcgState:
    """Pinned-shape resumable PCG state (one row per batch slot).

    Everything the iteration needs lives here, so a compiled
    ``run_chunk(state, k)`` can advance the batch, hand the state back to
    the host for retire/refill decisions, and resume bit-identically."""

    x: Any  # (S, ...) iterates
    r: Any  # (S, ...) residuals
    z: Any  # (S, ...) preconditioned residuals
    d: Any  # (S, ...) search directions
    nom: Any  # (S,) current (B r, r)
    nom0: Any  # (S,) (B r, r) at the row's (re)start
    threshold: Any  # (S,) per-row stopping value for nom
    iters: Any  # (S,) int32 iterations since the row's (re)start
    active: Any  # (S,) bool — still iterating


def _dots(a, b):
    """Per-scenario inner products: contract everything but axis 0."""
    return jnp.sum(
        a.reshape(a.shape[0], -1) * b.reshape(b.shape[0], -1), axis=1
    )


# (S,) coefficients broadcast against (S, ...) vectors with the same
# right-pad rule the batched Chebyshev smoother uses.
_col = _expand


def bpcg_init(
    A: Callable,
    b,
    M: Callable | None = None,
    *,
    x0=None,
    rel_tol=1e-6,
    abs_tol=0.0,
) -> BpcgState:
    """Build the initial :class:`BpcgState` for ``A x = b``.

    MFEM-style thresholds, per scenario: a row stops when
    ``nom <= max(nom0 * rel_tol^2, abs_tol^2)``; ``rel_tol``/``abs_tol``
    may be scalars or (S,) arrays.  A row with a zero RHS is born
    converged (0 iterations) — this is also what makes padded batch
    slots free."""
    if M is None:
        M = lambda r: r
    s = b.shape[0]
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b  # A is linear: A(0) == 0 exactly
    else:
        x = x0
        r = b - A(x)
    z = M(r)
    nom0 = _dots(z, r)
    rel = jnp.broadcast_to(jnp.asarray(rel_tol, dtype=nom0.dtype), (s,))
    ab = jnp.broadcast_to(jnp.asarray(abs_tol, dtype=nom0.dtype), (s,))
    threshold = jnp.maximum(nom0 * rel**2, ab**2)
    return BpcgState(
        x=x,
        r=r,
        z=z,
        d=z,
        nom=nom0,
        nom0=nom0,
        threshold=threshold,
        iters=jnp.zeros((s,), dtype=jnp.int32),
        active=nom0 > threshold,
    )


def bpcg_chunk(
    A: Callable,
    state: BpcgState,
    M: Callable | None = None,
    *,
    k_iters=None,
    maxiter: int = 5000,
) -> BpcgState:
    """Advance every active row by up to ``k_iters`` PCG iterations
    (unbounded — run to convergence/``maxiter`` — when ``k_iters`` is
    None).

    Chunked resumption is exact: inactive rows are frozen (alpha forced
    to 0, direction updates gated), so ``chunk(k1)`` followed by
    ``chunk(k2)`` yields the same state as one ``chunk(k1 + k2)`` call.
    ``k_iters`` may be a traced value, so one compiled program serves
    every chunk length."""
    if M is None:
        M = lambda r: r

    def cond(carry):
        st, step = carry
        go = jnp.any(st.active)
        if k_iters is not None:
            go = go & (step < k_iters)
        return go

    def body(carry):
        st, step = carry
        x, r, nom, active = st.x, st.r, st.nom, st.active
        ad = A(st.d)
        den = _dots(st.d, ad)
        # Inactive rows get alpha = 0 (frozen); den == 0 cannot occur for
        # an active SPD row (d != 0 there) but is guarded so one bad or
        # retired scenario can never NaN the rest of the batch.
        ok = active & (den > 0)
        alpha = jnp.where(ok, nom / jnp.where(den == 0, 1.0, den), 0.0)
        x = x + _col(alpha, x.ndim) * st.d
        r = r - _col(alpha, r.ndim) * ad
        z = M(r)
        betanom = _dots(z, r)
        beta = jnp.where(ok, betanom / jnp.where(nom == 0, 1.0, nom), 0.0)
        d = jnp.where(
            _col(active, st.d.ndim), z + _col(beta, st.d.ndim) * st.d, st.d
        )
        nom = jnp.where(active, betanom, nom)
        # Count only real steps (ok), matching scalar pcg: an aborted
        # degenerate direction (den <= 0) takes no step and adds none.
        iters = st.iters + ok.astype(jnp.int32)
        active = ok & (nom > st.threshold) & (iters < maxiter)
        new = dataclasses.replace(
            st, x=x, r=r, z=z, d=d, nom=nom, iters=iters, active=active
        )
        return (new, step + 1)

    state, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), dtype=jnp.int32))
    )
    return state


def merge_states(reset_mask, fresh: BpcgState, old: BpcgState) -> BpcgState:
    """Per-row state merge: rows selected by ``reset_mask`` (S,) take
    ``fresh`` (a just-initialized state for their new RHS/tolerance),
    the rest keep ``old`` bitwise — refilling a slot must not perturb
    the rows still in flight."""
    mask = jnp.asarray(reset_mask)

    def pick(f, o):
        return jnp.where(_col(mask, jnp.ndim(f)), f, o)

    return BpcgState(
        **{
            fld.name: pick(getattr(fresh, fld.name), getattr(old, fld.name))
            for fld in dataclasses.fields(BpcgState)
        }
    )


def bpcg_result(state: BpcgState) -> BPCGResult:
    return BPCGResult(
        x=state.x,
        iterations=state.iters,
        converged=state.nom <= state.threshold,
        final_norm=jnp.sqrt(jnp.abs(state.nom)),
        initial_norm=jnp.sqrt(jnp.abs(state.nom0)),
    )


def bpcg(
    A: Callable,
    b,
    M: Callable | None = None,
    *,
    x0=None,
    rel_tol=1e-6,
    abs_tol=0.0,
    maxiter: int = 5000,
) -> BPCGResult:
    """MFEM-style PCG over a leading scenario axis with masked
    convergence.

    ``A`` and ``M`` map (S, ...) batches to (S, ...) batches with no
    cross-scenario coupling; ``rel_tol``/``abs_tol`` may be scalars or
    (S,) arrays (per-scenario tolerances).  Scenarios that converge stop
    updating while the rest keep iterating; the loop exits when no
    scenario is active.  Implemented as the resumable step program run
    in one uninterrupted chunk (see :func:`bpcg_init` /
    :func:`bpcg_chunk`)."""
    state = bpcg_init(A, b, M, x0=x0, rel_tol=rel_tol, abs_tol=abs_tol)
    state = bpcg_chunk(A, state, M, k_iters=None, maxiter=maxiter)
    return bpcg_result(state)


class BatchedGMGSolver:
    """Compiled multi-scenario solve programs for one discretization.

    Construction builds everything material-independent for the beam
    benchmark family: the mesh/degree hierarchy, transfer operators,
    per-level fine-descendant maps, and the boundary traction pattern.
    ``solve`` takes per-scenario materials (attribute dicts and/or
    per-element (lam_e, mu_e) coefficient arrays — see
    :meth:`pack_materials`), traction vectors and tolerances and runs to
    completion; ``prepare`` + ``run_chunk`` expose the same solve as a
    resumable step program for continuous batching.  Each jitted entry
    point is traced once per batch size (bucket) and reused for every
    subsequent call of the same shape.
    """

    def __init__(
        self,
        coarse_mesh: HexMesh,
        n_h_refine: int,
        p_target: int,
        *,
        assembly: str = "paop",
        dtype=jnp.float64,
        cheb_degree: int = 2,
        power_iters: int = 10,
        ess_faces=("x0",),
        traction_face: str = "x1",
        maxiter: int = 200,
        pallas_interpret: bool | None = None,
        pallas_lane: str | None = None,
        mesh=None,
    ):
        if assembly == "fa":
            raise ValueError("batched solves are matrix-free ('fa' unsupported)")
        self.coarse_mesh = coarse_mesh
        self.n_h_refine = n_h_refine
        self.p_target = p_target
        self.assembly = assembly
        self.dtype = dtype
        self.cheb_degree = cheb_degree
        self.power_iters = power_iters
        self.maxiter = maxiter
        # Pallas lane, resolved ONCE here so every level operator runs
        # the same lane and ``self.pallas_lane`` reports what actually
        # runs ("compiled" or "interpret"; auto falls back to interpret
        # on backends that cannot lower Pallas natively).
        self.pallas_lane = resolve_lane(pallas_lane, interpret=pallas_interpret)
        # Scenario-axis device mesh (None = single-device).  An int is
        # shorthand for "shard over the first n devices".
        self.mesh, self.n_shards = normalize_scenario_mesh(mesh)

        spaces = hierarchy_spaces(coarse_mesh, n_h_refine, p_target)
        self.spaces = spaces

        # Attribute vocabulary (static): kept for validating attribute-
        # dict scenarios against the mesh (pack_materials).
        self.attr_values: tuple[int, ...] = tuple(
            int(a) for a in np.unique(coarse_mesh.attributes())
        )

        # Scenario materials travel as (S, nelem_fine) per-element
        # coefficient fields (attribute dicts are expanded on intake by
        # pack_materials).  Each coarser h-level sees the fine field
        # through its fine-descendant map — an exact power-of-two tree
        # average (see _restrict_field); p-embedding levels share the
        # fine mesh, so their map is the identity (stored as None).
        fine_mesh = spaces[-1].mesh
        self._base_ops = []
        self._desc_idx: list[Any] = []
        for i, sp in enumerate(spaces):
            lvl_assembly = assembly if i > 0 else "paop"
            # Base operators are geometry/tables carriers only: every
            # solve binds per-scenario fields via with_materials*.
            op = ElasticityOperator(
                sp,
                assembly=lvl_assembly,
                materials=DEFER_MATERIALS,
                dtype=dtype,
                ess_faces=ess_faces,
                pallas_lane=self.pallas_lane,
                shard_mesh=self.mesh,
            )
            self._base_ops.append(op)
            self._desc_idx.append(
                None
                if sp.nelem == fine_mesh.nelem
                else jnp.asarray(fine_descendants(sp.mesh, fine_mesh))
            )

        self.transfers = [
            make_transfer(
                spaces[i], spaces[i + 1], dtype=dtype, shard_mesh=self.mesh
            )
            for i in range(len(spaces) - 1)
        ]
        # traction_rhs is linear in the traction vector and separable:
        # F = pattern (x) t, so probing with t = e_x yields the pattern.
        fine = spaces[-1]
        self._traction_pattern = jnp.asarray(
            fine.traction_rhs(traction_face, (1.0, 0.0, 0.0))[:, 0],
            dtype=dtype,
        )
        self._fine_ess = jnp.asarray(self._base_ops[-1].ess_mask)
        self._jit_solve = jax.jit(self._solve_impl)
        self._jit_prepare = jax.jit(self._prepare_impl)
        self._jit_chunk = jax.jit(
            self._chunk_impl, static_argnames=("do_reset",)
        )

    @property
    def fine_space(self) -> H1Space:
        return self.spaces[-1]

    # -- sharding ------------------------------------------------------------
    def pad_batch(self, n: int) -> int:
        """Rows a batch of ``n`` scenarios must be padded to so the
        scenario axis divides the device mesh (n unchanged when
        single-device)."""
        m = self.n_shards
        return -(-n // m) * m

    def pad_scenarios(self, materials, tractions, rel_tol, n: int | None = None):
        """Pad a scenario batch to ``n`` rows (default: the device-aligned
        ``pad_batch`` size) with born-converged padding rows: the first
        scenario's materials (dict or per-element array pair alike —
        keeps the batched operators SPD) and a zero traction, so b == 0
        makes them free (0 iterations).  The ONE definition of the
        padding-row convention; the service and the differential tests
        both go through it.  Returns ``(materials, tractions, rel_tols,
        n_real)`` with rel_tols broadcast to a per-row array."""
        s = len(materials)
        if n is None:
            n = self.pad_batch(s)
        tractions = np.asarray(tractions, dtype=np.float64)
        rel = np.broadcast_to(
            np.asarray(rel_tol, dtype=np.float64), (s,)
        ).copy()
        if n > s:
            materials = list(materials) + [materials[0]] * (n - s)
            tractions = np.concatenate(
                [tractions, np.zeros((n - s, 3))], axis=0
            )
            rel = np.concatenate([rel, np.full((n - s,), 1e-6)])
        return materials, tractions, rel, s

    def _check_batch(self, s: int, what: str) -> None:
        if s % self.n_shards:
            raise ValueError(
                f"{what}: batch size {s} does not divide the "
                f"{self.n_shards}-device scenario mesh; pad to "
                f"pad_batch({s}) = {self.pad_batch(s)} born-converged rows"
            )

    def _pin(self, tree):
        """with_sharding_constraint (traced): axis-0 scenario sharding."""
        return pin_scenario(tree, self.mesh)

    def _put(self, tree):
        """device_put (host-side): axis-0 scenario sharding."""
        return device_put_scenario(tree, self.mesh)

    # -- prep pytree ---------------------------------------------------------
    # prep carries every per-scenario derived quantity the step program
    # needs, as plain arrays: the operators' weighted material fields per
    # level, the smoother inverse diagonals + lambda_max per smoothed
    # level, and the coarse Cholesky factor.  It is produced by
    # ``prepare`` (jitted) and consumed by ``run_chunk`` (jitted), so
    # chunks pay neither power iterations nor refactorization.

    def empty_prep(self, s: int) -> dict:
        """Zero-filled prep of the right shapes for an S-row batch (laid
        out over the scenario mesh when sharded).  Only meaningful as the
        ``prep`` argument of a ``prepare`` call whose reset mask covers
        every row that will ever be read."""
        self._check_batch(s, "empty_prep")
        lam_w, mu_w, dinv, lmax = [], [], [], []
        for i, (base, sp) in enumerate(zip(self._base_ops, self.spaces)):
            shape = (s * sp.nelem,) + base.w_detj.shape
            lam_w.append(np.zeros(shape, dtype=np.dtype(self.dtype)))
            mu_w.append(np.zeros(shape, dtype=np.dtype(self.dtype)))
            if i > 0:
                dinv.append(
                    np.zeros((s, sp.nscalar, 3), dtype=np.dtype(self.dtype))
                )
                lmax.append(np.zeros((s,), dtype=np.dtype(self.dtype)))
        n0 = self.spaces[0].nscalar * 3
        return self._put(
            {
                "lam_w": tuple(lam_w),
                "mu_w": tuple(mu_w),
                "dinv": tuple(dinv),
                "lmax": tuple(lmax),
                "chol": np.zeros((s, n0, n0), dtype=np.dtype(self.dtype)),
            }
        )

    def empty_state(self, s: int) -> BpcgState:
        """All-rows-retired state of the right shapes for an S-row batch
        (every row must be reset before its first chunk; laid out over
        the scenario mesh when sharded)."""
        self._check_batch(s, "empty_state")
        vec = np.zeros((s, self.fine_space.nscalar, 3), dtype=np.dtype(self.dtype))
        row = np.zeros((s,), dtype=np.dtype(self.dtype))
        return self._put(
            BpcgState(
                x=vec,
                r=vec,
                z=vec,
                d=vec,
                nom=row,
                nom0=row,
                threshold=row,
                iters=np.zeros((s,), dtype=np.int32),
                active=np.zeros((s,), dtype=bool),
            )
        )

    def take_rows(self, state: BpcgState, prep: dict, rows):
        """Gather batch rows (host-side re-bucketing): returns (state,
        prep) whose row i is the old row ``rows[i]``.  ``rows`` may
        repeat indices (placeholder rows that the caller is about to
        reset) and may be shorter or longer than the old batch.  The
        result is re-laid-out over the scenario mesh (a re-bucketing
        changes which device owns which row)."""
        rows = np.asarray(rows, dtype=np.int32)
        self._check_batch(len(rows), "take_rows")
        new_state = BpcgState(
            **{
                fld.name: jnp.asarray(getattr(state, fld.name))[rows]
                for fld in dataclasses.fields(BpcgState)
            }
        )

        def fold_take(w, ne):
            s_old = w.shape[0] // ne
            folded = jnp.asarray(w).reshape((s_old, ne) + w.shape[1:])
            return folded[rows].reshape((-1,) + w.shape[1:])

        new_prep = {
            "lam_w": tuple(
                fold_take(w, sp.nelem)
                for w, sp in zip(prep["lam_w"], self.spaces)
            ),
            "mu_w": tuple(
                fold_take(w, sp.nelem)
                for w, sp in zip(prep["mu_w"], self.spaces)
            ),
            "dinv": tuple(jnp.asarray(d)[rows] for d in prep["dinv"]),
            "lmax": tuple(jnp.asarray(l)[rows] for l in prep["lmax"]),
            "chol": jnp.asarray(prep["chol"])[rows],
        }
        return self._put(new_state), self._put(new_prep)

    def copy_prep_rows(self, prep: dict, src, dst) -> dict:
        """Duplicate prepared batch rows: row ``dst[i]`` takes row
        ``src[i]``'s derived data (weighted fields, smoother dinv/lmax,
        coarse factor) bitwise.  Since prep depends only on a row's
        materials (geometry is shared), a refilled slot whose materials
        match an already-prepared row can skip ``prepare`` — no power
        iterations, no refactorization — which is the common case for
        serving traffic with a bounded material vocabulary."""
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)

        def fold_copy(w, ne):
            s = w.shape[0] // ne
            f = jnp.asarray(w).reshape((s, ne) + w.shape[1:])
            return f.at[dst].set(f[src]).reshape((-1,) + w.shape[1:])

        def row_copy(a):
            a = jnp.asarray(a)
            return a.at[dst].set(a[src])

        return self._put(
            {
                "lam_w": tuple(
                    fold_copy(w, sp.nelem)
                    for w, sp in zip(prep["lam_w"], self.spaces)
                ),
                "mu_w": tuple(
                    fold_copy(w, sp.nelem)
                    for w, sp in zip(prep["mu_w"], self.spaces)
                ),
                "dinv": tuple(row_copy(d) for d in prep["dinv"]),
                "lmax": tuple(row_copy(l) for l in prep["lmax"]),
                "chol": row_copy(prep["chol"]),
            }
        )

    # -- traced bodies -------------------------------------------------------
    def _restrict_field(self, field, level: int):
        """Restrict a (S, nelem_fine) per-element coefficient field to
        hierarchy level ``level`` by averaging each level element's fine
        descendants.  The reduction is a pairwise halving tree over the
        (power-of-two) descendant count, so it is *exact* whenever all
        descendants of an element carry the same value — which is what
        makes a piecewise-constant array field reproduce the equivalent
        attribute-dict scenario bit-for-bit on every level.  Identity
        (no gather) on levels that share the fine mesh."""
        desc = self._desc_idx[level]
        if desc is None:
            return field
        g = field[:, desc]  # (S, nelem_level, n_children)
        k = g.shape[-1]
        while g.shape[-1] > 1:
            g = g[..., 0::2] + g[..., 1::2]
        return g[..., 0] / k

    def _prepare_body(self, lam_vals, mu_vals, reset_mask, prep) -> dict:
        """Fold the (S, nelem_fine) material fields of the masked rows
        into the per-level weighted fields in place (coarser levels via
        :meth:`_restrict_field`), and recompute the derived per-scenario
        data (smoother dinv/lambda_max, coarse Cholesky) for exactly
        those rows; unmasked rows keep their prep bitwise."""
        s = lam_vals.shape[0]
        lam_vals, mu_vals, reset_mask, prep = self._pin(
            (lam_vals, mu_vals, reset_mask, prep)
        )
        lam_w, mu_w, dinv, lmax = [], [], [], []
        chol = None
        for i, base in enumerate(self._base_ops):
            sp = self.spaces[i]
            prev = base.with_material_weights(
                prep["lam_w"][i], prep["mu_w"][i], s
            )
            op = prev.with_materials_rows(
                self._restrict_field(lam_vals, i),
                self._restrict_field(mu_vals, i),
                reset_mask,
            )
            lam_w.append(self._pin(op.lam_w))
            mu_w.append(self._pin(op.mu_w))
            cop = op.constrained()
            if i == 0:
                K = probe_coarse_matrix(
                    cop, sp.nscalar, s, self.dtype, shard_mesh=self.mesh
                )
                L = jnp.linalg.cholesky(K)
                chol = self._pin(
                    jnp.where(reset_mask[:, None, None], L, prep["chol"])
                )
            else:
                sm = ChebyshevSmoother.setup(
                    cop,
                    cop.diagonal(),
                    shape=(s, sp.nscalar, 3),
                    dtype=self.dtype,
                    degree=self.cheb_degree,
                    power_iters=self.power_iters,
                    batch_dims=1,
                    shard_mesh=self.mesh,
                )
                dinv.append(
                    self._pin(
                        jnp.where(
                            reset_mask[:, None, None],
                            sm.dinv,
                            prep["dinv"][i - 1],
                        )
                    )
                )
                lmax.append(
                    self._pin(
                        jnp.where(reset_mask, sm.lmax, prep["lmax"][i - 1])
                    )
                )
        return {
            "lam_w": tuple(lam_w),
            "mu_w": tuple(mu_w),
            "dinv": tuple(dinv),
            "lmax": tuple(lmax),
            "chol": chol,
        }

    def _build_from_prep(self, prep):
        """Hierarchy + preconditioner from a prep pytree: binds the
        stored weighted fields and smoother data — no power iterations,
        no probing, no factorization."""
        s = prep["chol"].shape[0]
        levels = []
        for i, base in enumerate(self._base_ops):
            sp = self.spaces[i]
            op = base.with_material_weights(
                prep["lam_w"][i], prep["mu_w"][i], s
            )
            cop = op.constrained()
            smoother = None
            if i > 0:
                smoother = ChebyshevSmoother(
                    A=cop,
                    dinv=prep["dinv"][i - 1],
                    lmax=prep["lmax"][i - 1],
                    degree=self.cheb_degree,
                )
            levels.append(
                Level(
                    space=sp,
                    operator=op,
                    constrained=cop,
                    smoother=smoother,
                    ess_mask=op.ess_mask,
                )
            )
        gmg = GMGPreconditioner(
            levels=levels,
            transfers=self.transfers,
            coarse_solve=cholesky_solver(prep["chol"], shard_mesh=self.mesh),
        )
        return levels, gmg

    def _rhs(self, tractions):
        b = self._traction_pattern[None, :, None] * tractions[:, None, :]
        return self._pin(
            jnp.where(self._fine_ess, 0.0, b)  # homogeneous elimination
        )

    def _prepare_impl(self, lam_vals, mu_vals, reset_mask, prep) -> dict:
        return self._prepare_body(lam_vals, mu_vals, reset_mask, prep)

    def _chunk_impl(
        self, tractions, rel_tol, reset_mask, state, prep, k_iters,
        *, do_reset: bool,
    ) -> tuple[BpcgState, Any]:
        state, prep = self._pin(state), self._pin(prep)
        levels, gmg = self._build_from_prep(prep)
        A = levels[-1].constrained
        if do_reset:
            fresh = bpcg_init(A, self._rhs(tractions), M=gmg, rel_tol=rel_tol)
            state = merge_states(reset_mask, fresh, state)
        start_iters = state.iters
        out = bpcg_chunk(
            A, state, M=gmg, k_iters=k_iters, maxiter=self.maxiter
        )
        # Per-row iterations consumed by THIS chunk: the scheduling
        # policies read retire cadence from this (S,) vector, so the
        # host never has to fetch the full state mid-flight.
        return self._pin(out), self._pin(out.iters - start_iters)

    def _solve_impl(self, lam_vals, mu_vals, tractions, rel_tol):
        s = lam_vals.shape[0]
        prep = self._prepare_body(
            lam_vals, mu_vals, jnp.ones((s,), dtype=bool), self.empty_prep(s)
        )
        levels, gmg = self._build_from_prep(prep)
        A = levels[-1].constrained
        state = bpcg_init(A, self._rhs(tractions), M=gmg, rel_tol=rel_tol)
        state = bpcg_chunk(A, state, M=gmg, k_iters=None, maxiter=self.maxiter)
        return bpcg_result(self._pin(state))

    # -- public entry --------------------------------------------------------
    def pack_materials(self, materials: list) -> tuple[Any, Any]:
        """Normalize a length-S scenario list into (S, nelem_fine)
        per-element coefficient fields.

        Each entry is either an attribute -> (lambda, mu) dict
        (piecewise-constant by mesh attribute) or a ``(lam_e, mu_e)``
        array pair of shape (nelem_fine,) giving one coefficient per
        FINE-mesh element; the two forms mix freely within one batch.
        Coarser hierarchy levels see each field through an exact
        power-of-two descendant average (:meth:`_restrict_field`), so a
        piecewise-constant array reproduces the equivalent dict scenario
        bit-for-bit.  Raises ValueError naming the scenario plus the
        missing/offending attribute (dicts) or the mismatched shape /
        first non-positive element index (arrays)."""
        ne = self.fine_space.nelem
        fine_mesh = self.fine_space.mesh
        lam = np.empty((len(materials), ne))
        mu = np.empty_like(lam)
        for si, m in enumerate(materials):
            where = f"scenario {si} materials"
            if isinstance(m, dict):
                check_material_dict(m, self.attr_values, where=where)
                lam[si], mu[si] = material_fields(fine_mesh, m)
            else:
                if getattr(m, "ndim", None) is not None and np.ndim(m) != 1:
                    # A bare 2-D array entry means the caller passed the
                    # raw stacked (lam_2d, mu_2d) pair itself instead of
                    # a scenario list — unpacking its rows here would
                    # silently cross-pair lambda/mu across scenarios.
                    raise TypeError(
                        f"{where}: got a {np.ndim(m)}-D array as a "
                        f"scenario entry; pack_materials takes a LIST "
                        f"of per-scenario entries (dicts or (lam_e, "
                        f"mu_e) pairs) — for a pre-stacked (S, nelem) "
                        f"pair use list(zip(lam, mu))"
                    )
                try:
                    lam_e, mu_e = m
                except (TypeError, ValueError):
                    raise TypeError(
                        f"{where}: expected an attribute->(lambda, mu) "
                        f"dict or a (lam_e, mu_e) array pair, got "
                        f"{type(m).__name__!r}"
                    ) from None
                lam[si], mu[si] = check_material_fields(
                    lam_e, mu_e, ne, where=where
                )
        return jnp.asarray(lam, self.dtype), jnp.asarray(mu, self.dtype)

    def prepare(self, lam_vals, mu_vals, reset_mask, prep) -> dict:
        """Jitted: fold the masked rows' new materials into the per-row
        operator fields and refresh their derived data (see
        ``_prepare_body``).

        ``lam_vals``/``mu_vals`` are (S, nelem_fine) per-element fields
        (the output of :meth:`pack_materials`); S must divide the device
        mesh when sharded — the fields ride the same axis-0
        NamedSharding as the rest of the prep pytree.  Rows NOT selected
        by ``reset_mask`` keep their prep bitwise.  One trace per batch
        size."""
        s, ne = np.shape(lam_vals)
        self._check_batch(int(s), "prepare")
        if ne != self.fine_space.nelem:
            raise ValueError(
                f"prepare: material fields have {ne} elements per row, "
                f"expected nelem_fine = {self.fine_space.nelem}"
            )
        lam_vals, mu_vals, reset_mask, prep = self._put(
            (lam_vals, mu_vals, reset_mask, prep)
        )
        return self._jit_prepare(lam_vals, mu_vals, reset_mask, prep)

    def run_chunk(
        self, tractions, rel_tol, reset_mask, state, prep, k_iters,
        *, do_reset: bool = False,
    ) -> tuple[BpcgState, Any]:
        """Jitted: advance the batch by up to ``k_iters`` iterations.
        With ``do_reset`` the masked rows are first re-initialized for
        their (new) tractions/tolerances: x = 0, r = b, fresh thresholds,
        iteration count 0 (their materials must already be folded into
        ``prep`` via :meth:`prepare` or :meth:`copy_prep_rows`); rows
        outside the mask resume bit-identically.  The batch size must
        divide the device mesh when sharded — padding rows are the
        caller's job (see :meth:`pad_scenarios`).  ``k_iters`` is a
        runtime argument — any chunk length reuses the same compiled
        program.

        Returns ``(state, consumed)`` where ``consumed`` is the (S,)
        int32 count of iterations each row executed inside this chunk
        (0 for rows that entered inactive).  It is the cadence signal
        the adaptive chunk policies feed on: one small vector instead of
        an extra mid-flight fetch of the full state."""
        tractions = jnp.asarray(tractions, self.dtype)
        self._check_batch(int(tractions.shape[0]), "run_chunk")
        rel = jnp.broadcast_to(
            jnp.asarray(rel_tol, self.dtype), (tractions.shape[0],)
        )
        tractions, rel, reset_mask, state, prep = self._put(
            (tractions, rel, reset_mask, state, prep)
        )
        return self._jit_chunk(
            tractions, rel, reset_mask, state, prep,
            jnp.asarray(k_iters, dtype=jnp.int32), do_reset=do_reset,
        )

    def solve(
        self,
        materials: list[dict],
        tractions,
        rel_tol,
    ) -> BPCGResult:
        """Solve S scenarios in one compiled program.

        materials: length-S list; each entry an attribute->(lambda, mu)
                   dict or a (lam_e, mu_e) per-element array pair of
                   shape (nelem_fine,) — the forms mix freely (see
                   :meth:`pack_materials`)
        tractions: (S, 3) traction vectors on the traction face
        rel_tol:   scalar or (S,) per-scenario relative tolerances

        Sharded solvers pad S up to a multiple of the device count with
        born-converged rows (see :meth:`pad_scenarios`) and slice them
        off the result: callers see exactly the S rows they asked for.
        """
        materials, tractions, rel_tol, s = self.pad_scenarios(
            materials, tractions, rel_tol
        )
        lam_vals, mu_vals = self.pack_materials(materials)
        tractions = jnp.asarray(tractions, self.dtype)
        rel = jnp.asarray(rel_tol, self.dtype)
        lam_vals, mu_vals, tractions, rel = self._put(
            (lam_vals, mu_vals, tractions, rel)
        )
        res = self._jit_solve(lam_vals, mu_vals, tractions, rel)
        if len(materials) > s:
            res = BPCGResult(
                **{
                    fld.name: getattr(res, fld.name)[:s]
                    for fld in dataclasses.fields(BPCGResult)
                }
            )
        return res
