"""Batched multi-scenario GMG-PCG: many parameterized elasticity solves
in one device program, resumable in bounded chunks.

The paper's end-to-end solve (fused PAop operator + GMG-preconditioned
CG) runs one scenario at a time; this module amortizes compilation and
hardware occupancy across a *batch* of scenarios (different materials,
tractions, tolerances) the way the LM serving engine batches decode
requests:

* ``bpcg`` — PCG over a leading scenario axis.  Per-scenario convergence
  is tracked with an active mask: converged scenarios' ``x``/``r``/``d``
  are frozen (their step sizes are forced to zero and direction updates
  gated), the loop runs until every scenario converges or hits
  ``maxiter``, and per-scenario iteration counts are reported.

* the resumable step program — ``bpcg`` is split into
  :func:`bpcg_init` (build a pinned-shape :class:`BpcgState`) and
  :func:`bpcg_chunk` (advance all rows by a bounded number of
  iterations).  Because frozen rows never change, running chunks of
  ``k1`` then ``k2`` iterations produces exactly the state of one
  uninterrupted ``k1 + k2`` run, which is what lets a serving layer
  retire converged rows and refill their slots *between* chunks
  (continuous batching) instead of waiting for a whole generation.
  :func:`merge_states` resets just the refilled rows; untouched rows
  keep their state bitwise.

* ``BatchedGMGSolver`` — compiled solve *programs* for one
  discretization ``(coarse_mesh, n_h_refine, p)``.  Geometry (spaces,
  transfers, gather maps, basis tables, traction pattern) is built once
  at construction; materials, tractions and tolerances are **runtime
  arguments**.  Two jitted entry points drive the step program:
  ``prepare`` folds (new) per-scenario materials into the operators'
  per-row weighted fields in place and recomputes the derived
  per-scenario data (smoother diagonals + lambda_max, the coarse
  Cholesky factor) for exactly the reset rows; ``run_chunk`` rebuilds
  the hierarchy from that prep pytree (no power iterations, no
  refactorization), advances the state by ``k`` iterations and reports
  the per-row iterations consumed (the retire-cadence signal the
  adaptive chunk policies in :mod:`repro.serve.chunk_policy` use).  The
  monolithic ``solve`` is the same machinery run to completion in one
  call.  Re-solving with new scenario data hits the compiled programs —
  no retrace, no hierarchy rebuild.

The scenario axis is threaded through ``ChebyshevSmoother``,
``GMGPreconditioner`` and ``Transfer``; operators fold it into the
element axis so the fused PA kernels (including Pallas) run unchanged
on an S-times-larger grid.

Multi-device sharding: ``BatchedGMGSolver(..., mesh=...)`` (a 1-D
``jax.sharding`` mesh over the scenario axis, or an int meaning "the
first n devices") shards the scenario axis S across devices end to
end — the :class:`BpcgState` pytree, the prep pytree (weighted material
fields, smoother dinv/lambda_max, coarse Cholesky factors) and the
operators' folded (S*E, ...) element arrays all carry axis-0
``NamedSharding``.  Scenarios never couple, so each device runs the
exact single-device program on its own rows; the only cross-device
traffic is the (S,)-vector convergence logic of ``bpcg`` (cheap
all-gathers).  ``solve`` pads S up to a multiple of the device count
with born-converged rows (zero traction) and slices them back off, so
sharding is a pure implementation detail: results, iteration counts and
convergence flags are identical to the single-device path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import DEFER_MATERIALS, ElasticityOperator
from repro.core.precision import PrecisionPolicy, resolve_precision
from repro.kernels.pa_elasticity.ops import resolve_lane
from repro.distributed.sharding import (
    device_put_scenario,
    normalize_scenario_mesh,
    pin_scenario,
)
from repro.core.geometry import (
    check_material_dict,
    check_material_fields,
    material_fields,
)
from repro.fem.mesh import HexMesh, fine_descendants
from repro.fem.space import H1Space
from repro.fem.transfer import make_transfer
from repro.solvers.chebyshev import ChebyshevSmoother, _expand
from repro.solvers.coarse import cholesky_solver, probe_coarse_matrix
from repro.solvers.gmg import GMGPreconditioner, Level, hierarchy_spaces

__all__ = [
    "bpcg",
    "bpcg_init",
    "bpcg_chunk",
    "bpcg_result",
    "true_residual_audit",
    "merge_states",
    "BpcgState",
    "BPCGResult",
    "BatchedGMGSolver",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BPCGResult:
    x: Any  # (S, ...) solutions
    iterations: Any  # (S,) int32 per-scenario counts
    converged: Any  # (S,) bool
    final_norm: Any  # (S,) sqrt((B r, r)) at exit
    initial_norm: Any  # (S,)
    stalled: Any  # (S,) bool — stagnation detected (reduced precision)
    fallback: Any  # (S,) bool — row was re-solved on the f64 path


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BpcgState:
    """Pinned-shape resumable PCG state (one row per batch slot).

    Everything the iteration needs lives here, so a compiled
    ``run_chunk(state, k)`` can advance the batch, hand the state back to
    the host for retire/refill decisions, and resume bit-identically."""

    x: Any  # (S, ...) iterates
    r: Any  # (S, ...) residuals
    z: Any  # (S, ...) preconditioned residuals
    d: Any  # (S, ...) search directions
    nom: Any  # (S,) current (B r, r)
    nom0: Any  # (S,) (B r, r) at the row's (re)start
    threshold: Any  # (S,) per-row stopping value for nom
    iters: Any  # (S,) int32 iterations since the row's (re)start
    active: Any  # (S,) bool — still iterating
    best: Any  # (S,) lowest nom seen since the row's (re)start
    stall: Any  # (S,) int32 consecutive low-progress iterations
    stalled: Any  # (S,) bool — sticky stagnation flag (see bpcg_chunk)


def _dots(a, b):
    """Per-scenario inner products: contract everything but axis 0."""
    return jnp.sum(
        a.reshape(a.shape[0], -1) * b.reshape(b.shape[0], -1), axis=1
    )


# (S,) coefficients broadcast against (S, ...) vectors with the same
# right-pad rule the batched Chebyshev smoother uses.
_col = _expand


def bpcg_init(
    A: Callable,
    b,
    M: Callable | None = None,
    *,
    x0=None,
    rel_tol=1e-6,
    abs_tol=0.0,
) -> BpcgState:
    """Build the initial :class:`BpcgState` for ``A x = b``.

    MFEM-style thresholds, per scenario: a row stops when
    ``nom <= max(nom0 * rel_tol^2, abs_tol^2)``; ``rel_tol``/``abs_tol``
    may be scalars or (S,) arrays.  A row with a zero RHS is born
    converged (0 iterations) — this is also what makes padded batch
    slots free."""
    if M is None:
        M = lambda r: r
    s = b.shape[0]
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b  # A is linear: A(0) == 0 exactly
    else:
        x = x0
        r = b - A(x)
    z = M(r)
    nom0 = _dots(z, r)
    rel = jnp.broadcast_to(jnp.asarray(rel_tol, dtype=nom0.dtype), (s,))
    ab = jnp.broadcast_to(jnp.asarray(abs_tol, dtype=nom0.dtype), (s,))
    threshold = jnp.maximum(nom0 * rel**2, ab**2)
    return BpcgState(
        x=x,
        r=r,
        z=z,
        d=z,
        nom=nom0,
        nom0=nom0,
        threshold=threshold,
        iters=jnp.zeros((s,), dtype=jnp.int32),
        active=nom0 > threshold,
        best=nom0,
        stall=jnp.zeros((s,), dtype=jnp.int32),
        stalled=jnp.zeros((s,), dtype=bool),
    )


def bpcg_chunk(
    A: Callable,
    state: BpcgState,
    M: Callable | None = None,
    *,
    k_iters=None,
    maxiter: int = 5000,
    stall_iters: int = 0,
    stall_rtol: float = 0.99,
) -> BpcgState:
    """Advance every active row by up to ``k_iters`` PCG iterations
    (unbounded — run to convergence/``maxiter`` — when ``k_iters`` is
    None).

    Chunked resumption is exact: inactive rows are frozen (alpha forced
    to 0, direction updates gated), so ``chunk(k1)`` followed by
    ``chunk(k2)`` yields the same state as one ``chunk(k1 + k2)`` call.
    ``k_iters`` may be a traced value, so one compiled program serves
    every chunk length.

    Stagnation detection (the reduced-precision safety net): with
    ``stall_iters > 0``, a row that goes ``stall_iters`` consecutive
    iterations without reducing its best-seen ``nom`` by at least a
    factor ``stall_rtol`` is flagged ``stalled`` (sticky) and
    deactivated — it has hit the precision floor of the arithmetic, and
    more iterations cannot help.  Tracked per scenario with the same
    masking as convergence, so one stuck row never holds the batch.
    The default ``stall_iters = 0`` disables detection entirely (no
    extra arithmetic in the loop body), keeping the f64 path
    bit-identical to the pre-stagnation program."""
    if M is None:
        M = lambda r: r

    def cond(carry):
        st, step = carry
        go = jnp.any(st.active)
        if k_iters is not None:
            go = go & (step < k_iters)
        return go

    def body(carry):
        st, step = carry
        x, r, nom, active = st.x, st.r, st.nom, st.active
        ad = A(st.d)
        den = _dots(st.d, ad)
        # Inactive rows get alpha = 0 (frozen); den == 0 cannot occur for
        # an active SPD row (d != 0 there) but is guarded so one bad or
        # retired scenario can never NaN the rest of the batch.
        ok = active & (den > 0)
        alpha = jnp.where(ok, nom / jnp.where(den == 0, 1.0, den), 0.0)
        x = x + _col(alpha, x.ndim) * st.d
        r = r - _col(alpha, r.ndim) * ad
        z = M(r)
        betanom = _dots(z, r)
        beta = jnp.where(ok, betanom / jnp.where(nom == 0, 1.0, nom), 0.0)
        d = jnp.where(
            _col(active, st.d.ndim), z + _col(beta, st.d.ndim) * st.d, st.d
        )
        nom = jnp.where(active, betanom, nom)
        # Count only real steps (ok), matching scalar pcg: an aborted
        # degenerate direction (den <= 0) takes no step and adds none.
        iters = st.iters + ok.astype(jnp.int32)
        active = ok & (nom > st.threshold) & (iters < maxiter)
        if stall_iters > 0:
            # Progress = the best-seen nom dropped by >= (1 - rtol);
            # best-so-far (not last-step) so an oscillating residual
            # doesn't reset the counter on every upswing.
            improved = betanom < st.best * stall_rtol
            stall = jnp.where(
                ok, jnp.where(improved, 0, st.stall + 1), st.stall
            )
            best = jnp.where(ok, jnp.minimum(st.best, betanom), st.best)
            hit = active & (stall >= stall_iters)
            stalled = st.stalled | hit
            active = active & ~hit
            new = dataclasses.replace(
                st, x=x, r=r, z=z, d=d, nom=nom, iters=iters,
                active=active, best=best, stall=stall, stalled=stalled,
            )
        else:
            new = dataclasses.replace(
                st, x=x, r=r, z=z, d=d, nom=nom, iters=iters, active=active
            )
        return (new, step + 1)

    state, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), dtype=jnp.int32))
    )
    return state


def merge_states(reset_mask, fresh: BpcgState, old: BpcgState) -> BpcgState:
    """Per-row state merge: rows selected by ``reset_mask`` (S,) take
    ``fresh`` (a just-initialized state for their new RHS/tolerance),
    the rest keep ``old`` bitwise — refilling a slot must not perturb
    the rows still in flight."""
    mask = jnp.asarray(reset_mask)

    def pick(f, o):
        return jnp.where(_col(mask, jnp.ndim(f)), f, o)

    return BpcgState(
        **{
            fld.name: pick(getattr(fresh, fld.name), getattr(old, fld.name))
            for fld in dataclasses.fields(BpcgState)
        }
    )


def true_residual_audit(
    A: Callable, M: Callable, b, state: BpcgState, slack: float = 4.0
) -> BpcgState:
    """The reduced-precision honesty check: CG's recursively updated
    residual drifts from ``b - A x`` once rounding dominates, so its
    ``nom`` can sail below any threshold while the *true* residual sits
    at the arithmetic's floor.  Recompute the true preconditioned norm
    for rows claiming convergence; a row whose true ``nom`` exceeds its
    threshold by more than ``slack`` is marked ``stalled`` (sticky) and
    gets the true norm as its exit ``nom``, so ``bpcg_result`` reports
    it unconverged and the solve/serving layers route it to the f64
    fallback.  Rows passing the audit keep their state bitwise.  Never
    run on the f64 path (drift there is below any meaningful
    tolerance — and the extra A/M application isn't free)."""
    claimed = ~state.active & (state.nom <= state.threshold) & ~state.stalled
    rt = b - A(state.x)
    nomt = _dots(M(rt), rt)
    lying = claimed & (nomt > state.threshold * slack)
    return dataclasses.replace(
        state,
        nom=jnp.where(lying, nomt, state.nom),
        stalled=state.stalled | lying,
    )


def _merge_fallback_rows(res: BPCGResult, sub: BPCGResult, rows) -> BPCGResult:
    """Merge an f64 re-solve of ``rows`` into a reduced-precision
    result.  The merged result is f64 (a fallback row's extra accuracy
    cannot ride an f32 vector); ``iterations`` accumulates so the
    reported count is the honest total cost, and ``fallback`` marks the
    re-solved rows while ``stalled`` keeps recording that the reduced
    pass flagged them."""
    rows = jnp.asarray(np.asarray(rows, dtype=np.int32))
    f64 = lambda a: jnp.asarray(a, jnp.float64)
    return BPCGResult(
        x=f64(res.x).at[rows].set(f64(sub.x)),
        iterations=res.iterations.at[rows].add(sub.iterations),
        converged=res.converged.at[rows].set(sub.converged),
        final_norm=f64(res.final_norm).at[rows].set(f64(sub.final_norm)),
        initial_norm=f64(res.initial_norm).at[rows].set(
            f64(sub.initial_norm)
        ),
        stalled=res.stalled,
        fallback=jnp.zeros_like(res.stalled).at[rows].set(True),
    )


def bpcg_result(state: BpcgState) -> BPCGResult:
    return BPCGResult(
        x=state.x,
        iterations=state.iters,
        converged=state.nom <= state.threshold,
        final_norm=jnp.sqrt(jnp.abs(state.nom)),
        initial_norm=jnp.sqrt(jnp.abs(state.nom0)),
        stalled=jnp.asarray(state.stalled),
        fallback=jnp.zeros_like(jnp.asarray(state.stalled)),
    )


def bpcg(
    A: Callable,
    b,
    M: Callable | None = None,
    *,
    x0=None,
    rel_tol=1e-6,
    abs_tol=0.0,
    maxiter: int = 5000,
    stall_iters: int = 0,
    stall_rtol: float = 0.99,
) -> BPCGResult:
    """MFEM-style PCG over a leading scenario axis with masked
    convergence.

    ``A`` and ``M`` map (S, ...) batches to (S, ...) batches with no
    cross-scenario coupling; ``rel_tol``/``abs_tol`` may be scalars or
    (S,) arrays (per-scenario tolerances).  Scenarios that converge stop
    updating while the rest keep iterating; the loop exits when no
    scenario is active.  Implemented as the resumable step program run
    in one uninterrupted chunk (see :func:`bpcg_init` /
    :func:`bpcg_chunk`; ``stall_iters`` enables the per-row stagnation
    detector for reduced-precision runs)."""
    state = bpcg_init(A, b, M, x0=x0, rel_tol=rel_tol, abs_tol=abs_tol)
    state = bpcg_chunk(
        A, state, M, k_iters=None, maxiter=maxiter,
        stall_iters=stall_iters, stall_rtol=stall_rtol,
    )
    return bpcg_result(state)


class BatchedGMGSolver:
    """Compiled multi-scenario solve programs for one discretization.

    Construction builds everything material-independent for the beam
    benchmark family: the mesh/degree hierarchy, transfer operators,
    per-level fine-descendant maps, and the boundary traction pattern.
    ``solve`` takes per-scenario materials (attribute dicts and/or
    per-element (lam_e, mu_e) coefficient arrays — see
    :meth:`pack_materials`), traction vectors and tolerances and runs to
    completion; ``prepare`` + ``run_chunk`` expose the same solve as a
    resumable step program for continuous batching.  Each jitted entry
    point is traced once per batch size (bucket) and reused for every
    subsequent call of the same shape.

    Precision: ``precision`` names a
    :class:`~repro.core.precision.PrecisionPolicy` (``"f64"``,
    ``"f32"``, ``"mixed"``, ``"mixed-bf16"`` or a policy object).  The
    outer Krylov loop — ``BpcgState`` vectors, operator apply in the CG
    recurrence, residual norms, thresholds — runs in
    ``policy.solve_dtype`` (exposed as ``self.dtype``); the GMG V-cycle
    (weighted material fields, Chebyshev smoother, transfers) runs in
    ``policy.precond_dtype``; the coarse probe/Cholesky in
    ``policy.coarse_dtype``.  For genuinely mixed policies the fine
    level keeps a second, ``solve_dtype`` copy of its weighted fields
    (``prep["lam_w_solve"]``/``prep["mu_w_solve"]``) so the outer
    residual is computed at full precision while the smoother streams
    reduced bytes.  Reduced policies run with the stagnation detector
    on, and ``solve`` re-solves any stalled rows on a lazily built f64
    twin solver (``fallback`` marks them in the result).  The legacy
    ``dtype`` argument still works and resolves to the matching uniform
    policy.
    """

    def __init__(
        self,
        coarse_mesh: HexMesh,
        n_h_refine: int,
        p_target: int,
        *,
        assembly: str = "paop",
        dtype=None,
        precision: str | PrecisionPolicy | None = None,
        cheb_degree: int = 2,
        power_iters: int = 10,
        ess_faces=("x0",),
        traction_face: str = "x1",
        maxiter: int = 200,
        stall_iters: int = 20,
        stall_rtol: float = 0.99,
        pallas_interpret: bool | None = None,
        pallas_lane: str | None = None,
        mesh=None,
    ):
        if assembly == "fa":
            raise ValueError("batched solves are matrix-free ('fa' unsupported)")
        self.coarse_mesh = coarse_mesh
        self.n_h_refine = n_h_refine
        self.p_target = p_target
        self.assembly = assembly
        self.precision = resolve_precision(precision, dtype)
        self.dtype = self.precision.solve_dtype
        self.precond_dtype = self.precision.precond_dtype
        self.coarse_dtype = self.precision.coarse_dtype
        self.cheb_degree = cheb_degree
        self.power_iters = power_iters
        self.maxiter = maxiter
        # Stagnation detection is armed only for reduced policies: the
        # f64 program stays bit-identical (stall_iters=0 compiles the
        # detector out of the loop body entirely).
        self.stall_iters = stall_iters if self.precision.reduced else 0
        self.stall_rtol = stall_rtol
        self._f64_twin: BatchedGMGSolver | None = None
        self._ess_faces = ess_faces
        self._traction_face = traction_face
        # Pallas lane, resolved ONCE here so every level operator runs
        # the same lane and ``self.pallas_lane`` reports what actually
        # runs ("compiled" or "interpret"; auto falls back to interpret
        # on backends that cannot lower Pallas natively).
        self.pallas_lane = resolve_lane(pallas_lane, interpret=pallas_interpret)
        # Scenario-axis device mesh (None = single-device).  An int is
        # shorthand for "shard over the first n devices".
        self.mesh, self.n_shards = normalize_scenario_mesh(mesh)

        spaces = hierarchy_spaces(coarse_mesh, n_h_refine, p_target)
        self.spaces = spaces

        # Attribute vocabulary (static): kept for validating attribute-
        # dict scenarios against the mesh (pack_materials).
        self.attr_values: tuple[int, ...] = tuple(
            int(a) for a in np.unique(coarse_mesh.attributes())
        )

        # Scenario materials travel as (S, nelem_fine) per-element
        # coefficient fields (attribute dicts are expanded on intake by
        # pack_materials).  Each coarser h-level sees the fine field
        # through its fine-descendant map — an exact power-of-two tree
        # average (see _restrict_field); p-embedding levels share the
        # fine mesh, so their map is the identity (stored as None).
        fine_mesh = spaces[-1].mesh
        # True when the outer Krylov and the V-cycle run different
        # dtypes — the fine level then carries a solve-dtype twin of its
        # base operator (outer A) next to the precond-dtype one.
        self._split_fine = jnp.dtype(self.dtype) != jnp.dtype(
            self.precond_dtype
        )
        self._base_ops = []
        self._desc_idx: list[Any] = []
        for i, sp in enumerate(spaces):
            lvl_assembly = assembly if i > 0 else "paop"
            # Base operators are geometry/tables carriers only: every
            # solve binds per-scenario fields via with_materials*.  The
            # V-cycle levels live at the policy's precond dtype.
            op = ElasticityOperator(
                sp,
                assembly=lvl_assembly,
                materials=DEFER_MATERIALS,
                dtype=self.precond_dtype,
                ess_faces=ess_faces,
                pallas_lane=self.pallas_lane,
                shard_mesh=self.mesh,
            )
            self._base_ops.append(op)
            self._desc_idx.append(
                None
                if sp.nelem == fine_mesh.nelem
                else jnp.asarray(fine_descendants(sp.mesh, fine_mesh))
            )
        self._fine_base_solve = (
            ElasticityOperator(
                spaces[-1],
                assembly=assembly if len(spaces) > 1 else "paop",
                materials=DEFER_MATERIALS,
                dtype=self.dtype,
                ess_faces=ess_faces,
                pallas_lane=self.pallas_lane,
                shard_mesh=self.mesh,
            )
            if self._split_fine
            else None
        )

        self.transfers = [
            make_transfer(
                spaces[i], spaces[i + 1], dtype=self.precond_dtype,
                shard_mesh=self.mesh,
            )
            for i in range(len(spaces) - 1)
        ]
        # traction_rhs is linear in the traction vector and separable:
        # F = pattern (x) t, so probing with t = e_x yields the pattern.
        fine = spaces[-1]
        self._traction_pattern = jnp.asarray(
            fine.traction_rhs(traction_face, (1.0, 0.0, 0.0))[:, 0],
            dtype=self.dtype,
        )
        self._fine_ess = jnp.asarray(self._base_ops[-1].ess_mask)
        self._jit_solve = jax.jit(self._solve_impl)
        self._jit_prepare = jax.jit(self._prepare_impl)
        self._jit_chunk = jax.jit(
            self._chunk_impl, static_argnames=("do_reset",)
        )

    @property
    def fine_space(self) -> H1Space:
        return self.spaces[-1]

    # -- sharding ------------------------------------------------------------
    def pad_batch(self, n: int) -> int:
        """Rows a batch of ``n`` scenarios must be padded to so the
        scenario axis divides the device mesh (n unchanged when
        single-device)."""
        m = self.n_shards
        return -(-n // m) * m

    def pad_scenarios(self, materials, tractions, rel_tol, n: int | None = None):
        """Pad a scenario batch to ``n`` rows (default: the device-aligned
        ``pad_batch`` size) with born-converged padding rows: the first
        scenario's materials (dict or per-element array pair alike —
        keeps the batched operators SPD) and a zero traction, so b == 0
        makes them free (0 iterations).  The ONE definition of the
        padding-row convention; the service and the differential tests
        both go through it.  Returns ``(materials, tractions, rel_tols,
        n_real)`` with rel_tols broadcast to a per-row array."""
        s = len(materials)
        if n is None:
            n = self.pad_batch(s)
        # Solver dtype, NOT a hard-coded float64: a non-f64 solver must
        # not have its runtime arguments silently promoted (the whole
        # solve would re-trace and run at the wrong precision).
        sdt = np.dtype(self.dtype)
        tractions = np.asarray(tractions, dtype=sdt)
        rel = np.broadcast_to(np.asarray(rel_tol, dtype=sdt), (s,)).copy()
        if n > s:
            materials = list(materials) + [materials[0]] * (n - s)
            tractions = np.concatenate(
                [tractions, np.zeros((n - s, 3), dtype=sdt)], axis=0
            )
            rel = np.concatenate([rel, np.full((n - s,), 1e-6, dtype=sdt)])
        return materials, tractions, rel, s

    def _check_batch(self, s: int, what: str) -> None:
        if s % self.n_shards:
            raise ValueError(
                f"{what}: batch size {s} does not divide the "
                f"{self.n_shards}-device scenario mesh; pad to "
                f"pad_batch({s}) = {self.pad_batch(s)} born-converged rows"
            )

    def _pin(self, tree):
        """with_sharding_constraint (traced): axis-0 scenario sharding."""
        return pin_scenario(tree, self.mesh)

    def _put(self, tree):
        """device_put (host-side): axis-0 scenario sharding."""
        return device_put_scenario(tree, self.mesh)

    # -- prep pytree ---------------------------------------------------------
    # prep carries every per-scenario derived quantity the step program
    # needs, as plain arrays: the operators' weighted material fields per
    # level, the smoother inverse diagonals + lambda_max per smoothed
    # level, and the coarse Cholesky factor.  It is produced by
    # ``prepare`` (jitted) and consumed by ``run_chunk`` (jitted), so
    # chunks pay neither power iterations nor refactorization.

    def empty_prep(self, s: int) -> dict:
        """Zero-filled prep of the right shapes for an S-row batch (laid
        out over the scenario mesh when sharded).  Only meaningful as the
        ``prep`` argument of a ``prepare`` call whose reset mask covers
        every row that will ever be read."""
        self._check_batch(s, "empty_prep")
        pdt = np.dtype(self.precond_dtype)
        lam_w, mu_w, dinv, lmax = [], [], [], []
        for i, (base, sp) in enumerate(zip(self._base_ops, self.spaces)):
            shape = (s * sp.nelem,) + base.w_detj.shape
            lam_w.append(np.zeros(shape, dtype=pdt))
            mu_w.append(np.zeros(shape, dtype=pdt))
            if i > 0:
                dinv.append(np.zeros((s, sp.nscalar, 3), dtype=pdt))
                lmax.append(np.zeros((s,), dtype=pdt))
        n0 = self.spaces[0].nscalar * 3
        prep = {
            "lam_w": tuple(lam_w),
            "mu_w": tuple(mu_w),
            "dinv": tuple(dinv),
            "lmax": tuple(lmax),
            "chol": np.zeros((s, n0, n0), dtype=np.dtype(self.coarse_dtype)),
        }
        if self._split_fine:
            fine = self.spaces[-1]
            shape = (s * fine.nelem,) + self._fine_base_solve.w_detj.shape
            sdt = np.dtype(self.dtype)
            prep["lam_w_solve"] = np.zeros(shape, dtype=sdt)
            prep["mu_w_solve"] = np.zeros(shape, dtype=sdt)
        return self._put(prep)

    def empty_state(self, s: int) -> BpcgState:
        """All-rows-retired state of the right shapes for an S-row batch
        (every row must be reset before its first chunk; laid out over
        the scenario mesh when sharded)."""
        self._check_batch(s, "empty_state")
        vec = np.zeros((s, self.fine_space.nscalar, 3), dtype=np.dtype(self.dtype))
        row = np.zeros((s,), dtype=np.dtype(self.dtype))
        return self._put(
            BpcgState(
                x=vec,
                r=vec,
                z=vec,
                d=vec,
                nom=row,
                nom0=row,
                threshold=row,
                iters=np.zeros((s,), dtype=np.int32),
                active=np.zeros((s,), dtype=bool),
                best=row,
                stall=np.zeros((s,), dtype=np.int32),
                stalled=np.zeros((s,), dtype=bool),
            )
        )

    def take_rows(self, state: BpcgState, prep: dict, rows):
        """Gather batch rows (host-side re-bucketing): returns (state,
        prep) whose row i is the old row ``rows[i]``.  ``rows`` may
        repeat indices (placeholder rows that the caller is about to
        reset) and may be shorter or longer than the old batch.  The
        result is re-laid-out over the scenario mesh (a re-bucketing
        changes which device owns which row)."""
        rows = np.asarray(rows, dtype=np.int32)
        self._check_batch(len(rows), "take_rows")
        new_state = BpcgState(
            **{
                fld.name: jnp.asarray(getattr(state, fld.name))[rows]
                for fld in dataclasses.fields(BpcgState)
            }
        )

        def fold_take(w, ne):
            s_old = w.shape[0] // ne
            folded = jnp.asarray(w).reshape((s_old, ne) + w.shape[1:])
            return folded[rows].reshape((-1,) + w.shape[1:])

        new_prep = {
            "lam_w": tuple(
                fold_take(w, sp.nelem)
                for w, sp in zip(prep["lam_w"], self.spaces)
            ),
            "mu_w": tuple(
                fold_take(w, sp.nelem)
                for w, sp in zip(prep["mu_w"], self.spaces)
            ),
            "dinv": tuple(jnp.asarray(d)[rows] for d in prep["dinv"]),
            "lmax": tuple(jnp.asarray(l)[rows] for l in prep["lmax"]),
            "chol": jnp.asarray(prep["chol"])[rows],
        }
        if self._split_fine:
            ne = self.fine_space.nelem
            new_prep["lam_w_solve"] = fold_take(prep["lam_w_solve"], ne)
            new_prep["mu_w_solve"] = fold_take(prep["mu_w_solve"], ne)
        return self._put(new_state), self._put(new_prep)

    def copy_prep_rows(self, prep: dict, src, dst) -> dict:
        """Duplicate prepared batch rows: row ``dst[i]`` takes row
        ``src[i]``'s derived data (weighted fields, smoother dinv/lmax,
        coarse factor) bitwise.  Since prep depends only on a row's
        materials (geometry is shared), a refilled slot whose materials
        match an already-prepared row can skip ``prepare`` — no power
        iterations, no refactorization — which is the common case for
        serving traffic with a bounded material vocabulary."""
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)

        def fold_copy(w, ne):
            s = w.shape[0] // ne
            f = jnp.asarray(w).reshape((s, ne) + w.shape[1:])
            return f.at[dst].set(f[src]).reshape((-1,) + w.shape[1:])

        def row_copy(a):
            a = jnp.asarray(a)
            return a.at[dst].set(a[src])

        new_prep = {
            "lam_w": tuple(
                fold_copy(w, sp.nelem)
                for w, sp in zip(prep["lam_w"], self.spaces)
            ),
            "mu_w": tuple(
                fold_copy(w, sp.nelem)
                for w, sp in zip(prep["mu_w"], self.spaces)
            ),
            "dinv": tuple(row_copy(d) for d in prep["dinv"]),
            "lmax": tuple(row_copy(l) for l in prep["lmax"]),
            "chol": row_copy(prep["chol"]),
        }
        if self._split_fine:
            ne = self.fine_space.nelem
            new_prep["lam_w_solve"] = fold_copy(prep["lam_w_solve"], ne)
            new_prep["mu_w_solve"] = fold_copy(prep["mu_w_solve"], ne)
        return self._put(new_prep)

    # -- host (de)serialization ----------------------------------------------
    # The checkpoint contract for fault-tolerant serving
    # (repro.serve.recovery): a resumable (state, prep) pair round-trips
    # through flat {name: host numpy array} dicts BITWISE — chunked
    # resumption is exact (see bpcg_chunk), so a restored flight that
    # re-enters run_chunk with these arrays finishes with the same
    # solutions and iteration counts as the uninterrupted run.  The name
    # vocabulary is self-describing per solver: BpcgState field names
    # for the state; ``lam_w{i}``/``mu_w{i}`` per hierarchy level,
    # ``dinv{i}``/``lmax{i}`` per smoothed level, ``chol``, and (for
    # genuinely mixed precision policies) the ``lam_w_solve``/
    # ``mu_w_solve`` fine-level twins for the prep.

    def state_dtype(self, field: str):
        """The dtype contract of one BpcgState field under this solver's
        precision policy (checkpoint restore casts through this, so a
        manifest written by the same policy round-trips bitwise and a
        mismatched one fails loudly in the numerics, not silently)."""
        if field in ("iters", "stall"):
            return np.int32
        if field in ("active", "stalled"):
            return np.bool_
        return np.dtype(self.dtype)

    def state_to_host(self, state: BpcgState) -> dict[str, np.ndarray]:
        """Host-gathered flat snapshot of a resumable state: one numpy
        array per BpcgState field, bitwise."""
        return {
            fld.name: np.asarray(jax.device_get(getattr(state, fld.name)))
            for fld in dataclasses.fields(BpcgState)
        }

    def state_from_host(
        self, arrays: dict[str, np.ndarray], *, place: bool = True
    ) -> BpcgState:
        """Rebuild a :class:`BpcgState` from a :meth:`state_to_host`
        snapshot, re-laid-out over THIS solver's scenario mesh — the
        elastic-restore path: the snapshot may come from a process with
        a different device count.  With ``place=False`` the state stays
        host-resident and unvalidated (for a ``take_rows`` re-bucketing
        immediately after, when the old batch does not divide the new
        mesh)."""
        state = BpcgState(
            **{
                fld.name: np.asarray(
                    arrays[fld.name], dtype=self.state_dtype(fld.name)
                )
                for fld in dataclasses.fields(BpcgState)
            }
        )
        if not place:
            return state
        self._check_batch(state.x.shape[0], "state_from_host")
        return self._put(state)

    def prep_to_host(self, prep: dict) -> dict[str, np.ndarray]:
        """Host-gathered flat snapshot of a prep pytree (see the
        contract note above for the name vocabulary)."""
        out: dict[str, np.ndarray] = {}
        get = lambda a: np.asarray(jax.device_get(a))
        for i, (lw, mw) in enumerate(zip(prep["lam_w"], prep["mu_w"])):
            out[f"lam_w{i}"] = get(lw)
            out[f"mu_w{i}"] = get(mw)
        for i, (d, l) in enumerate(zip(prep["dinv"], prep["lmax"])):
            out[f"dinv{i}"] = get(d)
            out[f"lmax{i}"] = get(l)
        out["chol"] = get(prep["chol"])
        if self._split_fine:
            out["lam_w_solve"] = get(prep["lam_w_solve"])
            out["mu_w_solve"] = get(prep["mu_w_solve"])
        return out

    def prep_from_host(
        self, arrays: dict[str, np.ndarray], *, place: bool = True
    ) -> dict:
        """Rebuild a prep pytree from a :meth:`prep_to_host` snapshot
        (``place`` as in :meth:`state_from_host`).  Raises KeyError if
        the snapshot's level structure does not match this solver —
        e.g. a checkpoint from a different discretization or a mixed
        policy's twins fed to a uniform-policy solver."""
        n_lv = len(self.spaces)
        prep = {
            "lam_w": tuple(arrays[f"lam_w{i}"] for i in range(n_lv)),
            "mu_w": tuple(arrays[f"mu_w{i}"] for i in range(n_lv)),
            "dinv": tuple(arrays[f"dinv{i}"] for i in range(n_lv - 1)),
            "lmax": tuple(arrays[f"lmax{i}"] for i in range(n_lv - 1)),
            "chol": arrays["chol"],
        }
        if self._split_fine:
            prep["lam_w_solve"] = arrays["lam_w_solve"]
            prep["mu_w_solve"] = arrays["mu_w_solve"]
        if not place:
            return prep
        self._check_batch(prep["chol"].shape[0], "prep_from_host")
        return self._put(prep)

    # -- traced bodies -------------------------------------------------------
    def _restrict_field(self, field, level: int):
        """Restrict a (S, nelem_fine) per-element coefficient field to
        hierarchy level ``level`` by averaging each level element's fine
        descendants.  The reduction is a pairwise halving tree over the
        (power-of-two) descendant count, so it is *exact* whenever all
        descendants of an element carry the same value — which is what
        makes a piecewise-constant array field reproduce the equivalent
        attribute-dict scenario bit-for-bit on every level.  Identity
        (no gather) on levels that share the fine mesh."""
        desc = self._desc_idx[level]
        if desc is None:
            return field
        g = field[:, desc]  # (S, nelem_level, n_children)
        k = g.shape[-1]
        while g.shape[-1] > 1:
            g = g[..., 0::2] + g[..., 1::2]
        return g[..., 0] / k

    def _prepare_body(self, lam_vals, mu_vals, reset_mask, prep) -> dict:
        """Fold the (S, nelem_fine) material fields of the masked rows
        into the per-level weighted fields in place (coarser levels via
        :meth:`_restrict_field`), and recompute the derived per-scenario
        data (smoother dinv/lambda_max, coarse Cholesky) for exactly
        those rows; unmasked rows keep their prep bitwise."""
        s = lam_vals.shape[0]
        lam_vals, mu_vals, reset_mask, prep = self._pin(
            (lam_vals, mu_vals, reset_mask, prep)
        )
        lam_w, mu_w, dinv, lmax = [], [], [], []
        chol = None
        for i, base in enumerate(self._base_ops):
            sp = self.spaces[i]
            prev = base.with_material_weights(
                prep["lam_w"][i], prep["mu_w"][i], s
            )
            op = prev.with_materials_rows(
                self._restrict_field(lam_vals, i),
                self._restrict_field(mu_vals, i),
                reset_mask,
            )
            lam_w.append(self._pin(op.lam_w))
            mu_w.append(self._pin(op.mu_w))
            cop = op.constrained()
            if i == 0:
                # Probe at the V-cycle dtype (the operator's own), then
                # factor at the coarse dtype — mixed-bf16 probes through
                # a bf16 operator but holds the Cholesky at f32, where
                # the factorization is still numerically viable.
                K = probe_coarse_matrix(
                    cop, sp.nscalar, s, self.precond_dtype,
                    shard_mesh=self.mesh,
                )
                L = jnp.linalg.cholesky(K.astype(self.coarse_dtype))
                chol = self._pin(
                    jnp.where(reset_mask[:, None, None], L, prep["chol"])
                )
            else:
                sm = ChebyshevSmoother.setup(
                    cop,
                    cop.diagonal(),
                    shape=(s, sp.nscalar, 3),
                    dtype=self.precond_dtype,
                    degree=self.cheb_degree,
                    power_iters=self.power_iters,
                    batch_dims=1,
                    shard_mesh=self.mesh,
                )
                dinv.append(
                    self._pin(
                        jnp.where(
                            reset_mask[:, None, None],
                            sm.dinv,
                            prep["dinv"][i - 1],
                        )
                    )
                )
                lmax.append(
                    self._pin(
                        jnp.where(reset_mask, sm.lmax, prep["lmax"][i - 1])
                    )
                )
        out = {
            "lam_w": tuple(lam_w),
            "mu_w": tuple(mu_w),
            "dinv": tuple(dinv),
            "lmax": tuple(lmax),
            "chol": chol,
        }
        if self._split_fine:
            # Solve-dtype twin of the fine-level weighted fields: the
            # outer Krylov's operator apply must run at full precision
            # even while the smoother streams the reduced copy.
            prev = self._fine_base_solve.with_material_weights(
                prep["lam_w_solve"], prep["mu_w_solve"], s
            )
            op = prev.with_materials_rows(
                lam_vals, mu_vals, reset_mask
            )
            out["lam_w_solve"] = self._pin(op.lam_w)
            out["mu_w_solve"] = self._pin(op.mu_w)
        return out

    def _build_from_prep(self, prep):
        """Hierarchy + preconditioner from a prep pytree: binds the
        stored weighted fields and smoother data — no power iterations,
        no probing, no factorization.

        Returns ``(levels, gmg, A, M)``: ``A`` is the outer Krylov
        operator at ``solve_dtype`` (the fine level's solve-dtype twin
        under a genuinely mixed policy, the fine V-cycle level
        otherwise) and ``M`` the preconditioner with the solve<->precond
        cast boundary folded in (identity casts under uniform
        policies)."""
        s = prep["chol"].shape[0]
        levels = []
        for i, base in enumerate(self._base_ops):
            sp = self.spaces[i]
            op = base.with_material_weights(
                prep["lam_w"][i], prep["mu_w"][i], s
            )
            cop = op.constrained()
            smoother = None
            if i > 0:
                smoother = ChebyshevSmoother(
                    A=cop,
                    dinv=prep["dinv"][i - 1],
                    lmax=prep["lmax"][i - 1],
                    degree=self.cheb_degree,
                )
            levels.append(
                Level(
                    space=sp,
                    operator=op,
                    constrained=cop,
                    smoother=smoother,
                    ess_mask=op.ess_mask,
                )
            )
        coarse = cholesky_solver(prep["chol"], shard_mesh=self.mesh)
        if jnp.dtype(self.coarse_dtype) != jnp.dtype(self.precond_dtype):
            inner, cdt, pdt = coarse, self.coarse_dtype, self.precond_dtype
            coarse = lambda r: inner(r.astype(cdt)).astype(pdt)
        gmg = GMGPreconditioner(
            levels=levels,
            transfers=self.transfers,
            coarse_solve=coarse,
        )
        if self._split_fine:
            fine_solve = self._fine_base_solve.with_material_weights(
                prep["lam_w_solve"], prep["mu_w_solve"], s
            )
            A = fine_solve.constrained()
            sdt, pdt = self.dtype, self.precond_dtype
            M = lambda r: gmg(r.astype(pdt)).astype(sdt)
        else:
            A = levels[-1].constrained
            M = gmg
        return levels, gmg, A, M

    def _rhs(self, tractions):
        b = self._traction_pattern[None, :, None] * tractions[:, None, :]
        return self._pin(
            jnp.where(self._fine_ess, 0.0, b)  # homogeneous elimination
        )

    def _prepare_impl(self, lam_vals, mu_vals, reset_mask, prep) -> dict:
        return self._prepare_body(lam_vals, mu_vals, reset_mask, prep)

    def _chunk_impl(
        self, tractions, rel_tol, reset_mask, state, prep, k_iters,
        *, do_reset: bool,
    ) -> tuple[BpcgState, Any]:
        state, prep = self._pin(state), self._pin(prep)
        levels, gmg, A, M = self._build_from_prep(prep)
        if do_reset:
            fresh = bpcg_init(A, self._rhs(tractions), M=M, rel_tol=rel_tol)
            state = merge_states(reset_mask, fresh, state)
        start_iters = state.iters
        out = bpcg_chunk(
            A, state, M=M, k_iters=k_iters, maxiter=self.maxiter,
            stall_iters=self.stall_iters, stall_rtol=self.stall_rtol,
        )
        if self.stall_iters > 0:
            out = true_residual_audit(A, M, self._rhs(tractions), out)
        # Per-row iterations consumed by THIS chunk: the scheduling
        # policies read retire cadence from this (S,) vector, so the
        # host never has to fetch the full state mid-flight.
        return self._pin(out), self._pin(out.iters - start_iters)

    def _solve_impl(self, lam_vals, mu_vals, tractions, rel_tol):
        s = lam_vals.shape[0]
        prep = self._prepare_body(
            lam_vals, mu_vals, jnp.ones((s,), dtype=bool), self.empty_prep(s)
        )
        levels, gmg, A, M = self._build_from_prep(prep)
        state = bpcg_init(A, self._rhs(tractions), M=M, rel_tol=rel_tol)
        state = bpcg_chunk(
            A, state, M=M, k_iters=None, maxiter=self.maxiter,
            stall_iters=self.stall_iters, stall_rtol=self.stall_rtol,
        )
        if self.stall_iters > 0:
            state = true_residual_audit(A, M, self._rhs(tractions), state)
        return bpcg_result(self._pin(state))

    # -- public entry --------------------------------------------------------
    def pack_materials(self, materials: list) -> tuple[Any, Any]:
        """Normalize a length-S scenario list into (S, nelem_fine)
        per-element coefficient fields.

        Each entry is either an attribute -> (lambda, mu) dict
        (piecewise-constant by mesh attribute) or a ``(lam_e, mu_e)``
        array pair of shape (nelem_fine,) giving one coefficient per
        FINE-mesh element; the two forms mix freely within one batch.
        Coarser hierarchy levels see each field through an exact
        power-of-two descendant average (:meth:`_restrict_field`), so a
        piecewise-constant array reproduces the equivalent dict scenario
        bit-for-bit.  Raises ValueError naming the scenario plus the
        missing/offending attribute (dicts) or the mismatched shape /
        first non-positive element index (arrays)."""
        ne = self.fine_space.nelem
        fine_mesh = self.fine_space.mesh
        lam = np.empty((len(materials), ne))
        mu = np.empty_like(lam)
        for si, m in enumerate(materials):
            where = f"scenario {si} materials"
            if isinstance(m, dict):
                check_material_dict(m, self.attr_values, where=where)
                lam[si], mu[si] = material_fields(fine_mesh, m)
            else:
                if getattr(m, "ndim", None) is not None and np.ndim(m) != 1:
                    # A bare 2-D array entry means the caller passed the
                    # raw stacked (lam_2d, mu_2d) pair itself instead of
                    # a scenario list — unpacking its rows here would
                    # silently cross-pair lambda/mu across scenarios.
                    raise TypeError(
                        f"{where}: got a {np.ndim(m)}-D array as a "
                        f"scenario entry; pack_materials takes a LIST "
                        f"of per-scenario entries (dicts or (lam_e, "
                        f"mu_e) pairs) — for a pre-stacked (S, nelem) "
                        f"pair use list(zip(lam, mu))"
                    )
                try:
                    lam_e, mu_e = m
                except (TypeError, ValueError):
                    raise TypeError(
                        f"{where}: expected an attribute->(lambda, mu) "
                        f"dict or a (lam_e, mu_e) array pair, got "
                        f"{type(m).__name__!r}"
                    ) from None
                lam[si], mu[si] = check_material_fields(
                    lam_e, mu_e, ne, where=where
                )
        return jnp.asarray(lam, self.dtype), jnp.asarray(mu, self.dtype)

    def prepare(self, lam_vals, mu_vals, reset_mask, prep) -> dict:
        """Jitted: fold the masked rows' new materials into the per-row
        operator fields and refresh their derived data (see
        ``_prepare_body``).

        ``lam_vals``/``mu_vals`` are (S, nelem_fine) per-element fields
        (the output of :meth:`pack_materials`); S must divide the device
        mesh when sharded — the fields ride the same axis-0
        NamedSharding as the rest of the prep pytree.  Rows NOT selected
        by ``reset_mask`` keep their prep bitwise.  One trace per batch
        size."""
        s, ne = np.shape(lam_vals)
        self._check_batch(int(s), "prepare")
        if ne != self.fine_space.nelem:
            raise ValueError(
                f"prepare: material fields have {ne} elements per row, "
                f"expected nelem_fine = {self.fine_space.nelem}"
            )
        lam_vals, mu_vals, reset_mask, prep = self._put(
            (lam_vals, mu_vals, reset_mask, prep)
        )
        return self._jit_prepare(lam_vals, mu_vals, reset_mask, prep)

    def run_chunk(
        self, tractions, rel_tol, reset_mask, state, prep, k_iters,
        *, do_reset: bool = False,
    ) -> tuple[BpcgState, Any]:
        """Jitted: advance the batch by up to ``k_iters`` iterations.
        With ``do_reset`` the masked rows are first re-initialized for
        their (new) tractions/tolerances: x = 0, r = b, fresh thresholds,
        iteration count 0 (their materials must already be folded into
        ``prep`` via :meth:`prepare` or :meth:`copy_prep_rows`); rows
        outside the mask resume bit-identically.  The batch size must
        divide the device mesh when sharded — padding rows are the
        caller's job (see :meth:`pad_scenarios`).  ``k_iters`` is a
        runtime argument — any chunk length reuses the same compiled
        program.

        Returns ``(state, consumed)`` where ``consumed`` is the (S,)
        int32 count of iterations each row executed inside this chunk
        (0 for rows that entered inactive).  It is the cadence signal
        the adaptive chunk policies feed on: one small vector instead of
        an extra mid-flight fetch of the full state."""
        tractions = jnp.asarray(tractions, self.dtype)
        self._check_batch(int(tractions.shape[0]), "run_chunk")
        rel = jnp.broadcast_to(
            jnp.asarray(rel_tol, self.dtype), (tractions.shape[0],)
        )
        tractions, rel, reset_mask, state, prep = self._put(
            (tractions, rel, reset_mask, state, prep)
        )
        return self._jit_chunk(
            tractions, rel, reset_mask, state, prep,
            jnp.asarray(k_iters, dtype=jnp.int32), do_reset=do_reset,
        )

    def _f64_fallback_solver(self) -> "BatchedGMGSolver":
        """The lazily built f64 twin that re-solves stalled rows: same
        discretization/geometry, the ``f64`` policy (which never
        recurses — its own detector is disarmed)."""
        if self._f64_twin is None:
            self._f64_twin = BatchedGMGSolver(
                self.coarse_mesh,
                self.n_h_refine,
                self.p_target,
                assembly=self.assembly,
                precision="f64",
                cheb_degree=self.cheb_degree,
                power_iters=self.power_iters,
                ess_faces=self._ess_faces,
                traction_face=self._traction_face,
                maxiter=self.maxiter,
                pallas_lane=self.pallas_lane,
                mesh=self.mesh,
            )
        return self._f64_twin

    def solve(
        self,
        materials: list[dict],
        tractions,
        rel_tol,
    ) -> BPCGResult:
        """Solve S scenarios in one compiled program.

        materials: length-S list; each entry an attribute->(lambda, mu)
                   dict or a (lam_e, mu_e) per-element array pair of
                   shape (nelem_fine,) — the forms mix freely (see
                   :meth:`pack_materials`)
        tractions: (S, 3) traction vectors on the traction face
        rel_tol:   scalar or (S,) per-scenario relative tolerances

        Sharded solvers pad S up to a multiple of the device count with
        born-converged rows (see :meth:`pad_scenarios`) and slice them
        off the result: callers see exactly the S rows they asked for.

        Reduced-precision policies carry the f64 safety net: rows the
        stagnation detector flagged (their requested tolerance sits
        below the reduced arithmetic's residual floor) are re-solved on
        the lazily built f64 twin and merged back — ``fallback`` marks
        them, ``iterations`` counts the total work (reduced + f64
        passes), and the merged result is promoted to f64 (only
        observable for the uniform ``f32`` policy; mixed policies
        already solve in f64)."""
        materials, tractions, rel_tol, s = self.pad_scenarios(
            materials, tractions, rel_tol
        )
        lam_vals, mu_vals = self.pack_materials(materials)
        tr = jnp.asarray(tractions, self.dtype)
        rel = jnp.asarray(rel_tol, self.dtype)
        lam_vals, mu_vals, tr, rel = self._put(
            (lam_vals, mu_vals, tr, rel)
        )
        res = self._jit_solve(lam_vals, mu_vals, tr, rel)
        if len(materials) > s:
            res = BPCGResult(
                **{
                    fld.name: getattr(res, fld.name)[:s]
                    for fld in dataclasses.fields(BPCGResult)
                }
            )
        if self.precision.reduced:
            need = np.asarray(res.stalled) & ~np.asarray(res.converged)
            if need.any():
                rows = np.nonzero(need)[0]
                twin = self._f64_fallback_solver()
                sub = twin.solve(
                    [materials[int(i)] for i in rows],
                    np.asarray(tractions, dtype=np.float64)[rows],
                    np.asarray(rel_tol, dtype=np.float64)[rows],
                )
                res = _merge_fallback_rows(res, sub, rows)
        return res
