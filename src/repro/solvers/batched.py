"""Batched multi-scenario GMG-PCG: many parameterized elasticity solves
in one device program.

The paper's end-to-end solve (fused PAop operator + GMG-preconditioned
CG) runs one scenario at a time; this module amortizes compilation and
hardware occupancy across a *batch* of scenarios (different materials,
tractions, tolerances) the way the LM serving engine batches decode
requests:

* ``bpcg`` — PCG over a leading scenario axis inside a single
  ``lax.while_loop``.  Per-scenario convergence is tracked with an
  active mask: converged scenarios' ``x``/``r``/``d`` are frozen (their
  step sizes are forced to zero and direction updates gated), the loop
  runs until every scenario converges or hits ``maxiter``, and
  per-scenario iteration counts are reported.

* ``BatchedGMGSolver`` — a compiled solve *program* for one
  discretization ``(coarse_mesh, n_h_refine, p)``.  Geometry (spaces,
  transfers, gather maps, basis tables, traction pattern) is built once
  at construction; materials, tractions and tolerances are **runtime
  arguments** to a single jitted function that rebinds per-scenario
  material fields through ``ElasticityOperator.with_materials``, runs
  per-scenario power iterations for the Chebyshev smoothers, factors
  the coarse level with a batched in-trace Cholesky, and drives ``bpcg``
  with the batched GMG V-cycle.  Re-solving with new scenario data hits
  the compiled program — no retrace, no hierarchy rebuild.

The scenario axis is threaded through ``ChebyshevSmoother``,
``GMGPreconditioner`` and ``Transfer``; operators fold it into the
element axis so the fused PA kernels (including Pallas) run unchanged
on an S-times-larger grid.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import DEFER_MATERIALS, ElasticityOperator
from repro.fem.mesh import HexMesh
from repro.fem.space import H1Space
from repro.fem.transfer import make_transfer
from repro.solvers.chebyshev import ChebyshevSmoother, _expand
from repro.solvers.coarse import make_batched_coarse_solver
from repro.solvers.gmg import GMGPreconditioner, Level, hierarchy_spaces

__all__ = ["bpcg", "BPCGResult", "BatchedGMGSolver"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BPCGResult:
    x: Any  # (S, ...) solutions
    iterations: Any  # (S,) int32 per-scenario counts
    converged: Any  # (S,) bool
    final_norm: Any  # (S,) sqrt((B r, r)) at exit
    initial_norm: Any  # (S,)


def _dots(a, b):
    """Per-scenario inner products: contract everything but axis 0."""
    return jnp.sum(
        a.reshape(a.shape[0], -1) * b.reshape(b.shape[0], -1), axis=1
    )


# (S,) coefficients broadcast against (S, ...) vectors with the same
# right-pad rule the batched Chebyshev smoother uses.
_col = _expand


def bpcg(
    A: Callable,
    b,
    M: Callable | None = None,
    *,
    x0=None,
    rel_tol=1e-6,
    abs_tol=0.0,
    maxiter: int = 5000,
) -> BPCGResult:
    """MFEM-style PCG over a leading scenario axis with masked
    convergence.

    ``A`` and ``M`` map (S, ...) batches to (S, ...) batches with no
    cross-scenario coupling; ``rel_tol``/``abs_tol`` may be scalars or
    (S,) arrays (per-scenario tolerances).  Scenarios that converge stop
    updating (alpha forced to 0, direction frozen) while the rest keep
    iterating; the loop exits when no scenario is active.  A scenario
    with a zero RHS is born converged (0 iterations) — this is also what
    makes padded batch slots free.
    """
    if M is None:
        M = lambda r: r
    x = jnp.zeros_like(b) if x0 is None else x0
    s = b.shape[0]

    r = b - A(x)
    z = M(r)
    nom0 = _dots(z, r)
    rel = jnp.broadcast_to(jnp.asarray(rel_tol, dtype=nom0.dtype), (s,))
    ab = jnp.broadcast_to(jnp.asarray(abs_tol, dtype=nom0.dtype), (s,))
    # MFEM: r0 = max(nom0 * rel_tol^2, abs_tol^2), per scenario.
    threshold = jnp.maximum(nom0 * rel**2, ab**2)
    active0 = nom0 > threshold
    iters0 = jnp.zeros((s,), dtype=jnp.int32)

    def cond(state):
        return jnp.any(state[-1])

    def body(state):
        x, r, z, d, nom, iters, active = state
        ad = A(d)
        den = _dots(d, ad)
        # Inactive rows get alpha = 0 (frozen); den == 0 cannot occur for
        # an active SPD row (d != 0 there) but is guarded so one bad or
        # retired scenario can never NaN the rest of the batch.
        ok = active & (den > 0)
        alpha = jnp.where(ok, nom / jnp.where(den == 0, 1.0, den), 0.0)
        x = x + _col(alpha, x.ndim) * d
        r = r - _col(alpha, r.ndim) * ad
        z = M(r)
        betanom = _dots(z, r)
        beta = jnp.where(ok, betanom / jnp.where(nom == 0, 1.0, nom), 0.0)
        d = jnp.where(
            _col(active, d.ndim), z + _col(beta, d.ndim) * d, d
        )
        nom = jnp.where(active, betanom, nom)
        # Count only real steps (ok), matching scalar pcg: an aborted
        # degenerate direction (den <= 0) takes no step and adds none.
        iters = iters + ok.astype(jnp.int32)
        active = ok & (nom > threshold) & (iters < maxiter)
        return (x, r, z, d, nom, iters, active)

    state = (x, r, z, z, nom0, iters0, active0)
    x, r, z, d, nom, iters, active = jax.lax.while_loop(cond, body, state)
    return BPCGResult(
        x=x,
        iterations=iters,
        converged=nom <= threshold,
        final_norm=jnp.sqrt(jnp.abs(nom)),
        initial_norm=jnp.sqrt(jnp.abs(nom0)),
    )


class BatchedGMGSolver:
    """One compiled multi-scenario solve program per discretization.

    Construction builds everything material-independent for the beam
    benchmark family: the mesh/degree hierarchy, transfer operators,
    element->attribute index maps, and the boundary traction pattern.
    ``solve`` takes per-scenario attribute materials, traction vectors
    and tolerances; its body is jitted once per batch size and reused
    for every subsequent batch of the same shape.
    """

    def __init__(
        self,
        coarse_mesh: HexMesh,
        n_h_refine: int,
        p_target: int,
        *,
        assembly: str = "paop",
        dtype=jnp.float64,
        cheb_degree: int = 2,
        power_iters: int = 10,
        ess_faces=("x0",),
        traction_face: str = "x1",
        maxiter: int = 200,
        pallas_interpret: bool = True,
    ):
        if assembly == "fa":
            raise ValueError("batched solves are matrix-free ('fa' unsupported)")
        self.coarse_mesh = coarse_mesh
        self.n_h_refine = n_h_refine
        self.p_target = p_target
        self.assembly = assembly
        self.dtype = dtype
        self.cheb_degree = cheb_degree
        self.power_iters = power_iters
        self.maxiter = maxiter

        spaces = hierarchy_spaces(coarse_mesh, n_h_refine, p_target)
        self.spaces = spaces

        # Attribute vocabulary (static): scenario materials arrive as
        # (S, n_attr) value arrays indexed by this ordering.
        self.attr_values: tuple[int, ...] = tuple(
            int(a) for a in np.unique(coarse_mesh.attributes())
        )
        attr_lut = {a: i for i, a in enumerate(self.attr_values)}

        self._base_ops = []
        self._attr_idx = []
        for i, sp in enumerate(spaces):
            lvl_assembly = assembly if i > 0 else "paop"
            # Base operators are geometry/tables carriers only: every
            # solve binds per-scenario fields via with_materials.
            op = ElasticityOperator(
                sp,
                assembly=lvl_assembly,
                materials=DEFER_MATERIALS,
                dtype=dtype,
                ess_faces=ess_faces,
                pallas_interpret=pallas_interpret,
            )
            self._base_ops.append(op)
            self._attr_idx.append(
                np.asarray(
                    [attr_lut[int(a)] for a in sp.mesh.attributes()],
                    dtype=np.int32,
                )
            )

        self.transfers = [
            make_transfer(spaces[i], spaces[i + 1], dtype=dtype)
            for i in range(len(spaces) - 1)
        ]
        # traction_rhs is linear in the traction vector and separable:
        # F = pattern (x) t, so probing with t = e_x yields the pattern.
        fine = spaces[-1]
        self._traction_pattern = jnp.asarray(
            fine.traction_rhs(traction_face, (1.0, 0.0, 0.0))[:, 0],
            dtype=dtype,
        )
        self._fine_ess = jnp.asarray(self._base_ops[-1].ess_mask)
        self._jit_solve = jax.jit(self._solve_impl)

    @property
    def fine_space(self) -> H1Space:
        return self.spaces[-1]

    # -- traced body ---------------------------------------------------------
    def _solve_impl(self, lam_vals, mu_vals, tractions, rel_tol):
        s = lam_vals.shape[0]
        levels = []
        coarse_solve = None
        for i, (base, idx) in enumerate(zip(self._base_ops, self._attr_idx)):
            sp = self.spaces[i]
            op = base.with_materials(lam_vals[:, idx], mu_vals[:, idx])
            cop = op.constrained()
            smoother = None
            if i == 0:
                coarse_solve = make_batched_coarse_solver(
                    cop, sp.nscalar, s, self.dtype
                )
            else:
                smoother = ChebyshevSmoother.setup(
                    cop,
                    cop.diagonal(),
                    shape=(s, sp.nscalar, 3),
                    dtype=self.dtype,
                    degree=self.cheb_degree,
                    power_iters=self.power_iters,
                    batch_dims=1,
                )
            levels.append(
                Level(
                    space=sp,
                    operator=op,
                    constrained=cop,
                    smoother=smoother,
                    ess_mask=op.ess_mask,
                )
            )
        gmg = GMGPreconditioner(
            levels=levels, transfers=self.transfers, coarse_solve=coarse_solve
        )
        b = self._traction_pattern[None, :, None] * tractions[:, None, :]
        b = jnp.where(self._fine_ess, 0.0, b)  # homogeneous elimination
        return bpcg(
            levels[-1].constrained,
            b,
            M=gmg,
            rel_tol=rel_tol,
            maxiter=self.maxiter,
        )

    # -- public entry --------------------------------------------------------
    def pack_materials(self, materials: list[dict]) -> tuple[Any, Any]:
        """(S,) list of attribute->(lambda, mu) dicts -> (S, n_attr) value
        arrays in ``attr_values`` order."""
        lam = np.empty((len(materials), len(self.attr_values)))
        mu = np.empty_like(lam)
        for si, m in enumerate(materials):
            missing = set(self.attr_values) - set(m)
            if missing:
                raise ValueError(
                    f"scenario {si} materials missing mesh attributes "
                    f"{sorted(missing)} (mesh has {self.attr_values})"
                )
            for ai, a in enumerate(self.attr_values):
                lam[si, ai], mu[si, ai] = m[a]
        return jnp.asarray(lam, self.dtype), jnp.asarray(mu, self.dtype)

    def solve(
        self,
        materials: list[dict],
        tractions,
        rel_tol,
    ) -> BPCGResult:
        """Solve S scenarios in one compiled program.

        materials: length-S list of attribute->(lambda, mu) dicts
        tractions: (S, 3) traction vectors on the traction face
        rel_tol:   scalar or (S,) per-scenario relative tolerances
        """
        lam_vals, mu_vals = self.pack_materials(materials)
        tractions = jnp.asarray(tractions, self.dtype)
        rel = jnp.broadcast_to(
            jnp.asarray(rel_tol, self.dtype), (len(materials),)
        )
        return self._jit_solve(lam_vals, mu_vals, tractions, rel)
