"""Geometric multigrid preconditioner (paper Sec. 3).

Hierarchy: starting from the coarse mesh, ``n_h_refine`` uniform
refinements give levels 0..r at degree p_min = 1; p-refinements then
double the degree until the finest level reaches the target p
(appending p_target itself when it is not a power of two, e.g. the
Fig. 5 sweep's p = 6).  Fine and intermediate levels use the selectable
matrix-free operator with Chebyshev(k=2)-Jacobi smoothing; the coarsest
level is assembled and solved per :mod:`repro.solvers.coarse`.

FA+GMG, PA+GMG and PAop+GMG differ only in the operator handle used on
fine/intermediate levels — exactly the paper's experimental contract.

Scenario batching: passing ``materials`` as a *sequence* of
attribute->(lambda, mu) dicts builds one hierarchy whose operators,
smoothers, transfers and coarse solve all carry a leading scenario axis
(S, nscalar, 3); the V-cycle below is shape-agnostic and preconditions
all scenarios in one pass (consumed by repro.solvers.batched.bpcg).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import ElasticityOperator
from repro.fem.mesh import HexMesh
from repro.fem.space import H1Space
from repro.fem.transfer import Transfer, make_transfer
from repro.solvers.chebyshev import ChebyshevSmoother
from repro.solvers.coarse import make_coarse_solver

__all__ = [
    "p_chain",
    "hierarchy_spaces",
    "build_hierarchy",
    "GMGPreconditioner",
    "Level",
]


def p_chain(p_target: int) -> list[int]:
    """Degree ladder 1 -> 2 -> 4 -> ... (-> p_target)."""
    chain = [1]
    while chain[-1] * 2 <= p_target:
        chain.append(chain[-1] * 2)
    if chain[-1] != p_target:
        chain.append(p_target)
    return chain


def hierarchy_spaces(
    coarse_mesh: HexMesh, n_h_refine: int, p_target: int
) -> list[H1Space]:
    """The GMG level ladder, coarse -> fine: ``n_h_refine`` uniform
    h-refinements at p = 1, then p-doubling on the finest mesh."""
    meshes = [coarse_mesh]
    for _ in range(n_h_refine):
        meshes.append(meshes[-1].refined())
    spaces = [H1Space(m, 1) for m in meshes]
    for p in p_chain(p_target)[1:]:
        spaces.append(H1Space(meshes[-1], p))
    return spaces


@dataclasses.dataclass
class Level:
    space: H1Space
    operator: ElasticityOperator
    constrained: Callable  # ConstrainedOperator
    smoother: ChebyshevSmoother | None
    ess_mask: Any


@dataclasses.dataclass
class GMGPreconditioner:
    levels: list[Level]  # coarse -> fine
    transfers: list[Transfer]  # transfers[i]: level i -> level i+1
    coarse_solve: Callable

    @property
    def fine(self) -> Level:
        return self.levels[-1]

    def __call__(self, r):
        return self._vcycle(len(self.levels) - 1, r)

    def _vcycle(self, l: int, b):
        if l == 0:
            return self.coarse_solve(b)
        lev = self.levels[l]
        x = lev.smoother(b)  # pre-smooth from zero initial guess
        r = b - lev.constrained(x)
        t = self.transfers[l - 1]
        rc = t.restrict(r)
        rc = jnp.where(jnp.asarray(self.levels[l - 1].ess_mask), 0.0, rc)
        e = self._vcycle(l - 1, rc)
        x = x + t.prolong(e)
        x = lev.smoother(b, x)  # post-smooth
        return x


def build_hierarchy(
    coarse_mesh: HexMesh,
    n_h_refine: int,
    p_target: int,
    assembly: str = "paop",
    materials=None,
    dtype=jnp.float64,
    cheb_degree: int = 2,
    power_iters: int = 10,
    coarse_method: str = "cholesky",
    ess_faces=("x0",),
    pallas_interpret: bool | None = None,
    pallas_lane: str | None = None,
) -> GMGPreconditioner:
    """Build the paper's GMG preconditioner for the beam benchmark.

    ``pallas_lane`` ("auto"/"compiled"/"interpret", default auto with
    interpret fallback) selects the Pallas lane for every
    ``paop_pallas`` level; the legacy ``pallas_interpret`` bool is
    honored when no lane is given."""
    spaces = hierarchy_spaces(coarse_mesh, n_h_refine, p_target)

    levels: list[Level] = []
    for i, sp in enumerate(spaces):
        is_coarsest = i == 0
        # Coarsest-level operator is only applied inside the inexact
        # pcg_jacobi coarse solve; use the cheap fused operator for it
        # unless the whole hierarchy is FA.
        lvl_assembly = assembly if (not is_coarsest or assembly == "fa") else "paop"
        op = ElasticityOperator(
            sp,
            assembly=lvl_assembly,
            materials=materials,
            dtype=dtype,
            ess_faces=ess_faces,
            pallas_interpret=pallas_interpret,
            pallas_lane=pallas_lane,
        )
        cop = op.constrained()
        smoother = None
        if not is_coarsest:
            diag = cop.diagonal()
            shape = (sp.nscalar, 3)
            if op.nbatch is not None:
                shape = (op.nbatch,) + shape
            smoother = ChebyshevSmoother.setup(
                cop,
                diag,
                shape=shape,
                dtype=dtype,
                degree=cheb_degree,
                power_iters=power_iters,
                batch_dims=1 if op.nbatch is not None else 0,
            )
        levels.append(
            Level(
                space=sp,
                operator=op,
                constrained=cop,
                smoother=smoother,
                ess_mask=op.ess_mask,
            )
        )

    transfers = [
        make_transfer(levels[i].space, levels[i + 1].space, dtype=dtype)
        for i in range(len(levels) - 1)
    ]
    coarse_solve = make_coarse_solver(levels[0].operator, method=coarse_method)
    return GMGPreconditioner(
        levels=levels, transfers=transfers, coarse_solve=coarse_solve
    )
