from repro.solvers.batched import BatchedGMGSolver, BPCGResult, bpcg
from repro.solvers.cg import pcg
from repro.solvers.chebyshev import ChebyshevSmoother
from repro.solvers.gmg import GMGPreconditioner, build_hierarchy

__all__ = [
    "pcg",
    "bpcg",
    "BPCGResult",
    "BatchedGMGSolver",
    "ChebyshevSmoother",
    "GMGPreconditioner",
    "build_hierarchy",
]
