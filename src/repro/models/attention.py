"""Attention: GQA with optional QKV bias, qk-norm, sliding window, and
three execution paths:

* ``full``     — materialized scores; smoke tests and short sequences.
* ``chunked``  — blockwise online-softmax (flash-style) in pure JAX:
  sequential ``lax.map`` over query chunks, ``lax.scan`` over KV chunks
  with a running (max, sum, acc) carry.  Never materializes the S x S
  score matrix — the paper's macro-kernel-fusion insight (avoid the
  operator-wide HBM round trip) applied to attention.  This path is what
  the 32k prefill and 4k training cells compile; the Pallas flash kernel
  (repro.kernels.flash_attention) is the TPU-hardware twin.
* ``decode``   — single-token query against a KV cache (dense or rolling
  sliding-window buffer).

KV heads are kept folded (B, S, K, D) with queries grouped (K, G) — the
GQA structure is exploited rather than broadcast-materialized.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm
from repro.models.rope import apply_mrope, apply_rope

__all__ = ["attn_init", "attention", "decode_attention", "init_kv_cache"]

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, K * hd), dtype),
        "wv": dense_init(ks[2], (d, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(params, x, cfg, positions):
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = jnp.einsum("bsd,dn->bsn", x, params["wq"])
    k = jnp.einsum("bsd,dn->bsn", x, params["wk"])
    v = jnp.einsum("bsd,dn->bsn", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.pos_embed == "rope":
        pos2 = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2, cfg.rope_theta)
        k = apply_rope(k, pos2, cfg.rope_theta)
    # sinusoidal: additive at the embedding layer, nothing to do here.
    return q, k, v


def _full_attention(q, k, v, window: Optional[int]):
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, S, H, hd)


def _chunked_attention(q, k, v, window, q_chunk, k_chunk):
    """Blockwise online-softmax attention (no S x S intermediate).

    Both loop bodies are rematted (flash-attention backward semantics):
    without ``jax.checkpoint`` here, scan/map AD would stack the per-
    (q-chunk, kv-chunk) score and softmax tensors as saved residuals —
    an (nq x nk x B x H x q_chunk x k_chunk) f32 monster that defeats
    the whole point of chunking.  With remat, the backward pass
    recomputes each block's scores from the (small) q/k/v chunks.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, S)
    assert S % q_chunk == 0 and S % k_chunk == 0
    nq, nk = S // q_chunk, S // k_chunk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, nq, q_chunk, K, G, hd)
    # scan iterates the leading axis: put the kv-chunk axis first.
    ks = jnp.moveaxis(k.reshape(B, nk, k_chunk, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, k_chunk, K, hd), 1, 0)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one_q_chunk(args):
        qi, qc = args  # qc: (B, q_chunk, K, G, hd)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def body(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kj) * scale
            kpos = j * k_chunk + jnp.arange(k_chunk)
            mask = qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask, s.astype(jnp.float32), NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (ks, vs, jnp.arange(nk))
        )
        o = acc / l[..., None]
        return jnp.moveaxis(o, 3, 1)  # (B, q_chunk, K, G, hd)

    out = jax.lax.map(one_q_chunk, (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, K, G, hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention(params, x, cfg, positions, impl: str = "auto",
              q_chunk: int = 1024, k_chunk: int = 1024):
    """Full-sequence causal attention; returns (B, S, d_model)."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg, positions)
    if impl == "auto":
        impl = "full" if S <= 1024 else "chunked"
    if impl == "full":
        o = _full_attention(q, k, v, cfg.sliding_window)
    else:
        o = _chunked_attention(q, k, v, cfg.sliding_window, q_chunk, k_chunk)
    return jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1), params["wo"]), (k, v)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------
def init_kv_cache(cfg, batch: int, max_len: int, dtype):
    """Dense cache, or a rolling window buffer under SWA."""
    K, hd = cfg.n_kv_heads, cfg.head_dim_
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, size, K, hd), dtype),
        "v": jnp.zeros((batch, size, K, hd), dtype),
    }


def decode_attention(params, x, cfg, cache, pos, rope_pos=None):
    """One-token step: x (B, 1, d); cache k/v (B, C, K, hd); pos scalar.

    Returns (out (B, 1, d), new_cache).  Under SWA the buffer is rolling
    (slot = pos % window); otherwise slot = pos.  ``rope_pos`` lets the
    caller decouple the rotary position from the cache slot (M-RoPE's
    text positions are offset by the vision-grid extent).
    """
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    G = H // K
    positions = jnp.full((B, 1), pos if rope_pos is None else rope_pos, jnp.int32)
    if cfg.pos_embed == "mrope":
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q, k_new, v_new = _qkv(params, x, cfg, positions)

    size = cache["k"].shape[1]
    slot = pos % size if cfg.sliding_window else pos
    z = jnp.zeros((), jnp.int32)
    at = (z, jnp.asarray(slot, jnp.int32), z, z)
    # cast BEFORE the update: rope returns f32 and dynamic_update_slice
    # would otherwise promote the whole cache carry to f32 — a 2x HBM
    # tax on the largest serving-time resident (measured: a 20 GiB f32
    # stacked-cache temp at qwen1.5-32b decode scale).
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), at)
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), at)

    qg = q.reshape(B, 1, K, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / math.sqrt(hd)
    idx = jnp.arange(size)
    valid = idx <= slot if not cfg.sliding_window else (
        (idx <= slot) | (pos >= size)
    )
    s = jnp.where(valid, s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(B, 1, H * hd)
    out = jnp.einsum("bsn,nd->bsd", o, params["wo"])
    return out, {"k": k, "v": v}
