"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential recurrence) — arXiv:2405.04517, simplified to
the load-bearing structure:

* mLSTM: exponential input gate + forget gate per head, matrix memory
  C in R^{dh x dh}, normalizer n, stabilizer m.  Training/prefill uses a
  *chunkwise* form (quadratic within a chunk, O(1) carry across chunks —
  the same never-materialize-the-LxL-operator move as SSD/sum
  factorization), decode uses the O(1) recurrent form.  Stabilized
  exactly as in the paper: h = (C q) / max(|n . q|, exp(-m)).
* sLSTM: per-head scalar cell/normalizer with block-diagonal recurrent
  feedback R h_{t-1}, exponential gating with the same stabilizer trick,
  evaluated with lax.scan (inherently sequential, as in the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm

__all__ = [
    "mlstm_init", "mlstm_apply", "mlstm_decode", "init_mlstm_state",
    "slstm_init", "slstm_apply", "slstm_decode", "init_slstm_state",
]

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _mdims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    return d_in, H, d_in // H


def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    d_in, H, dh = _mdims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, d_in), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": dense_init(ks[2], (d_in, d_in), dtype),
        "wk": dense_init(ks[3], (d_in, d_in), dtype),
        "wv": dense_init(ks[4], (d_in, d_in), dtype),
        "w_gates": dense_init(ks[5], (d_in, 2 * H), dtype),
        "b_gates": jnp.zeros((2 * H,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "w_down": dense_init(ks[6], (d_in, d), dtype),
    }


def _mlstm_qkvg(params, x, cfg):
    d_in, H, dh = _mdims(cfg)
    B, L, _ = x.shape
    up = jnp.einsum("bld,dn->bln", x, params["w_up"])
    xb, z = up[..., :d_in], up[..., d_in:]
    # causal depthwise conv + silu on the qk branch
    W = params["conv_w"].shape[0]
    padded = jnp.pad(xb, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(
        padded[:, i : i + L, :] * params["conv_w"][i][None, None, :]
        for i in range(W)
    )
    xc = jax.nn.silu(conv + params["conv_b"])
    q = jnp.einsum("bln,nm->blm", xc, params["wq"]).reshape(B, L, H, dh)
    k = jnp.einsum("bln,nm->blm", xc, params["wk"]).reshape(B, L, H, dh)
    v = jnp.einsum("bln,nm->blm", xb, params["wv"]).reshape(B, L, H, dh)
    gates = (
        jnp.einsum("bln,nm->blm", xc, params["w_gates"]).astype(jnp.float32)
        + params["b_gates"]
    )
    li = gates[..., :H]  # log input gate (exp gating: used directly)
    lf = jax.nn.log_sigmoid(gates[..., H:])  # log forget gate
    return q, k, v, li, lf, z, xb


def _mlstm_chunk_scan(q, k, v, li, lf, chunk):
    """Chunkwise stabilized mLSTM. q/k/v (B, L, H, dh); li/lf (B, L, H)."""
    B, L, H, dh = q.shape
    from repro.models.ssm import chunk_len

    Q = chunk_len(L, chunk)
    nc = L // Q
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    qc = q.reshape(B, nc, Q, H, dh).astype(jnp.float32) * scale
    kc = k.reshape(B, nc, Q, H, dh).astype(jnp.float32)
    vc = v.reshape(B, nc, Q, H, dh).astype(jnp.float32)
    lic = li.reshape(B, nc, Q, H)
    lfc = lf.reshape(B, nc, Q, H)
    F = jnp.cumsum(lfc, axis=2)  # inclusive within-chunk cum log-forget

    # pairwise log weights W[t, j] = F_t - F_j + li_j  (t >= j)
    Wlog = F[:, :, :, None, :] - F[:, :, None, :, :] + lic[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    Wlog = jnp.where(tri, Wlog, NEG)

    def step(carry, inp):
        C0, n0, m0 = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qt, kt, vt, Ft, Wt, lit = inp
        # qt (B,Q,H,dh), Ft (B,Q,H), Wt (B,Q,Q,H)
        b = Ft + m0[:, None, :]  # log carry decay at each t
        m = jnp.maximum(b, Wt.max(axis=2))  # (B,Q,H)
        c0 = jnp.exp(b - m)
        P = jnp.exp(Wt - m[:, :, None, :])  # (B,Q,Q,H)
        s = jnp.einsum("bthd,bjhd->btjh", qt, kt)  # scaled q.k
        sw = s * P
        num = jnp.einsum("btjh,bjhd->bthd", sw, vt) + c0[..., None] * jnp.einsum(
            "bhde,bthd->bthe", C0, qt
        )
        # denominator: c0 (n0.q) + sum_j P (k_j.q_t)
        den = c0 * jnp.einsum("bhd,bthd->bth", n0, qt) + jnp.einsum(
            "btjh->bth", sw
        )
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

        # carry to next chunk (t = Q-1 quantities, unstabilized-in-log form)
        FQ = Ft[:, -1, :]  # total log forget of the chunk
        wq_ = FQ[:, None, :] - Ft + lit  # (B,Q,H) per-j weight to chunk end
        m1 = jnp.maximum(FQ + m0, (wq_).max(axis=1))
        Cnew = jnp.exp(FQ + m0 - m1)[:, :, None, None] * C0 + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", jnp.exp(wq_ - m1[:, None, :]), kt, vt
        )
        nnew = jnp.exp(FQ + m0 - m1)[:, :, None] * n0 + jnp.einsum(
            "bjh,bjhd->bhd", jnp.exp(wq_ - m1[:, None, :]), kt
        )
        return (Cnew, nnew, m1), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), 0.0, jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(
        step,
        (C0, n0, m0),
        (
            jnp.moveaxis(qc, 1, 0),
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.moveaxis(F, 1, 0),
            jnp.moveaxis(Wlog, 1, 0),
            jnp.moveaxis(lic, 1, 0),
        ),
    )
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L, H, dh)
    return h, (Cf, nf, mf)


def mlstm_apply(params, x, cfg):
    d_in, H, dh = _mdims(cfg)
    B, L, _ = x.shape
    q, k, v, li, lf, z, xb = _mlstm_qkvg(params, x, cfg)
    h, state = _mlstm_chunk_scan(q, k, v, li, lf, cfg.chunk_size)
    h = h.reshape(B, L, d_in).astype(x.dtype)
    h = rmsnorm(h, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bln,nd->bld", h, params["w_down"])
    conv_tail = jnp.pad(
        xb[:, -(cfg.conv_width - 1) :, :],
        ((0, 0), (max(0, cfg.conv_width - 1 - L), 0), (0, 0)),
    )
    return out, {"C": state[0], "n": state[1], "m": state[2], "conv": conv_tail}


def init_mlstm_state(cfg, batch, dtype):
    d_in, H, dh = _mdims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in), dtype),
    }


def mlstm_decode(params, x, cfg, state):
    """Recurrent mLSTM step: x (B, 1, d)."""
    d_in, H, dh = _mdims(cfg)
    B = x.shape[0]
    up = jnp.einsum("bld,dn->bln", x, params["w_up"])
    xb, z = up[..., :d_in], up[..., d_in:]
    hist = jnp.concatenate([state["conv"], xb], axis=1)  # (B, W, d_in)
    conv = jnp.einsum("bwc,wc->bc", hist, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(conv)
    q = (xc @ params["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = (xc @ params["wk"]).reshape(B, H, dh).astype(jnp.float32)
    v = (xb[:, 0] @ params["wv"]).reshape(B, H, dh).astype(jnp.float32)
    gates = (xc @ params["w_gates"]).astype(jnp.float32) + params["b_gates"]
    li, lf = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    m = jnp.maximum(lf + state["m"], li)
    fp = jnp.exp(lf + state["m"] - m)
    ip = jnp.exp(li - m)
    C = fp[..., None, None] * state["C"] + ip[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v
    )
    n = fp[..., None] * state["n"] + ip[..., None] * k
    qs = q * scale
    num = jnp.einsum("bhde,bhd->bhe", C, qs)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qs)), jnp.exp(-m))
    h = (num / den[..., None]).reshape(B, 1, d_in).astype(x.dtype)
    h = rmsnorm(h, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bln,nd->bld", h, params["w_down"])
    return out, {"C": C, "n": n, "m": m, "conv": hist[:, 1:, :]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dtype),  # z, i, f, o
        "r": dense_init(ks[1], (4, H, dh, dh), dtype, scale=0.3),
        "b": jnp.zeros((4, d), jnp.float32),
        "norm": jnp.ones((d,), dtype),
        "w_out": dense_init(ks[2], (d, d), dtype),
    }


def _slstm_cell(params, wx_t, carry, cfg):
    """One sLSTM step. wx_t: (B, 4, H, dh) precomputed input projections."""
    H = cfg.n_heads
    d = cfg.d_model
    dh = d // H
    c, n, h, m = carry  # (B,H,dh) x3, m (B,H,dh)
    rh = jnp.einsum("ghde,bhe->bghd", params["r"].astype(jnp.float32), h)
    pre = wx_t.astype(jnp.float32) + rh + params["b"].reshape(4, H, dh)
    zt = jnp.tanh(pre[:, 0])
    li = pre[:, 1]
    lf = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(li - m_new)
    c = fp * c + ip * zt
    n = fp * n + ip
    h = o * c / jnp.maximum(jnp.abs(n), 1e-6)
    return (c, n, h, m_new)


def slstm_apply(params, x, cfg):
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    wx = jnp.einsum("bld,dn->bln", x, params["w_in"]).reshape(B, L, 4, H, dh)
    carry0 = init_slstm_state(cfg, B, x.dtype)

    def step(carry, wx_t):
        new = _slstm_cell(params, wx_t, carry, cfg)
        return new, new[2]

    carry, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L, d).astype(x.dtype)
    h = rmsnorm(h, params["norm"], cfg.norm_eps)
    return jnp.einsum("bld,dn->bln", h, params["w_out"]), carry


def init_slstm_state(cfg, batch, dtype):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, z, z)


def slstm_decode(params, x, cfg, carry):
    B = x.shape[0]
    H = cfg.n_heads
    d = cfg.d_model
    dh = d // H
    wx = (x[:, 0] @ params["w_in"]).reshape(B, 4, H, dh)
    carry = _slstm_cell(params, wx, carry, cfg)
    h = carry[2].reshape(B, 1, d).astype(x.dtype)
    h = rmsnorm(h, params["norm"], cfg.norm_eps)
    return jnp.einsum("bld,dn->bln", h, params["w_out"]), carry
