"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE splits the head-dim half-pairs into (t, h, w) sections; each
section's rotation angle uses the corresponding positional coordinate
from a (3, B, S) position tensor.  With identical coordinates in all
three sections (text-only input) M-RoPE reduces exactly to RoPE — that
reduction is asserted in tests.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_frequencies", "apply_rope", "apply_mrope"]


def rope_frequencies(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    return 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    ).astype(dtype)


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int."""
    freqs = rope_frequencies(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)  # (B, S, 1, half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    return _rotate(x, cos, sin)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """x: (B, S, H, hd); positions3: (3, B, S); sections sum to hd // 2."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)  # (half,)
    # Pick the section's positional coordinate per frequency slot.
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )
    pos = positions3[sec_id]  # (half, B, S) -- gathered per slot
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    return _rotate(x, cos, sin)
