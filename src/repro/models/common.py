"""Shared building blocks: norms, MLPs, embeddings, init helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "rmsnorm",
    "mlp_init",
    "mlp_apply",
    "sinusoidal_positions",
]


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun-like)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    """RMSNorm in f32 accumulation, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, dtype):
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    if mlp_type == "gelu":
        return {
            "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
        }
    raise ValueError(mlp_type)


def mlp_apply(params, x, mlp_type: str):
    if mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_up"]))
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def sinusoidal_positions(positions, d_model: int, dtype):
    """Classic transformer sinusoidal embeddings; positions (..., S)."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return emb.astype(dtype)
