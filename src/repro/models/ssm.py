"""Mamba2 (SSD) mixer: chunked block-diagonal + low-rank scan form for
training/prefill, O(1)-state recurrent form for decode.

The chunked SSD algorithm is the same "replace the dense quadratic
object by its factored action" move as the paper's sum factorization:
the (L x L) attention-like operator of the state-space dual form is
never materialized — within-chunk (Q x Q) blocks plus a low-rank
inter-chunk state recurrence reproduce its action exactly.

Shapes: d_inner = expand * d_model, H = d_inner / head_dim heads,
N = ssm_state, single B/C group (G = 1, all heads share B and C).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm

__all__ = [
    "mamba2_init",
    "mamba2_apply",
    "mamba2_decode",
    "init_mamba2_state",
    "chunk_len",
]


def chunk_len(L: int, chunk: int) -> int:
    """Largest divisor of L that is <= chunk.  Chunked SSD/mLSTM scans are
    exact for any chunk length, so an awkward L (odd prompt lengths) just
    gets a smaller chunk rather than padding."""
    q = min(chunk, L)
    while L % q:
        q -= 1
    return q


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    d_in, H, N = _dims(cfg)
    conv_ch = d_in + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),  # A = -exp(a_log) = -1
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[3], (d_in, d), dtype),
    }


def _split_proj(params, x, cfg):
    d_in, H, N = _dims(cfg)
    zxbcdt = jnp.einsum("bld,dn->bln", x, params["in_proj"])
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * N]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * N :]
    return z, xbc, dt_raw


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along L. xbc (B, L, C); w (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b)


def _ssd_chunked(xh, dt, a_log, bmat, cmat, chunk):
    """Chunked SSD scan.

    xh:   (B, L, H, P)   per-head inputs
    dt:   (B, L, H)      softplus'd step sizes
    bmat: (B, L, N), cmat: (B, L, N)  shared across heads (G = 1)
    Returns y (B, L, H, P) and the final state (B, H, P, N).
    """
    B, L, H, P = xh.shape
    N = bmat.shape[-1]
    Q = chunk_len(L, chunk)
    nc = L // Q

    A = -jnp.exp(a_log)  # (H,)
    a = dt * A  # (B, L, H) log-decay increments
    xdt = xh * dt[..., None]

    ac = a.reshape(B, nc, Q, H)
    cs = jnp.cumsum(ac, axis=2)  # inclusive cumsum within chunk
    xc = xdt.reshape(B, nc, Q, H, P)
    bc = bmat.reshape(B, nc, Q, N)
    cc = cmat.reshape(B, nc, Q, N)

    # --- within-chunk (block-diagonal) term
    # Ltri[i, j] = exp(cs_i - cs_j) for i >= j
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    ltri = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, ltri, xc)

    # --- per-chunk outgoing state: sum_j exp(cs_last - cs_j) B_j (x)dt_j
    decay_out = jnp.exp(cs[:, :, -1:, :] - cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, decay_out, xc)

    # --- inter-chunk recurrence (low-rank carry)
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (B,nc,H)

    def step(s, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        s_new = s * dec[:, :, None, None] + st
        return s_new, s  # emit the state *entering* this chunk

    s0 = jnp.zeros((B, H, N, P), xh.dtype)
    s_final, s_in = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)  # (B,nc,H,N,P) state entering chunk

    # --- inter-chunk contribution: C_i . S_in decayed to position i
    y_off = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", cc, jnp.exp(cs), s_in
    )
    y = (y_diag + y_off).reshape(B, L, H, P)
    return y, s_final


def mamba2_apply(params, x, cfg):
    """Full-sequence Mamba2 mixer. x (B, L, d_model) -> (y, final_state)."""
    d_in, H, N = _dims(cfg)
    P = cfg.ssm_head_dim
    B, L, _ = x.shape
    z, xbc_raw, dt_raw = _split_proj(params, x, cfg)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_in].reshape(B, L, H, P)
    bmat = xbc[..., d_in : d_in + N]
    cmat = xbc[..., d_in + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    y, state = _ssd_chunked(
        xs.astype(jnp.float32),
        dt,
        params["a_log"],
        bmat.astype(jnp.float32),
        cmat.astype(jnp.float32),
        cfg.chunk_size,
    )
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, L, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bln,nd->bld", y, params["out_proj"])
    # conv tail for a subsequent decode phase (last W-1 pre-conv inputs)
    tail = xbc_raw[:, -(cfg.conv_width - 1) :, :]
    conv_state = jnp.pad(
        tail, ((0, 0), (max(0, (cfg.conv_width - 1) - L), 0), (0, 0))
    )
    return out, {"ssm": state, "conv": conv_state}


def init_mamba2_state(cfg, batch: int, dtype):
    d_in, H, N = _dims(cfg)
    P = cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * N), dtype),
    }


def mamba2_decode(params, x, cfg, state):
    """One-token recurrent step. x (B, 1, d) -> (y (B, 1, d), new state)."""
    d_in, H, N = _dims(cfg)
    P = cfg.ssm_head_dim
    B = x.shape[0]
    z, xbc_new, dt_raw = _split_proj(params, x, cfg)

    # causal conv over [conv_state, xbc_new]
    hist = jnp.concatenate([state["conv"], xbc_new], axis=1)  # (B, W, C)
    w = params["conv_w"]
    conv = jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"]
    xbc = jax.nn.silu(conv)[:, None, :]
    new_conv = hist[:, 1:, :]

    xs = xbc[..., :d_in].reshape(B, H, P).astype(jnp.float32)
    bmat = xbc[:, 0, d_in : d_in + N].astype(jnp.float32)
    cmat = xbc[:, 0, d_in + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    decay = jnp.exp(dt * -jnp.exp(params["a_log"]))  # (B, H)

    s = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", bmat, dt, xs
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat, s)
    y = y + params["d_skip"][None, :, None] * xs
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bln,nd->bld", y, params["out_proj"])
    return out, {"ssm": s, "conv": new_conv}
