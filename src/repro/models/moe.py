"""Mixture-of-Experts FFN with grouped, capacity-bounded scatter dispatch.

Top-k routing (softmax gate, renormalized over the chosen k); experts are
stacked SwiGLU FFNs sharded over the ``model`` axis (expert parallelism).

Dispatch is *grouped per batch row* so every step is local to the data
shard: within a row, each (token, choice) computes its slot inside the
chosen expert's capacity buffer via an exclusive cumsum over the one-hot
assignment matrix, and is scattered into a (E, C, d) buffer
(C = S * top_k * capacity_factor / E; tokens beyond capacity are dropped
— GShard semantics).  The expert FFN then runs as dense einsums over the
(B, E, C, d) buffer with E sharded; no global cumsum, no (N, E, C)
one-hot dispatch tensor, no ragged shapes.

Combine exploits that assignments are token-major ordered: the gathered
outputs reshape to (B, S, k, d) and sum over k — no segment-sum.

Returns the Switch-style auxiliary load-balance loss for the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), dtype),
        "w_gate": dense_init(ks[1], (E, d, f), dtype),
        "w_up": dense_init(ks[2], (E, d, f), dtype),
        "w_down": dense_init(ks[3], (E, f, d), dtype),
    }


def moe_apply(params, x, cfg, act_spec=None):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar).

    act_spec: optional NamedSharding of the residual stream (B, S, d);
    when given, the (B, E, C, d) dispatch buffer and the (B, E, C, f)
    expert intermediate are constrained to batch-over-dp / f-over-model —
    without this GSPMD tends to replicate the batch axis of the scatter-
    built buffer, which at capacity C = 1.25*S*k/E is the largest
    activation in an MoE train step.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * S * k / E), 1)

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    gates = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    w, idx = jax.lax.top_k(gates, k)  # (B, S, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)

    # --- aux load-balancing loss (Switch-style), global over the batch.
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2), axis=(0, 1)
    )
    aux = E * jnp.sum(me * ce)

    # --- grouped dispatch (everything below is per-row, batch-local).
    fid = idx.reshape(B, S * k)  # expert id per assignment (token-major)
    fw = w.reshape(B, S * k).astype(x.dtype)
    onehot = jax.nn.one_hot(fid, E, dtype=jnp.int32)  # (B, S*k, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1) - onehot, fid[..., None], axis=-1
    )[..., 0]  # exclusive position within the chosen expert
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    src = jnp.repeat(jnp.arange(S), k)  # token index per assignment
    xa = jnp.take(x, src, axis=1)  # (B, S*k, d)
    contrib = jnp.where(keep[..., None], xa, 0)

    def scatter_row(f, p, c):
        return jnp.zeros((E, cap, d), x.dtype).at[f, p].add(c)

    buf = jax.vmap(scatter_row)(fid, pos_c, contrib)  # (B, E, C, d)

    constrain_buf = constrain_h = constrain_y = lambda t: t
    if act_spec is not None and hasattr(act_spec, "mesh"):
        from jax.sharding import NamedSharding, PartitionSpec as P

        dp = act_spec.spec[0]
        model_size = max(act_spec.mesh.shape.get("model", 1), 1)
        tp = "model" if "model" in act_spec.mesh.axis_names else None
        # Preferred: true EP — shard the expert axis (every expert einsum
        # local, no partial sums in fwd OR bwd; GSPMD turns dispatch/
        # combine into all-to-alls).  Fallback: shard the capacity axis,
        # which is also a pure batch dim of the expert einsums (the d_ff-
        # sharding alternative all-reduces a (B, E, C, d) f32 cotangent
        # per layer — measured 5 GiB per occurrence at olmoe scale).
        if E % model_size == 0:
            e_tp, cap_tp = tp, None
        else:
            e_tp = None
            cap_tp = tp if cap % model_size == 0 else None
        constrain_buf = lambda t: jax.lax.with_sharding_constraint(
            t, NamedSharding(act_spec.mesh, P(dp, e_tp, cap_tp, None)))
        constrain_h = lambda t: jax.lax.with_sharding_constraint(
            t, NamedSharding(act_spec.mesh, P(dp, e_tp, cap_tp, None)))
        constrain_y = lambda t: jax.lax.with_sharding_constraint(
            t, NamedSharding(act_spec.mesh, P(dp, None, None)))
    buf = constrain_buf(buf)

    # --- expert FFN (f sharded over 'model', batch over dp).  NOTE: the
    # down-projection's f-contraction leaves out_buf as model-axis partial
    # sums; the psum is deferred past the combine below, so the all-reduce
    # runs on the (B, S, d) token tensor, not the (B, E, C, d) capacity
    # buffer (C = 1.25*S*k/E slots: 2.5x more rows than tokens at top-8).
    g = constrain_h(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
    u = constrain_h(jnp.einsum("becd,edf->becf", buf, params["w_up"]))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])

    # --- combine: gather back, weight, drop, sum the k choices per token.
    def gather_row(ob, f, p):
        return ob[f, p]

    ya = jax.vmap(gather_row)(out_buf, fid, pos_c)  # (B, S*k, d)
    ya = ya * (fw * keep.astype(x.dtype))[..., None]
    y = constrain_y(ya.reshape(B, S, k, d).sum(axis=2))
    return y.astype(x.dtype), aux
