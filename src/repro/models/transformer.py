"""Model assembly: every assigned architecture as one decoder stack.

A single ``init_params`` / ``forward`` / ``prefill`` / ``decode_step``
interface covers the six families:

* dense / vlm / audio / moe — attention backbone; per-layer params are
  *stacked* along a leading layer axis and the forward pass is a
  ``lax.scan`` over layers (HLO size O(1) in depth — required for the
  64-layer dry-run configs) with per-layer ``jax.checkpoint`` (remat).
* zamba2 hybrid — Mamba2 backbone scanned in groups of
  ``shared_attn_every``; one weight-shared attention+MLP block applied
  after each group (the Zamba trick: 9 applications of a single set of
  attention weights at 54 layers).
* xlstm — heterogeneous mLSTM/sLSTM blocks (``slstm_indices``); a plain
  python loop (12 layers at full scale, HLO stays small).

Inputs are dicts from :func:`repro.data.pipeline.batch_spec`:
``tokens (B, S)`` int32 (musicgen: ``(B, S, n_codebooks)``), optional
``vision_embeds (B, n_vision_tokens, d_model)`` for the VLM stub, and
``labels`` shaped like tokens with ``-1`` marking masked-out positions.

The LM head is evaluated through :func:`chunked_ce_loss`, which scans
over sequence chunks so the (B, S, vocab) float32 logits tensor is never
materialized in HBM — the same round-trip-avoidance insight as the
paper's macro-kernel fusion, applied to the loss layer.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm as _ssm
from repro.models import xlstm as _xl
from repro.models.attention import (
    attention,
    attn_init,
    decode_attention,
    init_kv_cache,
)
from repro.models.common import (
    dense_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    sinusoidal_positions,
)
from repro.models.moe import moe_apply, moe_init

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_decode_state",
    "chunked_ce_loss",
    "param_count",
]

AUX_LOSS_COEF = 0.01
LOSS_CHUNK = 2048  # sequence chunk for the fused LM-head/CE scan


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Per-family block init / apply
# ---------------------------------------------------------------------------
def _attn_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(k1, cfg, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def _attn_block_apply(p, x, cfg, positions, impl="auto", act_spec=None):
    """Pre-norm attention block. Returns (x, aux, kv)."""
    h, kv = attention(
        p["attn"], rmsnorm(x, p["attn_norm"], cfg.norm_eps), cfg, positions, impl
    )
    x = x + h
    hn = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        m, aux = moe_apply(p["moe"], hn, cfg, act_spec=act_spec)
    else:
        m, aux = mlp_apply(p["mlp"], hn, cfg.mlp_type), 0.0
    return x + m, aux, kv


def _attn_block_decode(p, x, cfg, cache, pos):
    rope_pos = pos
    if cfg.pos_embed == "mrope":
        # text tokens past the vision prefix: t = h = w = pos - nv + g
        g = max(int(math.isqrt(max(cfg.n_vision_tokens, 1))), 1)
        rope_pos = pos - cfg.n_vision_tokens + g
    h, cache = decode_attention(
        p["attn"], rmsnorm(x, p["attn_norm"], cfg.norm_eps), cfg, cache, pos,
        rope_pos=rope_pos,
    )
    x = x + h
    hn = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        m, _ = moe_apply(p["moe"], hn, cfg)
    else:
        m = mlp_apply(p["mlp"], hn, cfg.mlp_type)
    return x + m, cache


def _mamba_block_init(key, cfg, dtype):
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "mixer": _ssm.mamba2_init(key, cfg, dtype),
    }


def _mamba_block_apply(p, x, cfg):
    h, state = _ssm.mamba2_apply(p["mixer"], rmsnorm(x, p["norm"], cfg.norm_eps), cfg)
    return x + h, state


def _mamba_block_decode(p, x, cfg, state):
    h, state = _ssm.mamba2_decode(p["mixer"], rmsnorm(x, p["norm"], cfg.norm_eps), cfg, state)
    return x + h, state


def _stacked(init_one, key, n, *args):
    """Stack n independent inits along a leading layer axis."""
    keys = jax.random.split(key, n)
    inits = [init_one(k, *args) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *inits)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key, cfg) -> dict:
    dtype = _dtype(cfg)
    k_emb, k_blocks, k_shared, k_head = jax.random.split(key, 4)

    params: dict[str, Any] = {}
    if cfg.n_codebooks:
        params["embed"] = dense_init(
            k_emb, (cfg.n_codebooks, cfg.vocab, cfg.d_model), dtype
        )
    else:
        params["embed"] = dense_init(k_emb, (cfg.vocab, cfg.d_model), dtype)

    bp = cfg.block_pattern
    if bp == "attn":
        params["blocks"] = _stacked(_attn_block_init, k_blocks, cfg.n_layers, cfg, dtype)
    elif bp == "zamba2":
        if cfg.n_layers % cfg.shared_attn_every:
            raise ValueError("zamba2 requires n_layers % shared_attn_every == 0")
        params["blocks"] = _stacked(_mamba_block_init, k_blocks, cfg.n_layers, cfg, dtype)
        params["shared"] = _attn_block_init(k_shared, cfg, dtype)
    elif bp == "mamba2":
        params["blocks"] = _stacked(_mamba_block_init, k_blocks, cfg.n_layers, cfg, dtype)
    elif bp == "xlstm":
        keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = [
            _xl.slstm_init(keys[i], cfg, dtype)
            if i in cfg.slstm_indices
            else {
                "norm": jnp.ones((cfg.d_model,), dtype),
                "mixer": _xl.mlstm_init(keys[i], cfg, dtype),
            }
            for i in range(cfg.n_layers)
        ]
    else:
        raise ValueError(bp)

    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.n_codebooks:
        params["lm_head"] = dense_init(
            k_head, (cfg.n_codebooks, cfg.d_model, cfg.vocab), dtype
        )
    elif not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab), dtype)
    return params


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# embedding / positions
# ---------------------------------------------------------------------------
def _embed(params, batch, cfg):
    tokens = batch["tokens"]
    if cfg.n_codebooks:
        # (B, S, n_cb) -> sum of per-codebook embeddings.
        x = jnp.take(params["embed"][0], tokens[..., 0], axis=0)
        for c in range(1, cfg.n_codebooks):
            x = x + jnp.take(params["embed"][c], tokens[..., c], axis=0)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.n_vision_tokens and "vision_embeds" in batch:
        nv = cfg.n_vision_tokens
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(x.dtype), x[:, nv:]], axis=1
        )
    B, S = tokens.shape[:2]
    if cfg.pos_embed == "sinusoidal":
        pos = jnp.arange(S)[None, :]
        x = x + sinusoidal_positions(pos, cfg.d_model, x.dtype)
    return x


def _positions(batch, cfg):
    """Position ids: (B, S) for RoPE, (3, B, S) t/h/w for M-RoPE."""
    B, S = batch["tokens"].shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.pos_embed != "mrope":
        return pos
    # VLM stub M-RoPE: the first n_vision_tokens form a sqrt(n) x sqrt(n)
    # patch grid at t=0; text tokens advance all three components together
    # starting from the grid extent (Qwen2-VL convention).
    nv = cfg.n_vision_tokens
    g = max(int(math.isqrt(max(nv, 1))), 1)
    i = jnp.arange(S, dtype=jnp.int32)
    is_vis = i < nv
    t = jnp.where(is_vis, 0, i - nv + g)
    h = jnp.where(is_vis, i // g, i - nv + g)
    w = jnp.where(is_vis, i % g, i - nv + g)
    return jnp.broadcast_to(jnp.stack([t, h, w])[:, None, :], (3, B, S))


# ---------------------------------------------------------------------------
# forward (training path): scan over layers, remat per block
# ---------------------------------------------------------------------------
def forward(params, batch, cfg, *, remat: bool = True, attn_impl: str = "auto",
            act_spec=None):
    """Run the stack; returns (hidden (B, S, d), aux_loss scalar).

    act_spec: optional PartitionSpec applied to the residual stream
    between blocks (sequence parallelism — bounds the per-layer remat
    save under scan; see repro.distributed.sharding.act_pspec).
    """
    constrain = (
        (lambda t: jax.lax.with_sharding_constraint(t, act_spec))
        if act_spec is not None
        else (lambda t: t)
    )
    x = constrain(_embed(params, batch, cfg))
    positions = _positions(batch, cfg)
    bp = cfg.block_pattern

    if bp == "attn":
        def body(carry, layer_p):
            x, aux = carry
            x, a, _ = _attn_block_apply(
                layer_p, x, cfg, positions, attn_impl, act_spec=act_spec
            )
            return (constrain(x), aux + a), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
        )

    elif bp in ("zamba2", "mamba2"):
        def mbody(carry, layer_p):
            x = carry
            x, _ = _mamba_block_apply(layer_p, x, cfg)
            return constrain(x), None

        if remat:
            mbody = jax.checkpoint(mbody, prevent_cse=False)
        if bp == "mamba2":
            x, _ = jax.lax.scan(mbody, x, params["blocks"])
            aux = 0.0
        else:
            every = cfg.shared_attn_every
            ng = cfg.n_layers // every
            grouped = jax.tree.map(
                lambda a: a.reshape((ng, every) + a.shape[1:]), params["blocks"]
            )
            shared = params["shared"]

            def gbody(carry, group_p):
                x = carry
                x, _ = jax.lax.scan(mbody, x, group_p)
                x, _, _ = _attn_block_apply(shared, x, cfg, positions, attn_impl)
                return x, None

            if remat:
                gbody = jax.checkpoint(gbody, prevent_cse=False)
            x, _ = jax.lax.scan(gbody, x, grouped)
            aux = 0.0

    elif bp == "xlstm":
        aux = 0.0
        for i, bpar in enumerate(params["blocks"]):
            if i in cfg.slstm_indices:
                h, _ = _xl.slstm_apply(bpar, x, cfg)  # post-norm residual inside
                x = x + h
            else:
                h, _ = _mamba_like_mlstm(bpar, x, cfg)
                x = x + h
    else:
        raise ValueError(bp)

    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def _mamba_like_mlstm(bpar, x, cfg):
    return _xl.mlstm_apply(bpar["mixer"], rmsnorm(x, bpar["norm"], cfg.norm_eps), cfg)


# ---------------------------------------------------------------------------
# fused LM head + cross-entropy (never materializes (B, S, V) in f32)
# ---------------------------------------------------------------------------
def chunked_ce_loss(hidden, head_w, labels, chunk: int = LOSS_CHUNK,
                    logits_spec=None):
    """Mean next-token CE over valid (label >= 0) positions.

    hidden (B, S, d); head_w (d, V); labels (B, S) already shifted by the
    data pipeline (-1 = ignore).  Scans over S-chunks with a rematted
    body, so the (B, c, V) float32 logits exist only transiently in both
    the forward AND the backward pass (without remat, scan AD would save
    every chunk's logits — the full (B, S, V) f32 tensor this function
    exists to avoid).  ``logits_spec`` shards the transient chunk over
    the model axis (vocab-parallel logits).
    """
    B, S, d = hidden.shape
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    h = jnp.moveaxis(hidden.reshape(B, S // c, c, d), 1, 0)
    l = jnp.moveaxis(labels.reshape(B, S // c, c), 1, 0)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        tot, cnt = carry
        hc, lc = inp
        logits = (hc @ head_w).astype(jnp.float32)
        if logits_spec is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_spec)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        valid = lc >= 0
        tot = tot + jnp.sum(jnp.where(valid, lse - ll, 0.0))
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0), (h, l))
    return tot / jnp.maximum(cnt, 1)


def _head_weight(params, cfg):
    if cfg.n_codebooks:
        return params["lm_head"]  # (n_cb, d, V)
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def loss_fn(params, batch, cfg, *, remat: bool = True, attn_impl: str = "auto",
            act_spec=None, logits_spec=None):
    """Scalar training loss (CE + MoE aux)."""
    hidden, aux = forward(
        params, batch, cfg, remat=remat, attn_impl=attn_impl, act_spec=act_spec
    )
    w = _head_weight(params, cfg)
    if cfg.n_codebooks:
        ce = 0.0
        for cb in range(cfg.n_codebooks):
            ce = ce + chunked_ce_loss(
                hidden, w[cb], batch["labels"][..., cb], logits_spec=logits_spec
            )
        ce = ce / cfg.n_codebooks
    else:
        ce = chunked_ce_loss(hidden, w, batch["labels"], logits_spec=logits_spec)
    return ce + AUX_LOSS_COEF * aux


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with explicit state
# ---------------------------------------------------------------------------
def init_decode_state(cfg, batch: int, max_len: int):
    """Per-layer decode state, stacked on a leading layer axis."""
    dtype = _dtype(cfg)
    bp = cfg.block_pattern
    if bp == "attn":
        one = init_kv_cache(cfg, batch, max_len, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
        )
    if bp in ("mamba2", "zamba2"):
        one = _ssm.init_mamba2_state(cfg, batch, dtype)
        st = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
        )
        if bp == "zamba2":
            ng = cfg.n_layers // cfg.shared_attn_every
            kv = init_kv_cache(cfg, batch, max_len, dtype)
            st = {
                "mamba": st,
                "shared_kv": jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (ng,) + a.shape), kv
                ),
            }
        return st
    if bp == "xlstm":
        return [
            _xl.init_slstm_state(cfg, batch, dtype)
            if i in cfg.slstm_indices
            else _xl.init_mlstm_state(cfg, batch, dtype)
            for i in range(cfg.n_layers)
        ]
    raise ValueError(bp)


def decode_step(params, token, state, pos, cfg):
    """One decode step.

    token: (B, 1) int32 (musicgen (B, 1, n_cb)); pos: scalar int32 —
    number of tokens already in the state.  Returns (logits, new state);
    logits (B, V) (musicgen (B, n_cb, V)).
    """
    batch = {"tokens": token}
    x = _embed(params, batch, cfg)
    if cfg.pos_embed == "sinusoidal":
        # _embed added position 0; re-add the correct one.
        x = x - sinusoidal_positions(
            jnp.zeros((1, 1), jnp.int32), cfg.d_model, x.dtype
        )
        x = x + sinusoidal_positions(
            jnp.full((1, 1), pos, jnp.int32), cfg.d_model, x.dtype
        )
    bp = cfg.block_pattern

    if bp == "attn":
        def body(x, inp):
            layer_p, cache = inp
            x, cache = _attn_block_decode(layer_p, x, cfg, cache, pos)
            return x, cache

        x, state = jax.lax.scan(body, x, (params["blocks"], state))

    elif bp in ("mamba2", "zamba2"):
        mamba_state = state["mamba"] if bp == "zamba2" else state

        def mbody(x, inp):
            layer_p, st = inp
            x, st = _mamba_block_decode(layer_p, x, cfg, st)
            return x, st

        if bp == "mamba2":
            x, state = jax.lax.scan(mbody, x, (params["blocks"], mamba_state))
        else:
            every = cfg.shared_attn_every
            ng = cfg.n_layers // every
            grouped_p = jax.tree.map(
                lambda a: a.reshape((ng, every) + a.shape[1:]), params["blocks"]
            )
            grouped_s = jax.tree.map(
                lambda a: a.reshape((ng, every) + a.shape[1:]), mamba_state
            )
            shared = params["shared"]

            def gbody(x, inp):
                gp, gs, kv = inp
                x, gs = jax.lax.scan(mbody, x, (gp, gs))
                x, kv = _attn_block_decode(shared, x, cfg, kv, pos)
                return x, (gs, kv)

            x, (gs, kvs) = jax.lax.scan(
                gbody, x, (grouped_p, grouped_s, state["shared_kv"])
            )
            state = {
                "mamba": jax.tree.map(
                    lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), gs
                ),
                "shared_kv": kvs,
            }

    elif bp == "xlstm":
        new_states = []
        for i, bpar in enumerate(params["blocks"]):
            if i in cfg.slstm_indices:
                h, st = _xl.slstm_decode(bpar, x, cfg, state[i])
            else:
                h, st = _xl.mlstm_decode(
                    bpar["mixer"], rmsnorm(x, bpar["norm"], cfg.norm_eps), cfg, state[i]
                )
            x = x + h
            new_states.append(st)
        state = new_states
    else:
        raise ValueError(bp)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = _head_weight(params, cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bld,cdv->bclv", x, w)[:, :, 0]
    else:
        logits = (x @ w)[:, 0]
    return logits, state


def prefill(params, batch, cfg, max_len: int | None = None, attn_impl: str = "auto",
            act_spec=None):
    """Process a full prompt; returns (last-position logits, decode state).

    Implemented for the attention family (KV states collected from the
    forward pass); recurrent families prefill by running forward and
    re-deriving state from their scan carries.
    """
    cfg_dtype = _dtype(cfg)
    B, S = batch["tokens"].shape[:2]
    max_len = max_len or S
    x = _embed(params, batch, cfg)
    positions = _positions(batch, cfg)
    bp = cfg.block_pattern

    if bp == "attn":
        caches = init_decode_state(cfg, B, max_len)

        def body(carry, inp):
            x = carry
            layer_p, _ = inp
            x, _, (k, v) = _attn_block_apply(
                layer_p, x, cfg, positions, attn_impl, act_spec=act_spec
            )
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], jnp.arange(cfg.n_layers)))
        size = caches["k"].shape[2]
        if cfg.sliding_window and S > size:
            # rolling window layout: slot = pos % size
            idx = (jnp.arange(S - size, S)) % size
            ks = ks[:, :, -size:][:, :, jnp.argsort(idx)]
            vs = vs[:, :, -size:][:, :, jnp.argsort(idx)]
            caches = {"k": ks.astype(cfg_dtype), "v": vs.astype(cfg_dtype)}
        else:
            caches = {
                "k": caches["k"].at[:, :, :S].set(ks.astype(cfg_dtype)),
                "v": caches["v"].at[:, :, :S].set(vs.astype(cfg_dtype)),
            }
        state = caches
    else:
        # Recurrent families: one scan pass collects hidden AND states.
        x, state = _recurrent_prefill(params, x, cfg, positions, max_len)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = _head_weight(params, cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bd,cdv->bcv", x[:, -1], w)
    else:
        logits = x[:, -1] @ w
    return logits, state


def _recurrent_prefill(params, x, cfg, positions, max_len):
    """One pass over the stack, returning (hidden, decode-ready states).

    Uniform recurrent families scan over the stacked layer params
    (states come out stacked (L, ...) — the init_decode_state layout);
    xlstm keeps a python loop (12 heterogeneous layers at full scale).
    """
    bp = cfg.block_pattern
    B = x.shape[0]
    dtype = _dtype(cfg)
    if bp == "xlstm":
        states = []
        for i, bpar in enumerate(params["blocks"]):
            if i in cfg.slstm_indices:
                h, st = _xl.slstm_apply(bpar, x, cfg)
            else:
                h, st = _mamba_like_mlstm(bpar, x, cfg)
            states.append(st)
            x = x + h
        return x, states

    def mbody(x, layer_p):
        x, st = _mamba_block_apply(layer_p, x, cfg)
        return x, st

    if bp == "mamba2":
        x, states = jax.lax.scan(mbody, x, params["blocks"])
        return x, states

    # zamba2: groups of `every` mamba layers + the weight-shared attn block
    every = cfg.shared_attn_every
    ng = cfg.n_layers // every
    grouped = jax.tree.map(
        lambda a: a.reshape((ng, every) + a.shape[1:]), params["blocks"]
    )
    shared = params["shared"]
    S = x.shape[1]

    def gbody(x, group_p):
        x, sts = jax.lax.scan(mbody, x, group_p)
        xn = rmsnorm(x, shared["attn_norm"], cfg.norm_eps)
        h2, (k, v) = attention(shared["attn"], xn, cfg, positions)
        x = x + h2
        hn = rmsnorm(x, shared["mlp_norm"], cfg.norm_eps)
        x = x + mlp_apply(shared["mlp"], hn, cfg.mlp_type)
        kv = init_kv_cache(cfg, B, max_len, dtype)
        kv = {
            "k": kv["k"].at[:, :S].set(k.astype(dtype)),
            "v": kv["v"].at[:, :S].set(v.astype(dtype)),
        }
        return x, (sts, kv)

    x, (gs, kvs) = jax.lax.scan(gbody, x, grouped)
    mamba = jax.tree.map(lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), gs)
    return x, {"mamba": mamba, "shared_kv": kvs}
