"""Inter-grid transfer operators for the GMG hierarchy (paper Sec. 3).

On the structured tensor-product grid both transfer kinds are separable
into per-axis 1D operators applied to the global node grid — the same
Kronecker-structure observation that powers sum factorization, reused at
the solver level:

* h-transfer (uniform refinement at fixed p): evaluate the coarse
  element basis at the fine nodes of its two children per axis.
* p-transfer (degree embedding on the same mesh): evaluate the degree-p_c
  basis at the degree-p_f GLL nodes per axis.

Prolongation is ``U_f = (Pz x Py x Px) U_c`` applied as three 1D
contractions; restriction is its exact transpose (the residual adjoint).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.basis import gll_nodes, lagrange_tables
from repro.distributed.sharding import pin_scenario
from repro.fem.space import H1Space

__all__ = ["Transfer", "h_transfer_1d", "p_transfer_1d", "make_transfer"]


def p_transfer_1d(n_el: int, p_coarse: int, p_fine: int) -> np.ndarray:
    """Global 1D prolongation (n_el*p_fine+1, n_el*p_coarse+1)."""
    E, _ = lagrange_tables(gll_nodes(p_coarse), gll_nodes(p_fine))
    nf, nc = n_el * p_fine + 1, n_el * p_coarse + 1
    P = np.zeros((nf, nc))
    for e in range(n_el):
        P[e * p_fine : e * p_fine + p_fine + 1, e * p_coarse : e * p_coarse + p_coarse + 1] = E
    return P


def h_transfer_1d(n_el_coarse: int, p: int) -> np.ndarray:
    """Global 1D prolongation from n_el to 2*n_el elements at degree p."""
    nodes = gll_nodes(p)
    El, _ = lagrange_tables(nodes, (nodes - 1.0) / 2.0)
    Er, _ = lagrange_tables(nodes, (nodes + 1.0) / 2.0)
    nf, nc = 2 * n_el_coarse * p + 1, n_el_coarse * p + 1
    P = np.zeros((nf, nc))
    for e in range(n_el_coarse):
        P[(2 * e) * p : (2 * e) * p + p + 1, e * p : e * p + p + 1] = El
        P[(2 * e + 1) * p : (2 * e + 1) * p + p + 1, e * p : e * p + p + 1] = Er
    return P


@dataclasses.dataclass
class Transfer:
    """Separable 3D transfer between two H1 spaces on the same box.

    Both directions accept an optional leading scenario-batch axis:
    (nscalar, 3) or (S, nscalar, 3) — the 1D contractions are written
    with einsum ellipses, so a batched V-cycle threads through unchanged.

    ``shard_mesh`` (a scenario-axis device mesh) pins batched outputs to
    axis-0 sharding: the 1D contractions touch only trailing axes, so
    prolongation/restriction of a sharded batch is purely shard-local
    and the V-cycle never materializes a replicated (S, ...) residual.
    """

    px: Any  # (Nx_f, Nx_c)
    py: Any
    pz: Any
    grid_c: tuple[int, int, int]
    grid_f: tuple[int, int, int]
    shard_mesh: Any = None

    def _pin(self, u):
        if u.ndim < 3:  # unbatched (nscalar, 3): nothing to shard
            return u
        return pin_scenario(u, self.shard_mesh)

    def prolong(self, u_c):
        """(..., nscalar_c, 3) -> (..., nscalar_f, 3)."""
        nxc, nyc, nzc = self.grid_c
        lead = u_c.shape[:-2]
        u = u_c.reshape(lead + (nzc, nyc, nxc, 3))
        u = jnp.einsum("...zyxc,Xx->...zyXc", u, self.px)
        u = jnp.einsum("...zyXc,Yy->...zYXc", u, self.py)
        u = jnp.einsum("...zYXc,Zz->...ZYXc", u, self.pz)
        return self._pin(u.reshape(lead + (-1, 3)))

    def restrict(self, r_f):
        """Transpose: (..., nscalar_f, 3) -> (..., nscalar_c, 3)."""
        nxf, nyf, nzf = self.grid_f
        lead = r_f.shape[:-2]
        r = r_f.reshape(lead + (nzf, nyf, nxf, 3))
        r = jnp.einsum("...ZYXc,Zz->...zYXc", r, self.pz)
        r = jnp.einsum("...zYXc,Yy->...zyXc", r, self.py)
        r = jnp.einsum("...zyXc,Xx->...zyxc", r, self.px)
        return self._pin(r.reshape(lead + (-1, 3)))


def make_transfer(
    coarse: H1Space, fine: H1Space, dtype=jnp.float64, shard_mesh=None
) -> Transfer:
    """Build the transfer between two nested spaces: either an h-refinement
    at equal degree or a p-embedding on the same mesh."""
    mc, mf = coarse.mesh, fine.mesh
    if mc.shape == mf.shape and coarse.p != fine.p:
        mats = [p_transfer_1d(n, coarse.p, fine.p) for n in mc.shape]
    elif (
        tuple(2 * n for n in mc.shape) == mf.shape and coarse.p == fine.p
    ):
        mats = [h_transfer_1d(n, coarse.p) for n in mc.shape]
    else:
        raise ValueError(
            f"spaces not nested: {mc.shape}@p={coarse.p} -> {mf.shape}@p={fine.p}"
        )
    px, py, pz = (jnp.asarray(m, dtype=dtype) for m in mats)
    return Transfer(
        px=px, py=py, pz=pz, grid_c=coarse.node_grid, grid_f=fine.node_grid,
        shard_mesh=shard_mesh,
    )
