from repro.fem.mesh import HexMesh, beam_hex
from repro.fem.space import H1Space

__all__ = ["HexMesh", "beam_hex", "H1Space"]
