"""Essential (Dirichlet) boundary-condition handling.

Mirrors MFEM's ``ConstrainedOperator`` semantics used by all assembly
levels: given the unconstrained operator action A and the set of essential
DoFs E,

    y = A (x with x_E zeroed);   y_E = x_E

which keeps the constrained operator symmetric positive-definite with a
unit diagonal block on E.  RHS elimination for inhomogeneous data is
``b <- b - A x_bc`` followed by ``b_E <- x_bc_E`` (homogeneous in the
paper's benchmark, but implemented generally).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ConstrainedOperator", "eliminate_rhs"]


class ConstrainedOperator:
    """Wraps ``apply(x) -> y`` with MFEM ConstrainedOperator semantics."""

    def __init__(self, apply_fn, ess_mask, diagonal_fn=None):
        self._apply = apply_fn
        # bool (nscalar, vdim); stored as the operator dtype at call time.
        self.ess_mask = jnp.asarray(ess_mask)
        self._diagonal_fn = diagonal_fn

    def __call__(self, x):
        m = self.ess_mask
        xi = jnp.where(m, 0.0, x)
        y = self._apply(xi)
        return jnp.where(m, x, y)

    def diagonal(self):
        """Operator diagonal with ones on constrained DoFs (what MFEM's
        AssembleDiagonal + ConstrainedOperator produce for the smoother)."""
        if self._diagonal_fn is None:
            raise ValueError("no diagonal_fn provided")
        d = self._diagonal_fn()
        return jnp.where(self.ess_mask, jnp.ones_like(d), d)


def eliminate_rhs(apply_fn, ess_mask, b, x_bc=None):
    """Form the reduced RHS for essential BCs (x_bc defaults to zero)."""
    m = jnp.asarray(ess_mask)
    if x_bc is None:
        return jnp.where(m, 0.0, b)
    xb = jnp.where(m, x_bc, 0.0)
    b2 = b - apply_fn(xb)
    return jnp.where(m, xb, b2)
