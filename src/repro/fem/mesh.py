"""Structured affine hexahedral meshes.

The paper's benchmark domain is MFEM's ``beam-hex`` mesh: an 8x1x1
structured hexahedral block with two element attributes (a 50:1 material
contrast), Dirichlet boundary attribute 1 on the x=0 face and Neumann
traction attribute 2 on the x=Lx face.  Uniform refinement doubles the
element count per direction; elements stay affine (the paper's target
regime), so the Jacobian is constant per element.

An optional ``linear_map`` applies a global affine map A x + b to the
box, producing non-diagonal (but still per-element-constant) Jacobians.
This is used by tests to exercise the full J^{-1} code paths.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["HexMesh", "beam_hex", "fine_descendants"]


@dataclasses.dataclass(frozen=True)
class HexMesh:
    """A structured nx x ny x nz hexahedral box mesh.

    Element ordering is lexicographic with ``ex`` fastest:
    ``e = ex + nx * (ey + ny * ez)``.
    """

    nx: int
    ny: int
    nz: int
    lengths: tuple[float, float, float] = (1.0, 1.0, 1.0)
    # Element attribute (material id), shape (nelem,), values in {1, 2, ...}.
    elem_attr: np.ndarray | None = None
    # Optional global affine map (3x3); identity if None.
    linear_map: np.ndarray | None = None

    def __post_init__(self):
        if self.elem_attr is not None:
            object.__setattr__(
                self, "elem_attr", np.asarray(self.elem_attr, dtype=np.int32)
            )
            if self.elem_attr.shape != (self.nelem,):
                raise ValueError(
                    f"elem_attr shape {self.elem_attr.shape} != ({self.nelem},)"
                )

    # -- sizes ------------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.nx, self.ny, self.nz)

    @property
    def nelem(self) -> int:
        return self.nx * self.ny * self.nz

    @property
    def h(self) -> tuple[float, float, float]:
        lx, ly, lz = self.lengths
        return (lx / self.nx, ly / self.ny, lz / self.nz)

    def attributes(self) -> np.ndarray:
        if self.elem_attr is not None:
            return self.elem_attr
        return np.ones(self.nelem, dtype=np.int32)

    # -- refinement --------------------------------------------------------
    def refined(self, times: int = 1) -> "HexMesh":
        """Uniform refinement: each element splits into 8 children that
        inherit the parent's attribute."""
        mesh = self
        for _ in range(times):
            f = 2
            attr = mesh.attributes().reshape(mesh.nz, mesh.ny, mesh.nx)
            attr = np.repeat(np.repeat(np.repeat(attr, f, 0), f, 1), f, 2)
            mesh = HexMesh(
                mesh.nx * f,
                mesh.ny * f,
                mesh.nz * f,
                mesh.lengths,
                attr.reshape(-1),
                mesh.linear_map,
            )
        return mesh

    def refined_to(self, min_elems: int) -> "HexMesh":
        """Refine uniformly until ``nelem >= min_elems`` (paper: ~1000)."""
        mesh = self
        while mesh.nelem < min_elems:
            mesh = mesh.refined()
        return mesh

    # -- geometry ----------------------------------------------------------
    def jacobian(self) -> np.ndarray:
        """Per-element-constant Jacobian of the reference->physical map
        ([-1,1]^3 reference cube), shape (3, 3); identical for all elements
        of a uniform box, possibly non-diagonal under ``linear_map``."""
        hx, hy, hz = self.h
        J = np.diag([hx / 2.0, hy / 2.0, hz / 2.0])
        if self.linear_map is not None:
            J = np.asarray(self.linear_map) @ J
        return J


def fine_descendants(coarse: HexMesh, fine: HexMesh) -> np.ndarray:
    """Fine-mesh element ids of every coarse element's descendants under
    uniform refinement, shape (coarse.nelem, f^3) with f = fine.nx //
    coarse.nx.

    Row ``e`` lists the fine elements covering coarse element ``e`` (in
    fine lexicographic order), so a per-element coefficient field given
    on the fine mesh can be restricted to any coarser hierarchy level by
    aggregating each row — the map the batched GMG solver uses to thread
    heterogeneous (lam_e, mu_e) fields through every level.  For
    ``coarse is fine`` (p-embedding levels share one mesh) this is the
    identity map of shape (nelem, 1)."""
    f, ry, rz = (
        fine.nx // coarse.nx,
        fine.ny // coarse.ny,
        fine.nz // coarse.nz,
    )
    if ry != f or rz != f or (
        coarse.nx * f,
        coarse.ny * f,
        coarse.nz * f,
    ) != fine.shape or f < 1 or (f & (f - 1)):
        raise ValueError(
            f"{fine.shape} is not a uniform power-of-two refinement of "
            f"{coarse.shape}"
        )
    ex = np.arange(coarse.nx)
    ey = np.arange(coarse.ny)
    ez = np.arange(coarse.nz)
    d = np.arange(f)
    # fine index (f*ex + dx) + fine.nx * ((f*ey + dy) + fine.ny * (f*ez + dz))
    fx = (f * ex[:, None] + d[None, :])  # (nx, f)
    fy = (f * ey[:, None] + d[None, :])
    fz = (f * ez[:, None] + d[None, :])
    idx = (
        fx[None, None, :, None, None, :]
        + fine.nx
        * (
            fy[None, :, None, None, :, None]
            + fine.ny * fz[:, None, None, :, None, None]
        )
    )  # (nz, ny, nx, f_z, f_y, f_x)
    return np.ascontiguousarray(
        idx.reshape(coarse.nelem, f**3).astype(np.int32)
    )


def beam_hex(nx: int = 8, ny: int = 1, nz: int = 1) -> HexMesh:
    """The MFEM ``beam-hex`` benchmark beam: x in [0, 8], unit cross
    section, attribute 1 for x < 4 (stiff: lambda=mu=50) and attribute 2
    for x >= 4 (soft: lambda=mu=1)."""
    ex = np.arange(nx)
    attr_x = np.where(ex < nx // 2, 1, 2).astype(np.int32)
    attr = np.tile(attr_x, ny * nz)
    return HexMesh(nx, ny, nz, lengths=(8.0, 1.0, 1.0), elem_attr=attr)
