"""H1-conforming tensor-product finite element space on a structured hex
mesh, with the E-vector <-> L-vector transitions (the G / G^T operators of
the MFEM chain A = P^T G^T B^T D B G P).

Global scalar DoFs live on the tensor grid of GLL nodes:
``(Nx, Ny, Nz) = (nx*p + 1, ny*p + 1, nz*p + 1)`` with lexicographic
numbering (x fastest).  The displacement L-vector is stored as
``(ndof, 3)``; the E-vector as ``(nelem, 3, D1D, D1D, D1D)`` with layout
``[e, c, iz, iy, ix]`` (x fastest — the unit-stride direction of the
paper's X-contraction).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.basis import BasisTables, basis_tables
from repro.fem.mesh import HexMesh

__all__ = ["H1Space"]

VDIM = 3

# Face name -> (axis, side) for the box boundary.
_FACES = {
    "x0": (0, 0), "x1": (0, 1),
    "y0": (1, 0), "y1": (1, 1),
    "z0": (2, 0), "z1": (2, 1),
}


@dataclasses.dataclass(frozen=True)
class H1Space:
    """Vector-valued H1 space of degree p on a structured hex mesh."""

    mesh: HexMesh
    p: int

    # -- basic sizes --------------------------------------------------------
    @property
    def tables(self) -> BasisTables:
        return basis_tables(self.p)

    @property
    def d1d(self) -> int:
        return self.p + 1

    @property
    def node_grid(self) -> tuple[int, int, int]:
        m = self.mesh
        return (m.nx * self.p + 1, m.ny * self.p + 1, m.nz * self.p + 1)

    @property
    def nscalar(self) -> int:
        nx, ny, nz = self.node_grid
        return nx * ny * nz

    @property
    def ndof(self) -> int:
        """True (vector) DoF count, the paper's reported metric."""
        return VDIM * self.nscalar

    @property
    def nelem(self) -> int:
        return self.mesh.nelem

    # -- element-restriction indices ----------------------------------------
    @functools.cached_property
    def gather_ids(self) -> np.ndarray:
        """(nelem, D1D, D1D, D1D) int32 global scalar-node ids, layout
        [e, iz, iy, ix]."""
        p, d1 = self.p, self.d1d
        m = self.mesh
        nx_n, ny_n, _ = self.node_grid
        ex = np.arange(m.nx)
        ey = np.arange(m.ny)
        ez = np.arange(m.nz)
        loc = np.arange(d1)
        gx = ex[:, None] * p + loc[None, :]  # (nx, D1D)
        gy = ey[:, None] * p + loc[None, :]
        gz = ez[:, None] * p + loc[None, :]
        # e = ex + nx*(ey + ny*ez); build ids[ez, ey, ex, iz, iy, ix].
        ids = (
            gx[None, None, :, None, None, :]
            + nx_n * gy[None, :, None, None, :, None]
            + nx_n * ny_n * gz[:, None, None, :, None, None]
        )
        ids = ids.reshape(m.nelem, d1, d1, d1)
        return ids.astype(np.int32)

    @functools.cached_property
    def dof_multiplicity(self) -> np.ndarray:
        """(nscalar,) number of elements sharing each node (for tests and
        counting-based restrictions)."""
        return np.bincount(self.gather_ids.reshape(-1), minlength=self.nscalar)

    # -- E <-> L ---------------------------------------------------------------
    def to_evec(self, u):
        """L-vector (nscalar, 3) -> E-vector (nelem, 3, D1D, D1D, D1D)."""
        gid = jnp.asarray(self.gather_ids)
        ue = u[gid]  # (nelem, D1D, D1D, D1D, 3)
        return jnp.moveaxis(ue, -1, 1)

    def scatter_add(self, ye):
        """E-vector (nelem, 3, D1D, D1D, D1D) -> L-vector (nscalar, 3) via
        G^T (sum of element contributions at shared nodes)."""
        gid = jnp.asarray(self.gather_ids).reshape(-1)
        yflat = jnp.moveaxis(ye, 1, -1).reshape(-1, VDIM)
        return jax.ops.segment_sum(yflat, gid, num_segments=self.nscalar)

    # -- node coordinates ------------------------------------------------------
    @functools.cached_property
    def node_coords_1d(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Physical 1D node coordinates along each axis (reference box,
        before any linear_map)."""
        out = []
        for n_el, L in zip(self.mesh.shape, self.mesh.lengths):
            h = L / n_el
            gll01 = (self.tables.nodes + 1.0) / 2.0  # [0, 1]
            xs = (np.arange(n_el)[:, None] * h + gll01[None, :] * h)
            # Merge shared endpoints: take all but last node of each element.
            xs = np.concatenate([xs[:, :-1].reshape(-1), [L]])
            out.append(xs)
        return tuple(out)

    def node_coords(self) -> np.ndarray:
        """(nscalar, 3) physical node coordinates (x fastest)."""
        xs, ys, zs = self.node_coords_1d
        X, Y, Z = np.meshgrid(xs, ys, zs, indexing="ij")
        pts = np.stack(
            [X.ravel(order="F"), Y.ravel(order="F"), Z.ravel(order="F")], axis=-1
        )
        if self.mesh.linear_map is not None:
            pts = pts @ np.asarray(self.mesh.linear_map).T
        return pts

    # -- boundary -----------------------------------------------------------
    def face_node_ids(self, face: str) -> np.ndarray:
        """Scalar node ids on a box face ('x0', 'x1', 'y0', ...)."""
        axis, side = _FACES[face]
        nx, ny, nz = self.node_grid
        ix = np.arange(nx)
        iy = np.arange(ny)
        iz = np.arange(nz)
        sel = [ix, iy, iz]
        sel[axis] = np.array([0 if side == 0 else self.node_grid[axis] - 1])
        IX, IY, IZ = np.meshgrid(*sel, indexing="ij")
        ids = IX + nx * (IY + ny * IZ)
        return ids.reshape(-1).astype(np.int32)

    def essential_mask(self, faces=("x0",)) -> np.ndarray:
        """(nscalar, 3) bool — True where the DoF is Dirichlet-constrained.
        The paper clamps all displacement components on boundary attribute 1
        (the x=0 face of the beam)."""
        mask = np.zeros((self.nscalar, VDIM), dtype=bool)
        for f in faces:
            mask[self.face_node_ids(f)] = True
        return mask

    # -- load vectors ---------------------------------------------------------
    def traction_rhs(self, face: str, traction, dtype=np.float64) -> np.ndarray:
        """Assemble F_i = int_Gamma t . phi_i dGamma on a box face with a
        constant traction vector (paper: t = (0, 0, -1e-2) on attr 2 = x1).

        Uses the tensor-product face quadrature; only the basis functions of
        face-adjacent elements are nonzero there, and on the structured grid
        these reduce to the face node grid directly.
        """
        t = np.asarray(traction, dtype=dtype)
        axis, _ = _FACES[face]
        tb = self.tables
        # 1D "lumped" row sums: s[i] = sum_q w_q B[q, i] per tangential axis,
        # times h/2 per element; assembled along the axis this becomes the 1D
        # mass-lumped weight vector on the global 1D node line.
        F = np.zeros((self.nscalar, VDIM), dtype=dtype)
        tang = [a for a in range(3) if a != axis]
        h = self.mesh.h
        # per-element 1D weights s (D1D,), assembled on the global line
        w1 = []
        for a in tang:
            s = (tb.qwts @ tb.B) * (h[a] / 2.0)  # (D1D,)
            n_el = self.mesh.shape[a]
            line = np.zeros(n_el * self.p + 1, dtype=dtype)
            for e in range(n_el):
                line[e * self.p : e * self.p + self.d1d] += s
            w1.append(line)
        # Face-jacobian correction for linear_map: scale by area factor.
        if self.mesh.linear_map is not None:
            A = np.asarray(self.mesh.linear_map)
            # area scaling = |(A e_t1) x (A e_t2)| for unit tangent vectors
            F_scale = np.linalg.norm(np.cross(A[:, tang[0]], A[:, tang[1]]))
        else:
            F_scale = 1.0
        ids = self.face_node_ids(face)
        nx, ny, nz = self.node_grid
        grid = [nx, ny, nz]
        face_w = np.outer(w1[0], w1[1]).reshape(-1)  # (n_t1 * n_t2,) "ij"
        # face_node_ids uses meshgrid(indexing="ij") over (ix, iy, iz) with the
        # face axis collapsed; its flattened order matches outer(w_t1, w_t2).
        F[ids] = F_scale * face_w[:, None] * t[None, :]
        return F
