"""Pure-jnp oracle for the PAop Pallas kernel.

Same math as :mod:`repro.core.paop` (which is itself validated against
full assembly); re-exposed here in the kernel's calling convention so the
kernel tests read as kernel-vs-oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.paop import paop_apply

__all__ = ["paop_ref"]


def paop_ref(x_e, lam_w, mu_w, jinv, B, G):
    """x_e: (nelem, 3, D1D, D1D, D1D) element-first framework layout."""
    return paop_apply(x_e, lam_w, mu_w, jinv, B, G)
