"""jit'd public wrapper for the PAop Pallas kernel.

Handles layout (framework element-first <-> kernel element-last),
padding to a whole number of element blocks, and the VMEM-budgeted
choice of elements-per-block (the TPU analog of the paper's slice-wise
working-set bound).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pa_elasticity.pa_elasticity import pa_elasticity_pallas

__all__ = [
    "pa_elasticity",
    "elements_per_block",
    "clamp_elements_per_block",
    "block_workingset_bytes",
]

# Target VMEM footprint per grid step. Real v5e VMEM is ~16 MB; leave
# headroom for double-buffered input/output blocks.
VMEM_BUDGET_BYTES = 8 * 2 ** 20
_LANE = 128  # TPU lane width: EB should be a multiple when possible.


def block_workingset_bytes(p: int, eb: int, itemsize: int = 4) -> int:
    """Working set of one grid step: x/y blocks, lambda/mu blocks, the
    reference gradient (9 ch), Voigt stress (6 ch) and pullback rows
    (3 ch live at a time) at quadrature resolution."""
    d1, q1 = p + 1, p + 2
    per_elem = (
        2 * 3 * d1 ** 3  # x, y
        + 2 * q1 ** 3  # lambda_w, mu_w
        + 9 * q1 ** 3  # ghat / grad
        + 6 * q1 ** 3  # voigt stress
        + 3 * q1 ** 3  # per-output-component pullback rows
    )
    return per_elem * eb * itemsize


def clamp_elements_per_block(eb: int, ne: int) -> int:
    """Clamp a requested elements-per-block to the element count.

    Never returns a block larger than ``ne`` (so padding is bounded below
    2x instead of the >10x blow-up an unclamped 128-block causes on e.g.
    ne=12), and prefers the largest divisor of ``ne`` that is at least
    half the clamped block — zero padding without shrinking the block
    enough to hurt occupancy.
    """
    eb = max(1, min(eb, ne))
    for d in range(eb, 0, -1):
        if ne % d == 0:
            if 2 * d > eb:
                return d
            break
    return eb


def elements_per_block(p: int, ne: int, itemsize: int = 4) -> int:
    """Largest lane-aligned EB whose working set fits the VMEM budget,
    clamped to the element count."""
    eb = _LANE
    while block_workingset_bytes(p, 2 * eb, itemsize) <= VMEM_BUDGET_BYTES:
        eb *= 2
    while eb > 8 and block_workingset_bytes(p, eb, itemsize) > VMEM_BUDGET_BYTES:
        eb //= 2
    return clamp_elements_per_block(eb, ne)


def pa_elasticity(x_e, lam_w, mu_w, jinv, B, G, *, eb=None, interpret=True):
    """Fused PAop operator action.

    x_e:    (nelem, 3, D1D, D1D, D1D)  framework layout
    lam_w:  (nelem, Q1D, Q1D, Q1D)     (mu_w likewise)
    jinv:   (3, 3) mesh-constant affine J^{-1}
    B, G:   (Q1D, D1D)
    Returns y_e in the same layout as x_e.
    """
    if jinv.ndim != 2:
        raise ValueError(
            "pa_elasticity kernel assumes a mesh-constant affine J^{-1}; "
            "use repro.core.paop.paop_apply for per-element geometry"
        )
    ne = x_e.shape[0]
    d1d = x_e.shape[-1]
    q1d = lam_w.shape[-1]
    p = d1d - 1
    itemsize = jnp.dtype(x_e.dtype).itemsize
    if eb is None:
        eb = elements_per_block(p, ne, itemsize)
    eb = clamp_elements_per_block(eb, ne)

    pad = (-ne) % eb
    xt = jnp.moveaxis(x_e, 0, -1)  # (3, D, D, D, NE)
    lt = jnp.moveaxis(lam_w, 0, -1)
    mt = jnp.moveaxis(mu_w, 0, -1)
    if pad:
        xt = jnp.pad(xt, [(0, 0)] * 4 + [(0, pad)])
        lt = jnp.pad(lt, [(0, 0)] * 3 + [(0, pad)])
        mt = jnp.pad(mt, [(0, 0)] * 3 + [(0, pad)])

    yt = pa_elasticity_pallas(
        xt, lt, mt, jinv, B, G, d1d=d1d, q1d=q1d, eb=eb, interpret=interpret
    )
    if pad:
        yt = yt[..., :ne]
    return jnp.moveaxis(yt, -1, 0)
