"""jit'd public wrapper for the PAop Pallas kernel.

Handles lane selection (compiled vs interpret, with automatic
fallback), layout (framework element-first <-> kernel element-last),
padding to a whole number of element blocks, and the VMEM-budgeted
choice of elements-per-block (the TPU analog of the paper's slice-wise
working-set bound).

Lanes
-----
The kernel runs in one of two *lanes*:

* ``"compiled"`` — native Pallas lowering (TPU Mosaic / GPU Triton).
  The real thing: one fused kernel per element block, VMEM-resident
  intermediates, measured numbers that can move on the roofline.
* ``"interpret"`` — the Pallas interpreter.  Runs on any backend
  (including the CPU CI containers), bit-faithful to the kernel
  dataflow, orders of magnitude slower.

``resolve_lane`` picks the lane: an explicit request wins, ``"auto"``
(and the legacy ``interpret=False``) selects ``compiled`` when the
backend can actually lower Pallas (``backend_supports_compiled``, a
cached compile probe) and falls back to ``interpret`` otherwise.  The
*resolved* lane is the honest report of what ran — operators, solvers,
the service and the BENCH artifacts all record it, never the request.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flops import default_q1d
from repro.kernels.pa_elasticity.pa_elasticity import pa_elasticity_pallas

__all__ = [
    "pa_elasticity",
    "elements_per_block",
    "clamp_elements_per_block",
    "block_workingset_bytes",
    "backend_supports_compiled",
    "resolve_lane",
    "PALLAS_LANES",
]

# Target VMEM footprint per grid step. Real v5e VMEM is ~16 MB; leave
# headroom for double-buffered input/output blocks.
VMEM_BUDGET_BYTES = 8 * 2 ** 20
_LANE = 128  # TPU lane width: EB should be a multiple when possible.

PALLAS_LANES = ("auto", "compiled", "interpret")

# Cached per-backend capability probe results (see
# backend_supports_compiled); tests monkeypatch this to simulate a
# compiled-capable backend on CPU.
_SUPPORT_CACHE: dict[str, bool] = {}


def _compile_probe() -> bool:
    """Attempt to actually compile a trivial Pallas kernel without
    ``interpret``.  Any failure — no Mosaic/Triton lowering for this
    backend, driver too old — means the compiled lane is unavailable."""

    def k(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    try:
        x = jnp.zeros((8, 128), jnp.float32)
        jax.jit(
            lambda v: pl.pallas_call(
                k, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype)
            )(v)
        ).lower(x).compile()
        return True
    except Exception:
        return False


def backend_supports_compiled(backend: str | None = None) -> bool:
    """True when the active JAX backend can lower ``pallas_call``
    natively (TPU Mosaic / GPU Triton).  CPU only interprets.  The
    answer is a cached *compile probe* — a backend that should support
    Pallas but fails to compile a trivial kernel reports False, which
    is what makes the ``interpret`` fallback automatic rather than a
    crash at first apply."""
    b = backend if backend is not None else jax.default_backend()
    if b not in _SUPPORT_CACHE:
        _SUPPORT_CACHE[b] = b in ("tpu", "gpu") and _compile_probe()
    return _SUPPORT_CACHE[b]


def resolve_lane(lane: str | None = None, *, interpret: bool | None = None) -> str:
    """Resolve a lane request to the lane that will actually run:
    ``"compiled"`` or ``"interpret"``.

    ``lane`` is ``"auto"`` / ``"compiled"`` / ``"interpret"`` (or None,
    meaning "derive from the legacy ``interpret`` flag": True pins the
    interpreter, False/None asks for auto).  ``"auto"`` and
    ``"compiled"`` both fall back to ``"interpret"`` when
    :func:`backend_supports_compiled` says the backend cannot lower the
    kernel — the resolved value is the report of record for what ran."""
    if lane is None:
        lane = "interpret" if interpret else "auto"
    if lane not in PALLAS_LANES:
        raise ValueError(
            f"unknown pallas lane {lane!r}; expected one of {PALLAS_LANES}"
        )
    if lane == "interpret":
        return "interpret"
    return "compiled" if backend_supports_compiled() else "interpret"


def block_workingset_bytes(
    p: int, eb: int, itemsize: int = 4, q1d: int | None = None
) -> int:
    """Peak working set of one grid step under the component-sliced
    dataflow: the x/y blocks, lambda/mu blocks, and at quadrature
    resolution the 6 Voigt channels + 3 pullback rows + ~3 transient
    sweep buffers live at the forward/backward seam (the 9-channel
    ``ghat`` stack of the naive dataflow is never materialized).

    ``q1d`` defaults to :func:`repro.core.flops.default_q1d` (the same
    helper the streaming-bytes/OI models use) but MUST be passed when
    the kernel runs a different quadrature — ``pa_elasticity`` reads the
    real ``q1d`` off ``lam_w`` and threads it here, so a non-default
    rule budgets VMEM against the truth instead of the default."""
    d1 = p + 1
    q1 = default_q1d(p) if q1d is None else q1d
    per_elem = (
        2 * 3 * d1 ** 3  # x, y
        + 2 * q1 ** 3  # lambda_w, mu_w
        + 6 * q1 ** 3  # voigt stress channels
        + 3 * q1 ** 3  # per-output-component pullback rows
        + 3 * q1 ** 3  # transient forward/backward sweep buffers
    )
    return per_elem * eb * itemsize


def clamp_elements_per_block(eb: int, ne: int) -> int:
    """Clamp a requested elements-per-block to the element count.

    Never returns a block larger than ``ne`` (so padding is bounded
    instead of the >10x blow-up an unclamped 128-block causes on e.g.
    ne=12).  Prefers the largest divisor of ``ne`` that is at least
    half the clamped block — zero padding without shrinking the block
    enough to hurt occupancy.  When no such divisor exists (e.g. prime
    ``ne``), the block is shrunk to ``ceil(ne / nblocks)`` at the same
    grid-step count, so padding is at most ``nblocks - 1`` elements
    (< one element per grid step) — NOT the up-to-2x padding the old
    return-the-request fallback allowed at high p where elements are
    scarce."""
    eb = max(1, min(eb, ne))
    for d in range(eb, 0, -1):
        if ne % d == 0:
            if 2 * d > eb:
                return d  # zero padding, >= half occupancy
            break
    # No divisor of ne in [ceil(eb/2), eb]: keep the grid-step count a
    # block of eb would need and minimize padding at that count.  The
    # result still satisfies 2 * block >= eb (occupancy) and pads by at
    # most nblocks - 1 elements.
    nblocks = -(-ne // eb)
    return -(-ne // nblocks)


def elements_per_block(
    p: int, ne: int, itemsize: int = 4, q1d: int | None = None
) -> int:
    """Largest lane-aligned EB whose working set fits the VMEM budget,
    clamped to the element count.  ``q1d`` is the actual 1-D quadrature
    point count when it differs from the default p+2 rule."""
    eb = _LANE
    while block_workingset_bytes(p, 2 * eb, itemsize, q1d) <= VMEM_BUDGET_BYTES:
        eb *= 2
    while eb > 1 and block_workingset_bytes(p, eb, itemsize, q1d) > VMEM_BUDGET_BYTES:
        eb //= 2
    return clamp_elements_per_block(eb, ne)


def pa_elasticity(
    x_e, lam_w, mu_w, jinv, B, G, *,
    eb=None, interpret: bool | None = None, lane: str | None = None,
):
    """Fused PAop operator action.

    x_e:    (nelem, 3, D1D, D1D, D1D)  framework layout
    lam_w:  (nelem, Q1D, Q1D, Q1D)     (mu_w likewise)
    jinv:   (3, 3) mesh-constant affine J^{-1}
    B, G:   (Q1D, D1D)
    lane:   "auto" | "compiled" | "interpret" (see :func:`resolve_lane`;
            the legacy boolean ``interpret`` is honored when ``lane`` is
            None — ``interpret=True`` pins the interpreter).
    Returns y_e in the same layout as x_e.
    """
    if jinv.ndim != 2:
        raise ValueError(
            "pa_elasticity kernel assumes a mesh-constant affine J^{-1}; "
            "use repro.core.paop.paop_apply for per-element geometry"
        )
    resolved = resolve_lane(lane, interpret=interpret)
    ne = x_e.shape[0]
    d1d = x_e.shape[-1]
    q1d = lam_w.shape[-1]
    p = d1d - 1
    itemsize = jnp.dtype(x_e.dtype).itemsize
    if eb is None:
        eb = elements_per_block(p, ne, itemsize, q1d)
    eb = clamp_elements_per_block(eb, ne)

    # The block working set must fit the VMEM budget for the lane that
    # actually runs — checked against the REAL q1d (read off lam_w), so
    # a non-default quadrature rule cannot silently over-budget VMEM.
    ws = block_workingset_bytes(p, eb, itemsize, q1d)
    if ws > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"pa_elasticity block working set {ws} B (p={p}, q1d={q1d}, "
            f"eb={eb}, itemsize={itemsize}) exceeds the VMEM budget "
            f"{VMEM_BUDGET_BYTES} B; pass a smaller eb or let "
            f"elements_per_block choose it"
        )

    pad = (-ne) % eb
    xt = jnp.moveaxis(x_e, 0, -1)  # (3, D, D, D, NE)
    lt = jnp.moveaxis(lam_w, 0, -1)
    mt = jnp.moveaxis(mu_w, 0, -1)
    if pad:
        xt = jnp.pad(xt, [(0, 0)] * 4 + [(0, pad)])
        lt = jnp.pad(lt, [(0, 0)] * 3 + [(0, pad)])
        mt = jnp.pad(mt, [(0, 0)] * 3 + [(0, pad)])

    yt = pa_elasticity_pallas(
        xt, lt, mt, jinv, B, G,
        d1d=d1d, q1d=q1d, eb=eb, interpret=resolved == "interpret",
    )
    if pad:
        yt = yt[..., :ne]
    return jnp.moveaxis(yt, -1, 0)
