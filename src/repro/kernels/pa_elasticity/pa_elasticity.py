"""Fused sum-factorized PAop elasticity kernel (Pallas, TPU target).

TPU-native adaptation of the paper's PAop kernel (Sec. 4). The paper's
CPU design decisions map as follows:

* **slice-wise loops bounding the L1/L2 working set**  ->  two levels of
  tiling.  Across elements, an explicit `BlockSpec` tiles a *block of EB
  elements* into VMEM, with EB chosen by `ops.elements_per_block` to
  keep the block working set under a VMEM budget.  Within the kernel
  body, the dataflow is *component-sliced*: the forward pass walks one
  displacement component at a time and folds its physical gradients
  straight into the 6 Voigt accumulators, and the backward pass emits
  one output component at a time, writing each straight to its `y_ref`
  slice.  The 9-channel reference-gradient stack (`ghat`) and the
  concatenated output accumulator of the naive dataflow are never
  materialized — the VMEM live set at quadrature resolution is bounded
  by the Voigt channels plus one component's transient sweeps (~12
  Q^3-channels instead of ~18), the TPU analog of the paper's slice
  loops keeping one x/y-plane resident in L1.
* **SIMD vectorization across the contraction loops**  ->  an
  element-last data layout `(3, D1D, D1D, D1D, EB)`.  Each 1D
  contraction becomes a `(Q1D x D1D) @ (D1D x N)` matmul with
  N = (planes x EB) — the element axis fills the 128-wide MXU/VPU lanes
  that a single element's D1D in [2, 9] never could.  This is the TPU
  version of "vectorize across elements".
* **macro-kernel fusion**  ->  the kernel body runs forward
  interpolation, pointwise Voigt stress, and the transpose contraction
  back-to-back on VMEM-resident values; the operator-wide QVec round
  trip through HBM does not exist.  HBM traffic per element is exactly
  x_e, y_e, lambda_w, mu_w (+ the shared B/G tables once per block).
* **Voigt notation**  ->  the stress lives as 6 channels; backward
  reconstructs rows of sigma.J^{-T} through the symmetric index map.

Lanes: `interpret=True` runs the Pallas interpreter (any backend, used
for CPU CI); `interpret=False` is the *compiled* lane (TPU Mosaic /
GPU Triton).  Lane selection with automatic fallback lives in
`ops.resolve_lane`; this module takes the already-resolved boolean.

The kernel assumes affine geometry with a mesh-constant J^{-1} (uniform
box; the general per-element-affine case is handled by the pure-JAX PAop
path).  Validated against `ref.paop_ref` across p in 1..8 and dtypes,
and compiled-vs-interpret (see tests/test_pa_elasticity_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pa_elasticity_pallas"]


# --------------------------------------------------------------------------
# Element-last contraction helpers. Shapes: (..., axis_dim, EB); tables
# (Q1D, D1D). Each is one MXU matmul of shape (Q1D, D1D) x (D1D, N).
# --------------------------------------------------------------------------
def _cx(t, table):
    # contract ix: (..., z, y, x, e) . (q, x) -> (..., z, y, q, e)
    return jnp.einsum("...zyxe,qx->...zyqe", t, table)


def _cy(t, table):
    return jnp.einsum("...zyqe,ry->...zrqe", t, table)


def _cz(t, table):
    return jnp.einsum("...zrqe,sz->...srqe", t, table)


def _cx_t(t, table):
    return jnp.einsum("...zyqe,qx->...zyxe", t, table)


def _cy_t(t, table):
    return jnp.einsum("...zrqe,ry->...zyqe", t, table)


def _cz_t(t, table):
    return jnp.einsum("...srqe,sz->...zrqe", t, table)


def _kernel(x_ref, lam_ref, mu_ref, jinv_ref, b_ref, g_ref, y_ref):
    """One grid step: the fused PAop dataflow for a block of EB elements.

    x_ref:   (3, D1D, D1D, D1D, EB)   VMEM
    lam_ref: (Q1D, Q1D, Q1D, EB)      VMEM  (mu_ref likewise)
    jinv_ref:(3, 3)                   constant per mesh (affine)
    b_ref:   (Q1D, D1D), g_ref: (Q1D, D1D)
    y_ref:   (3, D1D, D1D, D1D, EB)   VMEM

    The body is component-sliced (the paper's slice-wise loop
    reorganization): neither the 9-channel reference gradient stack nor
    a concatenated output buffer ever exists.  Forward folds each
    component's gradients into the 6 Voigt accumulators as it goes;
    backward emits one output component per iteration directly into its
    y_ref slice.
    """
    B = b_ref[...]
    G = g_ref[...]
    jinv = jinv_ref[...]
    lam_w = lam_ref[...]
    mu_w = mu_ref[...]

    # ---- forward, one displacement component c at a time (sm0/sm1 of
    # the paper, sliced).  Live at quadrature resolution: the running
    # Voigt accumulators (3 diagonal gradients + 3 symmetrized
    # off-diagonal sums) and one component's 3 transient reference
    # gradients — never the full (3, 3, Q, Q, Q, EB) grad tensor.
    diag = [None] * 3  # d_c u_c (physical)
    off = {}  # {(j, k): d_k u_j + d_j u_k}, j < k
    for c in range(3):
        xc = x_ref[c]
        u = _cx(xc, B)
        v = _cx(xc, G)
        # ghat[c, :] = (d_xi, d_eta, d_zeta) u_c, reference coords
        g0 = _cz(_cy(v, B), B)
        g1 = _cz(_cy(u, G), B)
        g2 = _cz(_cy(u, B), G)
        # physical row: d_j u_c = sum_m ghat[c, m] Jinv[m, j]
        for j in range(3):
            grad_cj = g0 * jinv[0, j] + g1 * jinv[1, j] + g2 * jinv[2, j]
            if j == c:
                diag[c] = grad_cj
            else:
                key = (min(c, j), max(c, j))
                off[key] = (
                    grad_cj if key not in off else off[key] + grad_cj
                )

    # ---- pointwise structured Voigt stress (weighted), 6 channels
    div = diag[0] + diag[1] + diag[2]
    ld = lam_w * div
    two_mu = 2.0 * mu_w
    s = {
        (0, 0): ld + two_mu * diag[0],
        (1, 1): ld + two_mu * diag[1],
        (2, 2): ld + two_mu * diag[2],
        (0, 1): mu_w * off[(0, 1)],
        (0, 2): mu_w * off[(0, 2)],
        (1, 2): mu_w * off[(1, 2)],
    }

    def sigma(a, b):
        return s[(a, b) if a <= b else (b, a)]

    # ---- backward, one output component c at a time: rows of
    # sigma.J^{-T} through the symmetric map, transpose sweeps, written
    # straight into the component's output slice (no concatenate).
    for c in range(3):
        # q_m = sum_j sigma[c, j] Jinv[m, j]   (3 pullback rows live)
        q = [
            sigma(c, 0) * jinv[m, 0]
            + sigma(c, 1) * jinv[m, 1]
            + sigma(c, 2) * jinv[m, 2]
            for m in range(3)
        ]
        # transpose sweeps: G along the derivative direction m, B elsewhere
        y_c = _cx_t(_cy_t(_cz_t(q[0], B), B), G)
        y_c += _cx_t(_cy_t(_cz_t(q[1], B), G), B)
        y_c += _cx_t(_cy_t(_cz_t(q[2], G), B), B)
        y_ref[c] = y_c


@functools.partial(
    jax.jit, static_argnames=("d1d", "q1d", "eb", "interpret")
)
def pa_elasticity_pallas(x_e, lam_w, mu_w, jinv, B, G, *, d1d, q1d, eb, interpret):
    """Apply the fused PAop kernel.

    x_e: (3, D1D, D1D, D1D, NE) element-last layout, NE a multiple of eb.
    lam_w/mu_w: (Q1D, Q1D, Q1D, NE); jinv: (3, 3); B/G: (Q1D, D1D).
    ``interpret=False`` is the compiled lane (native Pallas lowering);
    callers go through ``ops.pa_elasticity``, which resolves the lane
    against backend capability first.
    """
    ne = x_e.shape[-1]
    assert ne % eb == 0, (ne, eb)
    grid = (ne // eb,)

    def e_idx(i):
        return (0, 0, 0, 0, i)

    def q_idx(i):
        return (0, 0, 0, i)

    def full(i):
        return (0, 0)

    kwargs = {}
    if not interpret:
        # Compiled lane: element blocks are independent, so the grid is
        # free to execute in any order (enables Mosaic to overlap the
        # next block's DMA with this block's compute).
        try:
            from jax.experimental.pallas import tpu as pltpu

            kwargs["compiler_params"] = pltpu.TPUCompilerParams(
                dimension_semantics=("arbitrary",)
            )
        except (ImportError, AttributeError):  # pragma: no cover
            pass  # non-TPU compiled lowering (e.g. Triton) needs none

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x_e.shape, x_e.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, d1d, d1d, d1d, eb), e_idx),
            pl.BlockSpec((q1d, q1d, q1d, eb), q_idx),
            pl.BlockSpec((q1d, q1d, q1d, eb), q_idx),
            pl.BlockSpec((3, 3), full),
            pl.BlockSpec((q1d, d1d), full),
            pl.BlockSpec((q1d, d1d), full),
        ],
        out_specs=pl.BlockSpec((3, d1d, d1d, d1d, eb), e_idx),
        interpret=interpret,
        **kwargs,
    )(x_e, lam_w, mu_w, jinv, B, G)
    return out
