"""Fused sum-factorized PAop elasticity kernel (Pallas, TPU target).

TPU-native adaptation of the paper's PAop kernel (Sec. 4). The paper's
CPU design decisions map as follows:

* **slice-wise loops bounding the L1/L2 working set**  ->  an explicit
  `BlockSpec` that tiles a *block of EB elements* into VMEM.  On TPU the
  whole per-element working set (~114 KB at p=8 in f32) trivially fits
  the ~16 MB VMEM, so the tiling knob is *elements per block*, chosen by
  `ops.elements_per_block` to keep the block working set under a VMEM
  budget.
* **SIMD vectorization across the contraction loops**  ->  an
  element-last data layout `(3, D1D, D1D, D1D, EB)`.  Each 1D
  contraction becomes a `(Q1D x D1D) @ (D1D x N)` matmul with
  N = (channels x planes x EB) — the element axis fills the 128-wide
  MXU/VPU lanes that a single element's D1D in [2, 9] never could.
  This is the TPU version of "vectorize across elements".
* **macro-kernel fusion**  ->  the kernel body runs forward
  interpolation, pointwise Voigt stress, and the transpose contraction
  back-to-back on VMEM-resident values; the operator-wide QVec round
  trip through HBM does not exist.  HBM traffic per element is exactly
  x_e, y_e, lambda_w, mu_w (+ the shared B/G tables once per block).
* **Voigt notation**  ->  the stress lives as 6 channels; backward
  reconstructs rows of sigma.J^{-T} through the symmetric index map.

The kernel assumes affine geometry with a mesh-constant J^{-1} (uniform
box; the general per-element-affine case is handled by the pure-JAX PAop
path).  Validated in interpret mode against `ref.paop_ref` across
p in 1..8 and dtypes (see tests/test_pa_elasticity_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pa_elasticity_pallas"]


# --------------------------------------------------------------------------
# Element-last contraction helpers. Shapes: (..., axis_dim, EB); tables
# (Q1D, D1D). Each is one MXU matmul of shape (Q1D, D1D) x (D1D, N).
# --------------------------------------------------------------------------
def _cx(t, table):
    # contract ix: (..., z, y, x, e) . (q, x) -> (..., z, y, q, e)
    return jnp.einsum("...zyxe,qx->...zyqe", t, table)


def _cy(t, table):
    return jnp.einsum("...zyqe,ry->...zrqe", t, table)


def _cz(t, table):
    return jnp.einsum("...zrqe,sz->...srqe", t, table)


def _cx_t(t, table):
    return jnp.einsum("...zyqe,qx->...zyxe", t, table)


def _cy_t(t, table):
    return jnp.einsum("...zrqe,ry->...zyqe", t, table)


def _cz_t(t, table):
    return jnp.einsum("...srqe,sz->...zrqe", t, table)


def _kernel(x_ref, lam_ref, mu_ref, jinv_ref, b_ref, g_ref, y_ref):
    """One grid step: the fused PAop dataflow for a block of EB elements.

    x_ref:   (3, D1D, D1D, D1D, EB)   VMEM
    lam_ref: (Q1D, Q1D, Q1D, EB)      VMEM  (mu_ref likewise)
    jinv_ref:(3, 3)                   constant per mesh (affine)
    b_ref:   (Q1D, D1D), g_ref: (Q1D, D1D)
    y_ref:   (3, D1D, D1D, D1D, EB)   VMEM
    """
    x = x_ref[...]
    B = b_ref[...]
    G = g_ref[...]
    jinv = jinv_ref[...]
    lam_w = lam_ref[...]
    mu_w = mu_ref[...]

    # ---- forward: X then Y then Z 1D contractions (sm0/sm1 of the paper)
    u = _cx(x, B)
    v = _cx(x, G)
    d_xi = _cy(v, B)
    d_eta = _cy(u, G)
    u_xy = _cy(u, B)
    g_xi = _cz(d_xi, B)
    g_eta = _cz(d_eta, B)
    g_zeta = _cz(u_xy, G)
    # reference gradient: (3c, 3m, Q, Q, Q, EB)
    ghat = jnp.stack([g_xi, g_eta, g_zeta], axis=1)

    # ---- physical gradient: d_j u_c = sum_m ghat[c, m] Jinv[m, j]
    grad = jnp.einsum("cmzyxe,mj->cjzyxe", ghat, jinv)

    # ---- pointwise structured Voigt stress (weighted), 6 channels
    div = grad[0, 0] + grad[1, 1] + grad[2, 2]
    ld = lam_w * div
    two_mu = 2.0 * mu_w
    s00 = ld + two_mu * grad[0, 0]
    s11 = ld + two_mu * grad[1, 1]
    s22 = ld + two_mu * grad[2, 2]
    s01 = mu_w * (grad[0, 1] + grad[1, 0])
    s02 = mu_w * (grad[0, 2] + grad[2, 0])
    s12 = mu_w * (grad[1, 2] + grad[2, 1])

    # ---- backward: rows of sigma J^{-T}; sigma_{cj} via symmetric map
    voigt = ((s00, s01, s02), (s01, s11, s12), (s02, s12, s22))
    acc = None
    for c in range(3):
        # q_m = sum_j sigma[c, j] Jinv[m, j]   (per-output-component buffer)
        q = [
            voigt[c][0] * jinv[m, 0]
            + voigt[c][1] * jinv[m, 1]
            + voigt[c][2] * jinv[m, 2]
            for m in range(3)
        ]
        # transpose sweeps: G along the derivative direction m, B elsewhere
        y_c = _cx_t(_cy_t(_cz_t(q[0], B), B), G)
        y_c += _cx_t(_cy_t(_cz_t(q[1], B), G), B)
        y_c += _cx_t(_cy_t(_cz_t(q[2], G), B), B)
        y_c = y_c[None]
        acc = y_c if acc is None else jnp.concatenate([acc, y_c], axis=0)
    y_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("d1d", "q1d", "eb", "interpret")
)
def pa_elasticity_pallas(x_e, lam_w, mu_w, jinv, B, G, *, d1d, q1d, eb, interpret):
    """Apply the fused PAop kernel.

    x_e: (3, D1D, D1D, D1D, NE) element-last layout, NE a multiple of eb.
    lam_w/mu_w: (Q1D, Q1D, Q1D, NE); jinv: (3, 3); B/G: (Q1D, D1D).
    """
    ne = x_e.shape[-1]
    assert ne % eb == 0, (ne, eb)
    grid = (ne // eb,)

    def e_idx(i):
        return (0, 0, 0, 0, i)

    def q_idx(i):
        return (0, 0, 0, i)

    def full(i):
        return (0, 0)

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x_e.shape, x_e.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, d1d, d1d, d1d, eb), e_idx),
            pl.BlockSpec((q1d, q1d, q1d, eb), q_idx),
            pl.BlockSpec((q1d, q1d, q1d, eb), q_idx),
            pl.BlockSpec((3, 3), full),
            pl.BlockSpec((q1d, d1d), full),
            pl.BlockSpec((q1d, d1d), full),
        ],
        out_specs=pl.BlockSpec((3, d1d, d1d, d1d, eb), e_idx),
        interpret=interpret,
    )(x_e, lam_w, mu_w, jinv, B, G)
    return out
