from repro.kernels.pa_elasticity.ops import pa_elasticity

__all__ = ["pa_elasticity"]
