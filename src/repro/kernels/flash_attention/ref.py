"""Pure-jnp oracle for the flash-attention kernel: materialized causal
(optionally sliding-window) GQA attention in float32."""

from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_ref(q, k, v, *, window=None):
    """q (B, S, H, D); k/v (B, S, K, D) with H = K * G. Returns (B, S, H, D).

    Causal mask; optional sliding window (positions within [i-window+1, i]).
    Computed in f32, returned in q.dtype.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / jnp.sqrt(jnp.float32(D))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, S, H, D).astype(q.dtype)
