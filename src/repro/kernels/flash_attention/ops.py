"""jit'd public wrapper for the flash-attention kernel.

Handles block-size selection (S-divisible, lane-aligned), dtype, and the
fallback to the reference for shapes the kernel doesn't tile (tiny S).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_ref

__all__ = ["flash_attention", "pick_block"]


def pick_block(S: int, target: int = 128) -> int:
    """Largest divisor of S that is <= target (lane-aligned when possible)."""
    b = min(target, S)
    while S % b:
        b -= 1
    return b


def flash_attention(q, k, v, *, window=None, block_q=None, block_k=None,
                    interpret=True):
    """Causal GQA attention, fused. q (B,S,H,D); k/v (B,S,K,D)."""
    B, S, H, D = q.shape
    bq = block_q or pick_block(S)
    bk = block_k or pick_block(S)
    if S < 8:  # not worth tiling; keep the oracle path
        return flash_ref(q, k, v, window=window)
    return flash_attention_pallas(
        q, k, v, block_q=bq, block_k=bk, window=window, interpret=interpret
    )
