"""Fused causal GQA flash-attention kernel (Pallas, TPU target).

The paper's macro-kernel-fusion insight — eliminate the operator-wide
intermediate round trip through main memory — applied to attention: the
(S x S) score matrix never exists in HBM.  Online softmax carries the
running (max, sum, acc) across KV blocks inside VMEM.

TPU adaptation notes (vs. the CUDA flash-attention dataflow):

* Grid = (batch*kv_head, q_blocks, kv_blocks) with the KV block as the
  *innermost* (fastest) grid axis: on TPU the grid is executed
  sequentially per core, so the running softmax state lives in VMEM
  scratch across the kv-block sweep of one q-block — the analogue of a
  CUDA thread block's shared-memory accumulator, but made explicit via
  ``pl.when`` epilogue at the last kv step.
* The query block carries the G = H/K grouped heads folded into the row
  dimension ((G*Bq, D) tiles): GQA shares each loaded KV block across
  the whole query group for free, keeping the MXU minor dims at 128.
* Causality is handled at block granularity: whole blocks strictly
  above the diagonal are masked via a large-negative fill (the wrapper
  skips them entirely when ``block_skip`` — see ops.py); the diagonal
  block uses an elementwise iota mask.  Optional sliding window adds
  the symmetric lower cut.

Validated in interpret mode against ref.flash_ref (tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_q, block_k, n_kv_blocks, window):
    """One (bk-step) of the online-softmax sweep for one q block.

    q_ref: (G*Bq, D); k_ref/v_ref: (Bk, D); o_ref: (G*Bq, D)
    scratch: m/l (G*Bq, 1) f32, acc (G*Bq, D) f32 — persist across the
    kv grid axis (sequential on TPU).
    """
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    gbq = q.shape[0]
    g = gbq // block_q

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G*Bq, Bk)

    # causal / window mask on absolute positions
    rows = jax.lax.broadcasted_iota(jnp.int32, (gbq, k.shape[0]), 0)
    qpos = qi * block_q + rows % block_q  # fold G out of the row index
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (gbq, k.shape[0]), 1
    )
    mask = qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    # mask-aware exp: a fully-masked block would otherwise see
    # exp(NEG_INF - NEG_INF) = 1 (windowed sweeps hit this before the
    # first in-window block).
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _epilogue():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "window", "interpret"),
)
def flash_attention_pallas(q, k, v, *, block_q=128, block_k=128,
                           window=None, interpret=True):
    """q (B, S, H, D); k/v (B, S, K, D), H = K*G. Causal. Returns like q.

    Layout into the kernel: q -> (B*K, S*G?, ...) — we arrange
    (B*K, n_q_blocks) grid with a (G*Bq, D) query tile so each KV head's
    group shares its KV stream.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq = S // block_q
    nk = S // block_k
    scale = 1.0 / (D ** 0.5)

    # (B, S, K, G, D) -> (B*K, nq, G*Bq, D): fold G into the q-block rows.
    qr = (
        q.reshape(B, nq, block_q, K, G, D)
        .transpose(0, 3, 1, 4, 2, 5)
        .reshape(B * K, nq, G * block_q, D)
    )
    kr = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)

    grid = (B * K, nq, nk)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            block_q=block_q,
            block_k=block_k,
            n_kv_blocks=nk,
            window=window,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, G * block_q, D), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, None, G * block_q, D), lambda b, i, j: (b, i, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B * K, nq, G * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    # back to (B, S, H, D)
    out = out.reshape(B, K, nq, G, block_q, D).transpose(0, 2, 4, 1, 3, 5)
    return out.reshape(B, S, H, D)
