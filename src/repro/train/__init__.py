from repro.train.trainer import TrainState, make_train_step, train_state_init  # noqa: F401
