"""Training step factory: loss -> grad -> AdamW, pjit-ready.

``make_train_step`` builds the jittable pure function
``(state, batch) -> (state, metrics)``.  Distribution is supplied from
outside (launch/train.py or launch/dryrun.py) via in/out shardings; the
step itself is sharding-agnostic SPMD.  Buffer donation of ``state``
makes the update in-place at the XLA level (parameters + moments are the
dominant HBM residents at scale).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "train_state_init", "make_train_step"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array  # replicated scalar


def train_state_init(key, cfg, opt_cfg: AdamWConfig | None = None) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(
        params=params,
        opt_state=adamw_init(params),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    cfg,
    opt_cfg: AdamWConfig,
    *,
    remat: bool = True,
    attn_impl: str = "auto",
    act_spec=None,
    logits_spec=None,
    grad_transform: Callable | None = None,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    grad_transform: optional hook applied to the gradient pytree before
    the optimizer — this is where gradient compression
    (repro.distributed.compression) plugs in.  act_spec: sequence-
    parallel activation constraint (see distributed.sharding.act_pspec).
    """

    def train_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(
            state.params, batch, cfg, remat=remat, attn_impl=attn_impl,
            act_spec=act_spec, logits_spec=logits_spec,
        )
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, om = adamw_update(
            opt_cfg, state.params, grads, state.opt_state
        )
        new_state = TrainState(
            params=params, opt_state=opt_state, step=state.step + 1
        )
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step
