"""Loop-aware analytic cost model over jaxprs.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports)
counts a ``while`` body ONCE, so any scan-over-layers model under-reports
flops by ~n_layers (verified empirically in this repo: an unrolled
8-layer stack reports ~6.4x the flops of the identical scanned stack).
This walker computes global (unsharded) flops and a traffic model
directly from the jaxpr, multiplying scan bodies by their trip count —
the numbers the roofline terms actually need.

Conventions (documented in EXPERIMENTS.md):

* flops — dot_general: 2*M*N*K (multiply-add = 2); elementwise /
  reduction ops: one flop per output (or per input for reductions);
  integer/bool/shape ops: 0.  Matches XLA's convention modulo fusion.
* bytes — a *fusion-aware lower bound* of HBM traffic: only ops that
  must touch memory count — dot_general (all operands + result),
  gather/scatter/take/segment_sum, dynamic slicing/update, concatenate,
  and scan xs/ys/carry streaming per iteration.  Pure elementwise chains
  are assumed fused into their consumers (0 incremental bytes), which is
  what XLA fusion does to them on TPU.
* while loops with data-dependent trip counts (none in the dry-run
  cells) count their body once and set ``has_dynamic_loop``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax import core

__all__ = ["jaxpr_cost", "cost_of_fn", "JaxprCost"]


@dataclasses.dataclass
class JaxprCost:
    flops: float = 0.0
    bytes: float = 0.0
    dot_flops: float = 0.0
    gather_scatter_bytes: float = 0.0
    has_dynamic_loop: bool = False

    def __add__(self, o: "JaxprCost") -> "JaxprCost":
        return JaxprCost(
            self.flops + o.flops,
            self.bytes + o.bytes,
            self.dot_flops + o.dot_flops,
            self.gather_scatter_bytes + o.gather_scatter_bytes,
            self.has_dynamic_loop or o.has_dynamic_loop,
        )

    def __mul__(self, k: float) -> "JaxprCost":
        return JaxprCost(
            self.flops * k,
            self.bytes * k,
            self.dot_flops * k,
            self.gather_scatter_bytes * k,
            self.has_dynamic_loop,
        )


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:  # noqa: BLE001
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001
        return 0


_FLOAT_KINDS = ("f", "c", "bf")


def _is_float(aval) -> bool:
    try:
        return aval.dtype.kind in ("f", "c")
    except Exception:  # noqa: BLE001
        return False


_MEM_PRIMS = {
    "gather",
    "scatter",
    "scatter-add",
    "scatter_add",
    "dynamic_slice",
    "dynamic_update_slice",
    "concatenate",
    "segment_sum",
}

_ZERO_COST = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "convert_element_type",
    "bitcast_convert_type", "slice", "rev", "iota", "stop_gradient", "copy",
    "sharding_constraint", "device_put", "split", "pjit_sharding_constraint",
}


def _dot_flops(eqn) -> float:
    d = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = d
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    return 2.0 * _size(out) * k


def jaxpr_cost(jaxpr, consts=None) -> JaxprCost:
    total = JaxprCost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            body = eqn.params["jaxpr"]
            n = eqn.params["length"]
            total = total + jaxpr_cost(body.jaxpr) * n
            # xs/ys streaming already included by body eqns touching them.
            continue
        if name == "while":
            body = eqn.params["body_jaxpr"]
            sub = jaxpr_cost(body.jaxpr)
            sub.has_dynamic_loop = True
            total = total + sub
            continue
        if name == "cond":
            branches = eqn.params["branches"]
            costs = [jaxpr_cost(b.jaxpr) for b in branches]
            total = total + max(costs, key=lambda c: c.flops)
            continue
        if name == "shard_map":
            # the body runs once PER DEVICE of its mesh with local shapes;
            # global cost = body cost x mesh size.
            inner = eqn.params["jaxpr"]
            inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            n_dev = int(eqn.params["mesh"].size)
            total = total + jaxpr_cost(inner) * n_dev
            continue
        # generic call-like primitives (jit, pjit, remat2, custom_vjp_call,
        # closed_call, ...): recurse into whichever sub-jaxpr param exists.
        sub = (
            eqn.params.get("jaxpr")
            or eqn.params.get("call_jaxpr")
            or eqn.params.get("fun_jaxpr")
        )
        if sub is not None:
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            total = total + jaxpr_cost(inner)
            continue
        if name in ("dot_general",):
            f = _dot_flops(eqn)
            b = sum(_bytes(v.aval) for v in eqn.invars) + sum(
                _bytes(v.aval) for v in eqn.outvars
            )
            total = total + JaxprCost(flops=f, bytes=b, dot_flops=f)
            continue
        if name in _MEM_PRIMS or name.startswith("gather") or name.startswith("scatter"):
            b = sum(_bytes(v.aval) for v in eqn.invars) + sum(
                _bytes(v.aval) for v in eqn.outvars
            )
            total = total + JaxprCost(bytes=b, gather_scatter_bytes=b)
            continue
        if name in _ZERO_COST:
            continue
        # elementwise / reduction: flops ~ max(input, output) element count
        if any(_is_float(v.aval) for v in list(eqn.outvars) + list(eqn.invars)):
            n = max(
                [_size(v.aval) for v in eqn.outvars]
                + [_size(v.aval) for v in eqn.invars]
            )
            total = total + JaxprCost(flops=float(n))
    return total


def cost_of_fn(fn, *args, **kwargs) -> JaxprCost:
    """Cost of fn(*args) with abstract (ShapeDtypeStruct) arguments."""
    closed = jax.make_jaxpr(fn, **kwargs)(*args)
    return jaxpr_cost(closed.jaxpr)
