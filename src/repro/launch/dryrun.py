import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization.  Everything below is ordinary.

__doc__ = """Multi-pod dry-run driver.

For every (architecture x input-shape) cell this lowers + compiles the
appropriate step function (train_step / prefill / decode_step / FEM
AddMult) on the production mesh — 16x16 single-pod and 2x16x16
multi-pod — and records memory analysis, cost analysis and the
collective-traffic parse into one JSON per cell under ``--out``.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out runs/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --cells qwen3_32b:train_4k

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system; the driver prints them and exits nonzero at the end.
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             assembly: str = "paop", force: bool = False) -> dict:
    import jax

    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import collective_bytes, model_flops_estimate

    tag = f"{arch}__{shape.replace(':', '_')}__{mesh_kind}"
    path = os.path.join(out_dir, f"{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            prev = json.load(f)
        if prev.get("status") == "ok":  # failed cells re-run after fixes
            return prev

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "chips": int(mesh.size),
        "status": "error",
    }
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh, assembly=assembly)
        rec["meta"] = cell.meta
        lowered = cell.lower(mesh)
        rec["t_lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["t_compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {
            # NOTE: XLA counts while/scan bodies ONCE — these two are kept
            # for reference; the roofline uses the loop-aware jaxpr_cost.
            "xla_flops_per_dev_body_once": float(ca.get("flops", 0.0)),
            "xla_bytes_per_dev_body_once": float(ca.get("bytes accessed", 0.0)),
        }
        from repro.launch.jaxpr_cost import cost_of_fn

        jc = cost_of_fn(cell.fn, *cell.args)
        rec["cost"].update(
            {
                "flops_global": jc.flops,
                "bytes_global": jc.bytes,
                "dot_flops_global": jc.dot_flops,
                "gather_scatter_bytes_global": jc.gather_scatter_bytes,
                "flops_per_dev": jc.flops / mesh.size,
                "bytes_per_dev": jc.bytes / mesh.size,
                "has_dynamic_loop": jc.has_dynamic_loop,
            }
        )
        hlo = compiled.as_text()
        rec["hlo_chars"] = len(hlo)
        rec["collectives"] = collective_bytes(hlo)
        rec["model_flops"] = model_flops_estimate(arch, shape.split(":")[0], cell.meta)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["t_total_s"] = round(time.time() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="all",
                    help="'all' or comma list of arch:shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--assembly", default="paop",
                    help="elasticity ablation level for FEM cells")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch.cells import cell_ids

    if args.cells == "all":
        cells = cell_ids()
    else:
        cells = [tuple(c.split(":", 1)) for c in args.cells.split(",")]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, args.out,
                           assembly=args.assembly, force=args.force)
            ok = rec["status"] == "ok"
            if not ok:
                failures.append((arch, shape, mk, rec.get("error")))
            mem = rec.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
            print(
                f"[{'ok' if ok else 'FAIL':4s}] {arch:18s} {shape:14s} {mk:6s} "
                f"lower={rec.get('t_lower_s', 0):7.1f}s "
                f"compile={rec.get('t_compile_s', 0):7.1f}s "
                f"peak/dev={mem:6.2f} GiB"
                + ("" if ok else f"  {rec.get('error')}"),
                flush=True,
            )
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
