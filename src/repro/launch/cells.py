"""Dry-run cell construction: (architecture x input-shape x mesh) ->
(jittable fn, abstract args, shardings).

A *cell* is one entry of the assignment matrix.  LM cells lower
``train_step`` (train shapes), ``prefill`` (prefill shapes) or
``decode_step`` (decode shapes).  Elasticity cells lower the paper's
AddMult operator (optionally at a chosen ablation assembly level) on the
beam problem at the paper's problem scales.

Everything here is allocation-free: parameters, optimizer state, decode
caches and batches are ``jax.eval_shape`` / ShapeDtypeStruct stand-ins.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_config
from repro.configs.elasticity import ELASTICITY_SHAPES
from repro.core import flops as _fl
from repro.data.pipeline import batch_spec
from repro.distributed.sharding import (
    act_pspec,
    batch_pspec,
    decode_state_pspecs,
    param_pspecs,
    state_pspecs,
)
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import train_state_init, make_train_step

__all__ = ["build_cell", "cell_ids", "Cell", "skip_reason"]


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: tuple  # abstract (ShapeDtypeStruct) args
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    def lower(self, mesh=None):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        return jitted.lower(*self.args)


def skip_reason(arch: str, shape: str) -> str | None:
    """Assignment skip rules: long_500k only for sub-quadratic archs."""
    if arch == "elasticity":
        return None
    if shape == "long_500k":
        cfg = get_config(arch)
        if not cfg.sub_quadratic:
            return (
                "full-attention arch: 500k dense decode is quadratic-cost "
                "KV attention; skipped per assignment (see DESIGN.md)"
            )
    return None


def cell_ids(include_elasticity: bool = True) -> list[tuple[str, str]]:
    from repro.configs.base import ARCH_IDS

    out = []
    for arch in ARCH_IDS:
        if arch == "elasticity":
            if include_elasticity:
                out += [("elasticity", s) for s in ELASTICITY_SHAPES]
            continue
        out += [(arch, s) for s in SHAPES if skip_reason(arch, s) is None]
    return out


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _shardings(mesh, tree_of_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


SMALL_MODEL_PARAMS = int(5e8)  # below this, TP costs more than it saves


def _train_cell(arch: str, cfg, shape, mesh) -> Cell:
    axes = tuple(mesh.axis_names)
    key = jax.random.PRNGKey(0)
    state_shape = jax.eval_shape(lambda k: train_state_init(k, cfg), key)
    bspec = batch_spec(cfg, shape)

    # Models too small to amortize 16-way tensor parallelism (xlstm-125m:
    # one activation all-reduce per matmul for ~0 compute saved — measured
    # 29 GiB/dev of TP all-reduce vs 0.02 s of compute) run pure-DP: the
    # 'model' axis becomes extra batch parallelism, params FSDP over 'data'.
    pure_dp = cfg.n_params() < SMALL_MODEL_PARAMS
    dp = tuple(a for a in axes if a in ("pod", "data"))
    if pure_dp and shape.global_batch % mesh.size == 0:
        dp = axes
    sspec = state_pspecs(state_shape, mesh, tp=not pure_dp)
    bpspec = jax.tree.map(
        lambda leaf: P(dp, *(None,) * (leaf.ndim - 1)), bspec
    ) if pure_dp else batch_pspec(axes, bspec)
    # sequence-parallel activations for scan-over-layer families; the
    # xlstm per-token recurrences reshard every scan step under an
    # S-sharded layout, so they shard batch only.
    if cfg.block_pattern == "xlstm" or pure_dp:
        aspec = P(dp, None, None)
    else:
        aspec = act_pspec(axes)
    step = make_train_step(
        cfg,
        AdamWConfig(),
        remat=True,
        attn_impl="chunked" if shape.seq_len > 1024 else "full",
        act_spec=NamedSharding(mesh, aspec),
        logits_spec=NamedSharding(
            mesh, P(dp, None, None if pure_dp else "model")
        ),
    )
    return Cell(
        arch=arch,
        shape=shape.name,
        fn=step,
        args=(state_shape, bspec),
        in_shardings=(_shardings(mesh, sspec), _shardings(mesh, bpspec)),
        out_shardings=(_shardings(mesh, sspec), None),
        donate_argnums=(0,),
        meta={"kind": "train", "tokens": shape.seq_len * shape.global_batch},
    )


def _prefill_cell(arch: str, cfg, shape, mesh) -> Cell:
    from repro.models.transformer import prefill, init_params, init_decode_state

    axes = tuple(mesh.axis_names)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: init_params(k, cfg), key)
    bspec = batch_spec(cfg, shape)
    # labels are a training-only input
    bspec = {k: v for k, v in bspec.items() if k != "labels"}

    def fn(params, batch):
        return prefill(
            params, batch, cfg, max_len=shape.seq_len, attn_impl="chunked",
            act_spec=NamedSharding(mesh, act_pspec(axes)),
        )

    pspec = param_pspecs(params_shape, mesh)
    bpspec = batch_pspec(axes, bspec)
    state_shape = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len)
    )
    stspec = decode_state_pspecs(state_shape, axes, cfg, mesh)
    return Cell(
        arch=arch,
        shape=shape.name,
        fn=fn,
        args=(params_shape, bspec),
        in_shardings=(_shardings(mesh, pspec), _shardings(mesh, bpspec)),
        out_shardings=(None, _shardings(mesh, stspec)),
        meta={"kind": "prefill", "tokens": shape.seq_len * shape.global_batch},
    )


def _decode_cell(arch: str, cfg, shape, mesh) -> Cell:
    from repro.models.transformer import decode_step, init_params, init_decode_state

    axes = tuple(mesh.axis_names)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: init_params(k, cfg), key)
    B = shape.global_batch
    state_shape = jax.eval_shape(
        lambda: init_decode_state(cfg, B, shape.seq_len)
    )
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1)
    tok = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, token, state, pos):
        return decode_step(params, token, state, pos, cfg)

    pspec = param_pspecs(params_shape, mesh)
    stspec = decode_state_pspecs(state_shape, axes, cfg, mesh)
    dp = tuple(a for a in axes if a in ("pod", "data"))
    tok_sh = NamedSharding(mesh, P(dp, None, *([None] * (len(tok_shape) - 2))))
    if B % int(np.prod([mesh.shape[a] for a in dp])):
        tok_sh = NamedSharding(mesh, P())  # tiny batch: replicate tokens
        # (decode_state_pspecs already skipped the batch axis and kept the
        # head-axis 'model' sharding for the caches)
    return Cell(
        arch=arch,
        shape=shape.name,
        fn=fn,
        args=(params_shape, tok, state_shape, pos),
        in_shardings=(
            _shardings(mesh, pspec),
            tok_sh,
            _shardings(mesh, stspec),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(None, _shardings(mesh, stspec)),
        donate_argnums=(2,),
        meta={"kind": "decode", "tokens": shape.global_batch},
    )


# ---------------------------------------------------------------------------
# Elasticity cells (the paper's workload)
# ---------------------------------------------------------------------------
def _elasticity_cell(shape_name: str, mesh, assembly: str = "paop") -> Cell:
    """AddMult on the production mesh: domain decomposition.

    The L-vector has an odd DoF count (never evenly shardable), so it
    stays replicated at the interface; the *elements* — which DO divide
    the mesh (structured refinement gives power-of-two element counts) —
    are sharded over every mesh axis via a constraint on the E-vector.
    GSPMD then runs gather/physics/scatter owner-computes per shard and
    reduces the overlapping node contributions (the halo exchange).
    """
    from repro.core.operators import ElasticityOperator
    from repro.fem.mesh import beam_hex
    from repro.fem.space import H1Space

    es = ELASTICITY_SHAPES[shape_name]
    m = beam_hex()
    for _ in range(es.n_h_refine):
        m = m.refined()
    space = H1Space(m, es.p)
    op = ElasticityOperator(space, assembly=assembly, dtype=jnp.float32)

    axes = tuple(mesh.axis_names)
    elem_axes = tuple(a for a in axes)
    n_shards = int(np.prod([mesh.shape[a] for a in elem_axes]))
    if space.nelem % n_shards:
        elem_axes = elem_axes[1:]  # drop the pod/data axis if uneven
        n_shards = int(np.prod([mesh.shape[a] for a in elem_axes]))
    e_sh = NamedSharding(mesh, P(elem_axes, None, None, None, None))

    x = jax.ShapeDtypeStruct((space.nscalar, 3), jnp.float32)
    xsh = NamedSharding(mesh, P())  # replicated L-vector interface

    def fn(v):
        x_e = space.to_evec(v)
        x_e = jax.lax.with_sharding_constraint(x_e, e_sh)
        y_e = op._apply_evec(x_e)
        return space.scatter_add(y_e)

    return Cell(
        arch="elasticity",
        shape=f"{shape_name}" + ("" if assembly == "paop" else f":{assembly}"),
        fn=fn,
        args=(x,),
        in_shardings=(xsh,),
        out_shardings=xsh,
        meta={
            "kind": "addmult",
            "assembly": assembly,
            "ndof": space.ndof,
            "nelem": space.nelem,
            "p": es.p,
            "flops_per_elem": _fl.paop_flops_per_elem(es.p)
            if assembly.startswith("paop")
            else _fl.dense_flops_per_elem(es.p),
        },
    )


def _elasticity_dd_cell(shape_name: str, mesh) -> Cell:
    """Domain-decomposed AddMult (shard_map halo exchange) — the
    beyond-paper distribution optimization; compare against the GSPMD
    baseline cell in §Perf."""
    from repro.core.paop_dd import SlabDecomposition
    from repro.fem.mesh import beam_hex
    from repro.fem.space import H1Space

    es = ELASTICITY_SHAPES[shape_name]
    m = beam_hex()
    for _ in range(es.n_h_refine):
        m = m.refined()
    space = H1Space(m, es.p)
    axes = tuple(mesh.axis_names)
    dd = SlabDecomposition(space, mesh, axes, dtype=jnp.float32)

    xb = jax.ShapeDtypeStruct(
        (dd.n_shards, dd.lnx * dd.lny * dd.lnz, 3), jnp.float32
    )
    bsh = NamedSharding(mesh, P((*axes,), None, None))
    return Cell(
        arch="elasticity",
        shape=f"{shape_name}:dd",
        fn=dd.apply_blocks,
        args=(xb,),
        in_shardings=(bsh,),
        out_shardings=bsh,
        meta={
            "kind": "addmult_dd",
            "assembly": "paop_dd",
            "ndof": space.ndof,
            "nelem": space.nelem,
            "p": es.p,
            "grid": [dd.gx, dd.gy],
            "flops_per_elem": _fl.paop_flops_per_elem(es.p),
        },
    )


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh, assembly: str = "paop") -> Cell:
    if arch == "elasticity":
        if assembly == "paop_dd" or shape_name.endswith(":dd"):
            return _elasticity_dd_cell(shape_name.split(":")[0], mesh)
        return _elasticity_cell(shape_name, mesh, assembly)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(arch, shape_name)
    if reason:
        raise ValueError(f"cell ({arch}, {shape_name}) skipped: {reason}")
    if shape.kind == "train":
        return _train_cell(arch, cfg, shape, mesh)
    if shape.kind == "prefill":
        return _prefill_cell(arch, cfg, shape, mesh)
    if shape.kind == "decode":
        return _decode_cell(arch, cfg, shape, mesh)
    raise ValueError(shape.kind)
