"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state): the single-pod mesh is 16 x 16 = 256 chips
(one v5e pod in the 2D view used here), the multi-pod mesh prepends a
``pod`` axis of 2 (512 chips).  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import so these shapes are buildable on the CPU container.

Axis roles (see repro.distributed.sharding):
  pod   — data parallelism across pods; only gradient all-reduce and
          pipeline collective-permute ride the inter-pod links.
  data  — data parallelism within a pod.
  model — tensor/expert parallelism within a pod (ICI-local).
"""

from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; older jax uses plain meshes.
    from jax.sharding import AxisType
except ImportError:
    AxisType = None

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "axis_type_kwargs",
    "MESH_AXES",
]


def axis_type_kwargs(n: int) -> dict:
    """``axis_types=(Auto,) * n`` for jax.make_mesh where supported, {}
    on older jax (which only has implicitly-auto meshes)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}

MESH_AXES = {
    False: ("data", "model"),
    True: ("pod", "data", "model"),
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_type_kwargs(len(axes)))


def make_local_mesh(model_parallel: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = model_parallel or 1
    while n % mp:
        mp //= 2
    return jax.make_mesh(
        (n // mp, mp), ("data", "model"), **axis_type_kwargs(2)
    )
