"""Roofline-term extraction from compiled XLA artifacts.

This container is CPU-only; TPU v5e is the *target*.  The three roofline
terms are derived from the dry-run's compiled artifact:

    compute term    = HLO_FLOPs / (chips * peak_FLOPs)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` on an SPMD-partitioned module reports
*per-device* flops / bytes (verified against a single-device compile of
the same program), so the global quantities are per-device x chips and
the division by chips cancels: each term below is computed directly from
per-device numbers.

collective_bytes is not in cost_analysis: :func:`collective_bytes`
parses the post-optimization HLO (``compiled.as_text()``, whose shapes
are also per-device) and sums operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.  Next to
that simple convention we report ``link_bytes`` under a ring-algorithm
model (what actually crosses a chip's ICI links):

    all-reduce       2 * R * (k-1)/k     (R = per-device result bytes,
    all-gather       R * (k-1)/k          k = collective group size)
    reduce-scatter   R * (k-1)
    all-to-all       R * (k-1)/k
    collective-perm. R

The collective term uses link_bytes (physically meaningful); the table
also records the operand-sum number for comparability.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

__all__ = [
    "V5E",
    "HardwareSpec",
    "collective_bytes",
    "roofline_from_artifacts",
    "RooflineTerms",
    "MeasuredPlacement",
    "place_measured",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # FLOP/s per chip (bf16)
    hbm_bw: float  # bytes/s per chip
    link_bw: float  # bytes/s per ICI link


V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device collective traffic, by both conventions (see module doc).

    Returns dict with 'operand_bytes', 'link_bytes', 'per_op' breakdown.
    """
    operand = 0.0
    link = 0.0
    per_op: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        R = _shape_bytes(m.group("result"))
        if R == 0:
            continue
        k = max(_group_size(line), 1)
        if op == "all-reduce":
            opb = R
            lkb = 2 * R * (k - 1) / k
        elif op == "all-gather":
            opb = R / k
            lkb = R * (k - 1) / k
        elif op == "reduce-scatter":
            opb = R * k
            lkb = R * (k - 1)
        elif op == "all-to-all":
            opb = R
            lkb = R * (k - 1) / k
        else:  # collective-permute
            opb = R
            lkb = R
        operand += opb
        link += lkb
        per_op[op] = per_op.get(op, 0.0) + lkb
    return {"operand_bytes": operand, "link_bytes": link, "per_op": per_op}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    bytes_per_dev: float
    link_bytes_per_dev: float
    operand_bytes_per_dev: float
    model_flops: float  # global useful FLOPs (6*N*D etc.)
    chips: int
    per_op: dict

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs (remat/redundancy waste)."""
        hlo_global = self.flops_per_dev * self.chips
        return self.model_flops / hlo_global if hlo_global else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of the compute roof: compute term over the
        binding term (1.0 = compute-bound at peak)."""
        return self.compute_s / self.bound_s if self.bound_s else float("nan")


def model_flops_estimate(arch: str, shape_name: str, meta: dict) -> float:
    """Useful-FLOPs reference: 6*N*D train, 2*N*D prefill/decode (MoE:
    active params); elasticity: paper-kernel FLOPs/elem x nelem."""
    if arch == "elasticity":
        # forward+backward sum-factorized sweeps: leading-order
        # 2 passes x 3 dirs x 2 tables... measured analytically in
        # benchmarks.table5; use the stored per-elem count when present.
        return meta.get("flops_per_elem", 0.0) * meta.get("nelem", 0)
    from repro.configs.base import get_config, SHAPES

    cfg = get_config(arch)
    n = cfg.n_active_params()
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        toks = shape.seq_len * shape.global_batch
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.seq_len * shape.global_batch
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # decode: one token per row


@dataclasses.dataclass(frozen=True)
class MeasuredPlacement:
    """A *measured* operator apply placed on the roofline: its analytic
    operational intensity, the roof that OI allows on the target
    hardware, and the fraction of it the measurement achieved.

    Unlike :class:`RooflineTerms` (three *predicted* time terms from a
    compiled artifact), this starts from a wall-clock measurement —
    ``benchmarks/operator_sweep.py`` produces one per
    ``BENCH_operator_sweep.json`` row — so ``fraction`` compares reality
    against the model instead of model against model.  On this CPU
    container fractions are tiny; the point is the *trajectory* as perf
    PRs land, measured against a fixed target roof."""

    oi: float  # analytic FLOPs/byte of the measured apply
    achieved_flops: float  # model FLOPs / measured seconds (FLOP/s)
    achieved_bw: float  # model streamed bytes / measured seconds (B/s)
    roof_flops: float  # min(peak, oi * hbm_bw) * chips (FLOP/s)
    fraction: float  # achieved_flops / roof_flops
    bound: str  # which ceiling binds at this OI: "memory" | "compute"
    hw: HardwareSpec


def place_measured(
    *,
    flops_per_apply: float,
    bytes_per_apply: float,
    t_apply_s: float,
    chips: int = 1,
    hw: HardwareSpec = V5E,
) -> MeasuredPlacement:
    """Place one measured operator apply against ``hw``'s roofline.
    ``flops_per_apply`` / ``bytes_per_apply`` are the analytic models
    (paper kernel FLOPs and streaming bytes); ``t_apply_s`` the fenced
    wall time of one apply."""
    if t_apply_s <= 0:
        raise ValueError(f"t_apply_s must be > 0, got {t_apply_s}")
    if bytes_per_apply <= 0:
        raise ValueError(f"bytes_per_apply must be > 0, got {bytes_per_apply}")
    oi = flops_per_apply / bytes_per_apply
    roof = min(hw.peak_flops, oi * hw.hbm_bw) * chips
    return MeasuredPlacement(
        oi=oi,
        achieved_flops=flops_per_apply / t_apply_s,
        achieved_bw=bytes_per_apply / t_apply_s,
        roof_flops=roof,
        fraction=(flops_per_apply / t_apply_s) / roof,
        bound="memory" if oi * hw.hbm_bw < hw.peak_flops else "compute",
        hw=hw,
    )


def roofline_from_artifacts(
    *,
    flops_per_dev: float,
    bytes_per_dev: float,
    hlo_text: str | None,
    chips: int,
    model_flops: float,
    hw: HardwareSpec = V5E,
    coll: dict | None = None,
) -> RooflineTerms:
    if coll is None:
        coll = collective_bytes(hlo_text or "")
    return RooflineTerms(
        compute_s=flops_per_dev / hw.peak_flops,
        memory_s=bytes_per_dev / hw.hbm_bw,
        collective_s=coll["link_bytes"] / hw.link_bw,
        flops_per_dev=flops_per_dev,
        bytes_per_dev=bytes_per_dev,
        link_bytes_per_dev=coll["link_bytes"],
        operand_bytes_per_dev=coll["operand_bytes"],
        model_flops=model_flops,
        chips=chips,
        per_op=coll.get("per_op", {}),
    )
