"""End-to-end driver for the paper's benchmark: the two-material
cantilever beam under a constant downward traction, solved by
GMG-preconditioned PCG (paper Sec. 5.1.4).

Usage:
    PYTHONPATH=src python -m repro.launch.solve --p 2 --refine 2 \
        --assembly paop --coarse cholesky

Reports the paper's phase breakdown: Prec. (preconditioner setup),
Form-LS (RHS + constraint elimination), Solve (outer PCG), Total,
iteration count, and operator kernel time accumulated inside AddMult.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import MATERIALS_BEAM
from repro.core.operators import ElasticityOperator
from repro.core.precision import resolve_precision
from repro.fem.bc import eliminate_rhs
from repro.fem.mesh import beam_hex
from repro.solvers.cg import pcg
from repro.solvers.gmg import build_hierarchy

TRACTION = (0.0, 0.0, -1e-2)


@dataclasses.dataclass
class SolveReport:
    p: int
    assembly: str
    ndof: int
    nelem: int
    iterations: int
    t_precond: float
    t_form_ls: float
    t_solve: float
    t_total: float
    final_rel_norm: float
    precision: str = "f64"
    x: Any = None


def solve_beam(
    p: int,
    n_h_refine: int = 1,
    assembly: str = "paop",
    coarse_mesh=None,
    rel_tol: float = 1e-6,
    maxiter: int = 5000,
    coarse_method: str = "cholesky",
    dtype=None,
    precision: str | None = None,
    keep_solution: bool = False,
    pallas_interpret: bool | None = None,
    pallas_lane: str | None = None,
    materials: dict | None = None,
    traction=TRACTION,
) -> SolveReport:
    """Solve the beam benchmark once.  ``precision`` names a
    :class:`~repro.core.precision.PrecisionPolicy`: the GMG hierarchy
    (smoothers, transfers, element kernels) is built at the policy's
    ``precond_dtype`` while the outer PCG — operator apply, residual
    norms, tolerance test — runs at ``solve_dtype``, with casts only at
    the preconditioner boundary.  The legacy uniform ``dtype`` argument
    still works (f64 default)."""
    policy = resolve_precision(precision, dtype)
    coarse_mesh = coarse_mesh if coarse_mesh is not None else beam_hex()
    materials = materials if materials is not None else MATERIALS_BEAM
    t0 = time.perf_counter()

    # --- preconditioner setup (GMG hierarchy, smoothers, coarse factor)
    gmg = build_hierarchy(
        coarse_mesh,
        n_h_refine,
        p,
        assembly=assembly,
        materials=materials,
        dtype=policy.precond_dtype,
        coarse_method=coarse_method,
        pallas_interpret=pallas_interpret,
        pallas_lane=pallas_lane,
    )
    fine = gmg.fine
    sdt = policy.solve_dtype
    if jnp.dtype(sdt) != jnp.dtype(policy.precond_dtype):
        # Split-precision fine level: the outer Krylov streams its own
        # solve-dtype operator; the V-cycle is entered/left via casts.
        solve_op = ElasticityOperator(
            fine.space,
            assembly=assembly,
            materials=materials,
            dtype=sdt,
            ess_faces=("x0",),
            pallas_interpret=pallas_interpret,
            pallas_lane=pallas_lane,
        )
        A = solve_op.constrained()
        pdt = policy.precond_dtype
        M = lambda r: gmg(r.astype(pdt)).astype(sdt)  # noqa: E731
        rhs_op = solve_op.apply
        ess_mask = solve_op.ess_mask
    else:
        A = fine.constrained
        M = gmg
        rhs_op = fine.operator.apply
        ess_mask = fine.ess_mask
    t1 = time.perf_counter()

    # --- form linear system: traction RHS + essential elimination
    b = jnp.asarray(fine.space.traction_rhs("x1", traction), dtype=sdt)
    b = eliminate_rhs(rhs_op, ess_mask, b)
    t2 = time.perf_counter()

    # --- outer PCG with the GMG preconditioner
    @jax.jit
    def run(bv):
        return pcg(A, bv, M=M, rel_tol=rel_tol, maxiter=maxiter)

    res = run(b)
    x = res.x.block_until_ready()
    t3 = time.perf_counter()

    return SolveReport(
        p=p,
        assembly=assembly,
        ndof=fine.space.ndof,
        nelem=fine.space.nelem,
        iterations=int(res.iterations),
        t_precond=t1 - t0,
        t_form_ls=t2 - t1,
        t_solve=t3 - t2,
        t_total=t3 - t0,
        final_rel_norm=float(res.final_norm / res.initial_norm),
        precision=policy.name,
        x=x if keep_solution else None,
    )


def main() -> None:
    # The f64 tiers of every policy need x64 enabled; without it jax
    # silently truncates to f32 and the residual accounting lies.
    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--refine", type=int, default=1)
    ap.add_argument("--assembly", default="paop")
    ap.add_argument("--coarse", default="cholesky")
    ap.add_argument("--rel-tol", type=float, default=1e-6)
    ap.add_argument("--precision", default="f64",
                    choices=["f64", "f32", "mixed", "mixed-bf16"],
                    help="precision policy: uniform f64/f32, or mixed / "
                         "mixed-bf16 (f64 outer PCG + residual test over "
                         "a reduced-precision V-cycle)")
    args = ap.parse_args()

    rep = solve_beam(
        args.p,
        args.refine,
        assembly=args.assembly,
        rel_tol=args.rel_tol,
        coarse_method=args.coarse,
        precision=args.precision,
    )
    print(
        f"p={rep.p} assembly={rep.assembly} precision={rep.precision} "
        f"ndof={rep.ndof} "
        f"iters={rep.iterations} prec={rep.t_precond:.3f}s "
        f"form={rep.t_form_ls:.3f}s solve={rep.t_solve:.3f}s "
        f"total={rep.t_total:.3f}s rel={rep.final_rel_norm:.2e}"
    )


if __name__ == "__main__":
    main()
