"""End-to-end driver for the paper's benchmark: the two-material
cantilever beam under a constant downward traction, solved by
GMG-preconditioned PCG (paper Sec. 5.1.4).

Usage:
    PYTHONPATH=src python -m repro.launch.solve --p 2 --refine 2 \
        --assembly paop --coarse cholesky

Reports the paper's phase breakdown: Prec. (preconditioner setup),
Form-LS (RHS + constraint elimination), Solve (outer PCG), Total,
iteration count, and operator kernel time accumulated inside AddMult.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import MATERIALS_BEAM
from repro.fem.bc import eliminate_rhs
from repro.fem.mesh import beam_hex
from repro.solvers.cg import pcg
from repro.solvers.gmg import build_hierarchy

TRACTION = (0.0, 0.0, -1e-2)


@dataclasses.dataclass
class SolveReport:
    p: int
    assembly: str
    ndof: int
    nelem: int
    iterations: int
    t_precond: float
    t_form_ls: float
    t_solve: float
    t_total: float
    final_rel_norm: float
    x: Any = None


def solve_beam(
    p: int,
    n_h_refine: int = 1,
    assembly: str = "paop",
    coarse_mesh=None,
    rel_tol: float = 1e-6,
    maxiter: int = 5000,
    coarse_method: str = "cholesky",
    dtype=jnp.float64,
    keep_solution: bool = False,
    pallas_interpret: bool | None = None,
    pallas_lane: str | None = None,
    materials: dict | None = None,
    traction=TRACTION,
) -> SolveReport:
    coarse_mesh = coarse_mesh if coarse_mesh is not None else beam_hex()
    t0 = time.perf_counter()

    # --- preconditioner setup (GMG hierarchy, smoothers, coarse factor)
    gmg = build_hierarchy(
        coarse_mesh,
        n_h_refine,
        p,
        assembly=assembly,
        materials=materials if materials is not None else MATERIALS_BEAM,
        dtype=dtype,
        coarse_method=coarse_method,
        pallas_interpret=pallas_interpret,
        pallas_lane=pallas_lane,
    )
    fine = gmg.fine
    t1 = time.perf_counter()

    # --- form linear system: traction RHS + essential elimination
    b = jnp.asarray(
        fine.space.traction_rhs("x1", traction), dtype=dtype
    )
    b = eliminate_rhs(fine.operator.apply, fine.ess_mask, b)
    t2 = time.perf_counter()

    # --- outer PCG with the GMG preconditioner
    @jax.jit
    def run(bv):
        return pcg(
            fine.constrained, bv, M=gmg, rel_tol=rel_tol, maxiter=maxiter
        )

    res = run(b)
    x = res.x.block_until_ready()
    t3 = time.perf_counter()

    return SolveReport(
        p=p,
        assembly=assembly,
        ndof=fine.space.ndof,
        nelem=fine.space.nelem,
        iterations=int(res.iterations),
        t_precond=t1 - t0,
        t_form_ls=t2 - t1,
        t_solve=t3 - t2,
        t_total=t3 - t0,
        final_rel_norm=float(res.final_norm / res.initial_norm),
        x=x if keep_solution else None,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--refine", type=int, default=1)
    ap.add_argument("--assembly", default="paop")
    ap.add_argument("--coarse", default="cholesky")
    ap.add_argument("--rel-tol", type=float, default=1e-6)
    args = ap.parse_args()

    rep = solve_beam(
        args.p,
        args.refine,
        assembly=args.assembly,
        rel_tol=args.rel_tol,
        coarse_method=args.coarse,
    )
    print(
        f"p={rep.p} assembly={rep.assembly} ndof={rep.ndof} "
        f"iters={rep.iterations} prec={rep.t_precond:.3f}s "
        f"form={rep.t_form_ls:.3f}s solve={rep.t_solve:.3f}s "
        f"total={rep.t_total:.3f}s rel={rep.final_rel_norm:.2e}"
    )


if __name__ == "__main__":
    main()
