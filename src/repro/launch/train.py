"""End-to-end training driver.

Runs a real (allocated, stepped) training loop on whatever devices exist
— the CPU container trains reduced configs; on a pod the same driver
takes the full configs.  Demonstrates the whole substrate: deterministic
sharded data pipeline, FSDP+TP sharding, remat + sequence-parallel
constraints, AdamW, atomic checkpointing with restart, straggler
watchdog, and optional error-feedback gradient compression.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 256 --ckpt-dir runs/ckpt

Fault tolerance: kill the process at any step and rerun the same command
— it resumes from the last complete checkpoint with bit-identical data
order (the pipeline is a pure function of the step counter).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ShapeConfig, get_config, get_reduced
from repro.data.pipeline import TokenPipeline, make_batch
from repro.distributed.elastic import StepWatchdog
from repro.distributed.sharding import batch_pspec, state_pspecs
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainState, make_train_step, train_state_init

__all__ = ["train_loop", "main"]


def train_loop(
    cfg,
    shape: ShapeConfig,
    *,
    steps: int = 100,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    seed: int = 0,
    opt: AdamWConfig | None = None,
    compression=None,
    log_every: int = 10,
    mesh=None,
    watchdog_timeout: float = 3600.0,
):
    """Train; returns (final state, list of metric dicts)."""
    opt = opt or AdamWConfig(total_steps=steps, warmup_steps=max(steps // 20, 1))
    mesh = mesh or make_local_mesh()
    axes = tuple(mesh.axis_names)

    state = train_state_init(jax.random.PRNGKey(seed), cfg)
    sspec = state_pspecs(state, mesh)
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                            is_leaf=lambda x: isinstance(x, P))
    state = jax.tree.map(jax.device_put, state, state_sh)

    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_latest(state)
        if restored is not None:
            state, extra, start_step = restored
            state = jax.tree.map(jax.device_put, state, state_sh)
            print(f"[train] resumed from checkpoint step {start_step}")

    grad_transform = None
    if compression is not None:
        # stateless wrapper: residual folded into opt extras would need a
        # TrainState extension; examples keep residual host-side.
        grad_transform = compression

    step_fn = jax.jit(
        make_train_step(cfg, opt, grad_transform=grad_transform),
        in_shardings=(state_sh, None),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )

    pipe = TokenPipeline(cfg, shape, seed=seed, start_step=start_step)
    wd = StepWatchdog(watchdog_timeout)
    history = []
    t0 = time.perf_counter()
    try:
        for i in range(start_step, steps):
            batch = next(pipe)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            with wd.step():
                state, metrics = step_fn(state, batch)
            if (i + 1) % log_every == 0 or i + 1 == steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i + 1
                m["wall_s"] = time.perf_counter() - t0
                history.append(m)
                print(
                    f"[train] step {i+1:5d} loss {m['loss']:.4f} "
                    f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                    f"({m['wall_s']:.1f}s)", flush=True,
                )
            if mgr is not None and (i + 1) % ckpt_every == 0:
                mgr.save(i + 1, state, extra={"arch": cfg.name})
    finally:
        pipe.close()
    if mgr is not None:
        mgr.save(steps, state, extra={"arch": cfg.name})
    return state, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the small same-family smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = dataclasses.replace(cfg, dtype="float32")
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    train_loop(
        cfg, shape, steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, seed=args.seed, opt=opt,
    )


if __name__ == "__main__":
    main()
