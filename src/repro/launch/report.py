"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts in runs/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report --dir runs/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.roofline import V5E, RooflineTerms

__all__ = ["load_records", "roofline_row", "render_dryrun", "render_roofline"]


def load_records(d: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def terms_of(rec: dict) -> RooflineTerms:
    c = rec["cost"]
    coll = rec["collectives"]
    return RooflineTerms(
        compute_s=c["flops_per_dev"] / V5E.peak_flops,
        memory_s=c["bytes_per_dev"] / V5E.hbm_bw,
        collective_s=coll["link_bytes"] / V5E.link_bw,
        flops_per_dev=c["flops_per_dev"],
        bytes_per_dev=c["bytes_per_dev"],
        link_bytes_per_dev=coll["link_bytes"],
        operand_bytes_per_dev=coll["operand_bytes"],
        model_flops=rec.get("model_flops", 0.0),
        chips=rec["chips"],
        per_op=coll.get("per_op", {}),
    )


def render_dryrun(recs: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | status | lower s | compile s | "
        "peak GiB/dev | HLO flops/dev | collective GiB/dev (link) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        ok = r.get("status") == "ok"
        mem = r.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
        coll = r.get("collectives", {}).get("link_bytes", 0) / 2**30
        flops = r.get("cost", {}).get("flops_per_dev", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{'ok' if ok else 'FAIL'} | {r.get('t_lower_s', '')} | "
            f"{r.get('t_compile_s', '')} | {mem:.2f} | {flops:.3e} | "
            f"{coll:.3f} |"
        )
    return "\n".join(out)


def render_roofline(recs: list[dict], mesh: str = "single") -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "bound s | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        t = terms_of(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {t.compute_s:.4e} | "
            f"{t.memory_s:.4e} | {t.collective_s:.4e} | {t.dominant} | "
            f"{t.bound_s:.4e} | {t.useful_flops_ratio:.2f} | "
            f"{t.roofline_fraction:.3f} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load_records(args.dir)
    n_ok = sum(r.get("status") == "ok" for r in recs)
    print(f"## Dry-run ({n_ok}/{len(recs)} cells ok)\n")
    print(render_dryrun(recs))
    print(f"\n## Roofline ({args.mesh}-pod, v5e constants)\n")
    print(render_roofline(recs, args.mesh))


if __name__ == "__main__":
    main()
