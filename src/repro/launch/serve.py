"""Serving driver: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --requests 8 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = dataclasses.replace(cfg, dtype="float32")

    rng = np.random.default_rng(args.seed)
    shape = (args.prompt_len,) if not cfg.n_codebooks else (
        args.prompt_len, cfg.n_codebooks)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab, shape).astype(np.int32),
            max_new_tokens=args.new_tokens,
            temperature=args.temperature,
        )
        for _ in range(args.requests)
    ]
    eng = ServeEngine(cfg, max_len=args.prompt_len + args.new_tokens + 8,
                      max_batch=args.max_batch, seed=args.seed)
    t0 = time.perf_counter()
    eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
