"""CLI for the batched elasticity solve service.

Generates a mixed multi-scenario workload (varying materials, tractions
and tolerances, optionally across several discretizations), drives it
through :class:`repro.serve.elasticity_service.ElasticityService`, and
prints per-request reports plus aggregate throughput.

Usage:
    PYTHONPATH=src python -m repro.launch.serve_solve \
        --n-requests 16 --max-batch 8 --p 2 --refine 1
    PYTHONPATH=src python -m repro.launch.serve_solve --p 1 2  # mixed keys
    PYTHONPATH=src python -m repro.launch.serve_solve --continuous
"""

from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

from repro.serve.elasticity_service import (  # noqa: E402
    ElasticityService,
    SolveRequest,
)


def make_workload(
    n_requests: int, ps: list[int], refine: int, base_tol: float
) -> list[SolveRequest]:
    """A deterministic mixed workload: alternating material contrasts,
    traction directions/magnitudes and tolerances across ``ps``."""
    reqs = []
    for i in range(n_requests):
        stiff = 50.0 + 10.0 * (i % 3)
        soft = 1.0 + 0.5 * (i % 2)
        tz = -1e-2 * (1.0 + 0.25 * (i % 4))
        ty = 2e-3 if i % 2 else 0.0
        reqs.append(
            SolveRequest(
                p=ps[i % len(ps)],
                refine=refine,
                materials={1: (stiff, stiff), 2: (soft, soft)},
                traction=(0.0, ty, tz),
                rel_tol=base_tol if i % 2 else base_tol * 1e-2,
            )
        )
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--p", type=int, nargs="+", default=[2])
    ap.add_argument("--refine", type=int, default=1)
    ap.add_argument("--rel-tol", type=float, default=1e-6)
    ap.add_argument("--assembly", default="paop")
    ap.add_argument("--repeat", type=int, default=1,
                    help="re-run the workload to demonstrate cache hits")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (slot refill + bucketed "
                         "padding) instead of generational")
    ap.add_argument("--chunk-iters", type=int, default=8,
                    help="PCG iterations per continuous chunk")
    args = ap.parse_args()

    service = ElasticityService(
        max_batch=args.max_batch, assembly=args.assembly,
        chunk_iters=args.chunk_iters,
    )
    for round_i in range(args.repeat):
        reqs = make_workload(
            args.n_requests, args.p, args.refine, args.rel_tol
        )
        t0 = time.perf_counter()
        if args.continuous:
            reports = service.solve_continuous(reqs)
        else:
            reports = service.solve(reqs)
        dt = time.perf_counter() - t0
        print(
            f"-- round {round_i}: {len(reports)} scenarios in {dt:.2f}s "
            f"({len(reports) / dt:.2f} scenarios/s)"
        )
        print(
            f"{'i':>3} {'key':16s} {'ndof':>7} {'iters':>5} {'conv':>5} "
            f"{'rel_norm':>9} {'hit':>4} {'setup(s)':>8} {'solve(s)':>8}"
        )
        for i, rep in enumerate(reports):
            p, refine, shape = rep.key[:3]
            short_key = f"p{p}/r{refine}/{'x'.join(map(str, shape))}"
            print(
                f"{i:>3} {short_key:16s} {rep.ndof:>7} "
                f"{rep.iterations:>5} {str(rep.converged):>5} "
                f"{rep.final_rel_norm:>9.2e} {str(rep.cache_hit):>4} "
                f"{rep.t_setup:>8.3f} {rep.t_solve:>8.3f}"
            )
    print(f"service stats: {service.stats}")


if __name__ == "__main__":
    main()
