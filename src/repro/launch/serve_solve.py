"""CLI for the batched elasticity solve service.

Generates a mixed multi-scenario workload (varying materials, tractions
and tolerances, optionally across several discretizations), drives it
through :class:`repro.serve.elasticity_service.ElasticityService`, and
prints per-request reports plus aggregate throughput.

Usage:
    PYTHONPATH=src python -m repro.launch.serve_solve \
        --n-requests 16 --max-batch 8 --p 2 --refine 1
    PYTHONPATH=src python -m repro.launch.serve_solve --p 1 2  # mixed keys
    PYTHONPATH=src python -m repro.launch.serve_solve --continuous
    PYTHONPATH=src python -m repro.launch.serve_solve \
        --continuous --chunk-policy adaptive   # cadence-driven chunks
    PYTHONPATH=src python -m repro.launch.serve_solve \
        --continuous --devices 4 --chunk-policy shard-adaptive
    PYTHONPATH=src python -m repro.launch.serve_solve --devices 4  # sharded
    PYTHONPATH=src python -m repro.launch.serve_solve \
        --material-field lognormal:7   # heterogeneous per-element fields
    PYTHONPATH=src python -m repro.launch.serve_solve --continuous \
        --metrics-out metrics.prom --trace-out trace.json  # observability
    PYTHONPATH=src python -m repro.launch.serve_solve --continuous \
        --checkpoint-dir ckpt --checkpoint-every 2   # fault tolerance
    PYTHONPATH=src python -m repro.launch.serve_solve --continuous \
        --checkpoint-dir ckpt --resume               # restart after a kill

``--material-field {graded,checkerboard,lognormal[:seed]}`` replaces the
attribute-dict materials with per-element ``(lam_e, mu_e)`` coefficient
fields on the fine mesh — graded stiffness along the beam, a two-phase
checkerboard composite, or a lognormal random field (the classic
random-media setting).  Requests cycle through a small field vocabulary
so the continuous engine's digest-keyed prep-row reuse still engages.

``--devices N`` shards the scenario axis of every compiled solver over N
devices.  On a CPU-only host it forces N virtual XLA host devices
(``--xla_force_host_platform_device_count``), which MUST happen before
jax initializes its backend — hence the heavyweight imports live inside
``main``.

``--chunk-policy {fixed,adaptive,shard-adaptive}`` selects how the
continuous engine picks each chunk's PCG iteration count (and, for
shard-adaptive, which device refills land on).  Scheduling never changes
numerics — reports are identical across policies — and the run prints
the scheduler counters (chunks dispatched, mean chunk length, wasted
iterations); see docs/SCHEDULING.md.

``--metrics-out`` dumps the service's metrics registry (Prometheus text,
or a JSON snapshot for ``.json`` paths); ``--trace-out`` attaches a
device-fencing span recorder and writes a Chrome ``trace_event`` file
viewable at https://ui.perfetto.dev; ``--events-out`` writes the same
spans as JSON-lines.  A latency-quantile summary line (p50/p90/p99 from
the registry histogram) prints either way; see docs/OBSERVABILITY.md.

``--checkpoint-dir`` (continuous mode) snapshots the full serving state
— every in-flight resumable BpcgState, the queue, tickets — every
``--checkpoint-every`` steps through
:class:`repro.serve.recovery.ServiceRecovery`; ``--resume`` restores the
newest intact checkpoint instead of submitting a fresh workload, so a
SIGKILLed run restarted with the same flags finishes every accepted
request with bitwise-identical solutions and iteration counts.
``--devices`` may differ across the restart (elastic rescale).
``--watchdog-timeout`` arms the step hang detector; ``--report-out``
writes one JSON line per report (ticket, iterations, solution hash) for
differential comparison; ``--kill-after-steps`` SIGKILLs the process
mid-run (fault-injection hook for the test harness).  See
docs/FAULT_TOLERANCE.md.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import time

import jax

jax.config.update("jax_enable_x64", True)


def make_material_field(kind: str, coarse_mesh, refine: int, i: int):
    """Per-element ``(lam_e, mu_e)`` fields on the fine mesh for request
    ``i``.  ``kind`` is ``graded`` (stiffness ramps down along x from the
    clamped end), ``checkerboard`` (two-phase composite by element
    parity) or ``lognormal[:seed]`` (iid lognormal random medium).  A
    vocabulary of 4 variants per kind keeps digest-keyed prep reuse
    live under continuous refill."""
    import numpy as np

    fine = coarse_mesh.refined(refine)
    nx, ny, nz = fine.shape
    e = np.arange(fine.nelem)
    ex, ey, ez = e % nx, (e // nx) % ny, e // (nx * ny)
    v = i % 4  # field vocabulary index
    if kind == "graded":
        t = (ex + 0.5) / nx  # 0 at the clamped x=0 face
        lam = (50.0 + 5.0 * v) * (1.0 - t) + 1.0
        mu = 0.8 * lam
    elif kind == "checkerboard":
        hard = (ex + ey + ez) % 2 == 0
        lam = np.where(hard, 50.0 + 5.0 * v, 1.0 + 0.2 * v)
        mu = np.where(hard, 45.0 + 5.0 * v, 1.0)
    elif kind.startswith("lognormal"):
        seed = int(kind.split(":", 1)[1]) if ":" in kind else 0
        rng = np.random.default_rng(seed * 1000 + v)
        lam = np.exp(rng.normal(np.log(10.0), 0.6, fine.nelem))
        mu = np.exp(rng.normal(np.log(8.0), 0.6, fine.nelem))
    else:
        raise ValueError(
            f"unknown --material-field {kind!r} (expected graded, "
            f"checkerboard or lognormal[:seed])"
        )
    return np.asarray(lam, dtype=np.float64), np.asarray(mu, np.float64)


def make_workload(
    n_requests: int,
    ps: list[int],
    refine: int,
    base_tol: float,
    material_field: str | None = None,
):
    """A deterministic mixed workload: alternating material contrasts,
    traction directions/magnitudes and tolerances across ``ps``; with
    ``material_field`` set, attribute dicts are replaced by per-element
    coefficient fields from :func:`make_material_field`."""
    from repro.fem.mesh import beam_hex
    from repro.serve.elasticity_service import SolveRequest

    reqs = []
    for i in range(n_requests):
        p = ps[i % len(ps)]
        if material_field is None:
            stiff = 50.0 + 10.0 * (i % 3)
            soft = 1.0 + 0.5 * (i % 2)
            materials = {1: (stiff, stiff), 2: (soft, soft)}
        else:
            materials = make_material_field(
                material_field, beam_hex(), refine, i
            )
        tz = -1e-2 * (1.0 + 0.25 * (i % 4))
        ty = 2e-3 if i % 2 else 0.0
        reqs.append(
            SolveRequest(
                p=p,
                refine=refine,
                materials=materials,
                traction=(0.0, ty, tz),
                rel_tol=base_tol if i % 2 else base_tol * 1e-2,
            )
        )
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--p", type=int, nargs="+", default=[2])
    ap.add_argument("--refine", type=int, default=1)
    ap.add_argument("--rel-tol", type=float, default=1e-6)
    ap.add_argument("--assembly", default="paop")
    ap.add_argument("--pallas-lane", default="auto",
                    choices=["auto", "compiled", "interpret"],
                    help="Pallas kernel lane for paop_pallas assembly: "
                         "compiled (native lowering) with automatic "
                         "interpret fallback on backends that cannot "
                         "lower Pallas (the service reports the lane "
                         "that actually ran)")
    ap.add_argument("--precision", default="f64",
                    choices=["f64", "f32", "mixed", "mixed-bf16"],
                    help="service-default precision policy (requests may "
                         "still name their own): f64, f32 (uniform), or "
                         "mixed / mixed-bf16 (f64 outer Krylov over a "
                         "reduced-precision V-cycle).  Reduced policies "
                         "auto-fall-back stagnated rows to f64 — the "
                         "report's prec column shows the policy that "
                         "produced each answer, * marks a fallback")
    ap.add_argument("--repeat", type=int, default=1,
                    help="re-run the workload to demonstrate cache hits")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (slot refill + bucketed "
                         "padding) instead of generational")
    ap.add_argument("--chunk-iters", type=int, default=8,
                    help="PCG iterations per continuous chunk (fixed "
                         "policy) / no-history fallback (adaptive)")
    ap.add_argument("--chunk-policy", default="fixed",
                    choices=["fixed", "adaptive", "shard-adaptive"],
                    help="continuous chunk scheduling: fixed chunk "
                         "length, retire-cadence adaptive, or per-device "
                         "cadence + shard-balanced refill placement "
                         "(never changes numerics)")
    ap.add_argument("--min-chunk", type=int, default=None,
                    help="adaptive policies: chunk length lower clamp")
    ap.add_argument("--max-chunk", type=int, default=None,
                    help="adaptive policies: chunk length upper clamp "
                         "(default 4 * chunk-iters)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the scenario axis over N devices (forces "
                         "N virtual host devices on CPU)")
    ap.add_argument("--material-field", default=None,
                    metavar="{graded,checkerboard,lognormal[:seed]}",
                    help="heterogeneous per-element (lam_e, mu_e) fields "
                         "instead of attribute dicts")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the service metrics registry as a "
                         "Prometheus text dump (.prom/.txt) or JSON "
                         "snapshot (.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request/chunk spans (device-fenced) and "
                         "write a Chrome trace_event file — open it at "
                         "https://ui.perfetto.dev")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="also write the spans as a JSON-lines event log")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="continuous mode: checkpoint the full serving "
                         "state (in-flight BpcgState, queue, tickets) "
                         "into DIR at step boundaries")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    metavar="N", help="steps between checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest intact checkpoint from "
                         "--checkpoint-dir instead of submitting a "
                         "fresh workload (falls back to a fresh "
                         "workload when DIR has no usable checkpoint)")
    ap.add_argument("--watchdog-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="arm a step hang detector: steps exceeding "
                         "this raise the watchdog_fires counter and "
                         "emit a watchdog_fire span")
    ap.add_argument("--report-out", default=None, metavar="PATH",
                    help="write one JSON line per report (ticket, "
                         "iterations, converged, rel_norm, precision, "
                         "sha256 of the solution vector) — the "
                         "crash/restore differential suite compares "
                         "these files bitwise")
    ap.add_argument("--kill-after-steps", type=int, default=None,
                    metavar="N", help="SIGKILL this process after N "
                         "locally executed continuous steps (after the "
                         "checkpoint hook) — fault-injection test hook")
    args = ap.parse_args()
    if args.checkpoint_dir and not args.continuous:
        ap.error("--checkpoint-dir requires --continuous (the "
                 "generational path holds no resumable in-flight state)")

    # Env must be set before anything touches the jax backend.
    from repro.distributed.sharding import (
        force_host_device_count,
        scenario_mesh,
    )

    force_host_device_count(args.devices)
    from repro.serve.elasticity_service import ElasticityService

    mesh = None
    if args.devices is not None:
        mesh = scenario_mesh(args.devices)
        print(f"scenario mesh: {mesh.devices.size} devices "
              f"({jax.device_count()} visible)")

    spans = None
    if args.trace_out or args.events_out:
        from repro.obs import SpanRecorder

        spans = SpanRecorder()
    service = ElasticityService(
        max_batch=args.max_batch, assembly=args.assembly,
        pallas_lane=args.pallas_lane, precision=args.precision,
        chunk_iters=args.chunk_iters, chunk_policy=args.chunk_policy,
        min_chunk=args.min_chunk, max_chunk=args.max_chunk, mesh=mesh,
        spans=spans,
    )
    if args.assembly == "paop_pallas":
        print(f"pallas lane: {service.pallas_lane} "
              f"(requested {args.pallas_lane})")
    recovery = None
    if args.checkpoint_dir:
        from repro.serve.recovery import ServiceRecovery

        recovery = ServiceRecovery(
            service, args.checkpoint_dir, every=args.checkpoint_every
        )
    if args.watchdog_timeout is not None:
        service.attach_watchdog(args.watchdog_timeout)
    resumed = False
    if recovery is not None and args.resume:
        resumed = recovery.restore()
        if resumed:
            print(
                f"resumed from checkpoint step {service._step_index} "
                f"({len(service._flights)} flight(s), "
                f"{len(service._queue)} queued) in {args.checkpoint_dir}"
            )
        else:
            print(f"no usable checkpoint in {args.checkpoint_dir}; "
                  f"starting fresh")
    all_reports = []
    for round_i in range(args.repeat):
        t0 = time.perf_counter()
        if args.continuous:
            # Explicit step loop so checkpoints land at every step
            # boundary and a kill can strike between them.  A resumed
            # round 0 submits nothing: the checkpoint carries the whole
            # workload (flights + queue + any undrained reports).
            if not (resumed and round_i == 0):
                reqs = make_workload(
                    args.n_requests, args.p, args.refine, args.rel_tol,
                    material_field=args.material_field,
                )
                if args.report_out:
                    reqs = [
                        dataclasses.replace(r, keep_solution=True)
                        for r in reqs
                    ]
                for r in reqs:
                    service.submit(r)
            local_steps = 0
            while not service.idle():
                service.step()
                if recovery is not None:
                    recovery.maybe_checkpoint()
                local_steps += 1
                if (
                    args.kill_after_steps is not None
                    and local_steps >= args.kill_after_steps
                ):
                    print(
                        f"kill-after-steps: SIGKILL after local step "
                        f"{local_steps}",
                        flush=True,
                    )
                    os.kill(os.getpid(), signal.SIGKILL)
            reports = service.drain()
        else:
            reqs = make_workload(
                args.n_requests, args.p, args.refine, args.rel_tol,
                material_field=args.material_field,
            )
            reports = service.solve(reqs)
        all_reports.extend(reports)
        dt = time.perf_counter() - t0
        # Throughput counts REAL requests only — padding rows (bucket or
        # device alignment) ride in padded_rows and are excluded.
        print(
            f"-- round {round_i}: {len(reports)} scenarios in {dt:.2f}s "
            f"({len(reports) / dt:.2f} scenarios/s)"
        )
        print(
            f"{'i':>3} {'key':16s} {'prec':>7} {'ndof':>7} {'iters':>5} "
            f"{'conv':>5} {'rel_norm':>9} {'hit':>4} {'rows':>7} "
            f"{'setup(s)':>8} {'solve(s)':>8}"
        )
        for i, rep in enumerate(reports):
            p, refine, shape = rep.key[:3]
            short_key = f"p{p}/r{refine}/{'x'.join(map(str, shape))}"
            rows = f"{rep.batch_size}/{rep.padded_rows}"
            prec = rep.precision + ("*" if rep.fallback else "")
            print(
                f"{i:>3} {short_key:16s} {prec:>7} {rep.ndof:>7} "
                f"{rep.iterations:>5} {str(rep.converged):>5} "
                f"{rep.final_rel_norm:>9.2e} {str(rep.cache_hit):>4} "
                f"{rows:>7} {rep.t_setup:>8.3f} {rep.t_solve:>8.3f}"
            )
    print(f"service stats: {service.stats}")
    if recovery is not None:
        print(f"recovery: {recovery.summary()}")
    if args.report_out:
        import hashlib
        import json

        import numpy as np

        with open(args.report_out, "w") as f:
            for rep in all_reports:
                x_hash = (
                    None
                    if rep.x is None
                    else hashlib.sha256(
                        np.ascontiguousarray(rep.x).tobytes()
                    ).hexdigest()
                )
                f.write(json.dumps({
                    "ticket": rep.ticket,
                    "iterations": int(rep.iterations),
                    "converged": bool(rep.converged),
                    "final_rel_norm": float(rep.final_rel_norm),
                    "precision": rep.precision,
                    "fallback": bool(rep.fallback),
                    "born_converged": bool(rep.born_converged),
                    "x_sha256": x_hash,
                }) + "\n")
        print(f"reports -> {args.report_out}")
    if args.continuous:
        # Scheduler outcome of the chosen --chunk-policy: how many
        # chunks were dispatched, their mean chosen length, and the
        # slot-iterations near-converged rows idled inside chunks.
        s = service.trace.summary()
        print(
            f"scheduler[{service.chunk_policy.name}]: "
            f"chunks={s['chunks']} mean_chunk={s['mean_chunk']:.2f} "
            f"wasted_iters={s['wasted_iters']} refills={s['refills']}"
        )
    lat = service.latency_summary()
    if lat:
        print(
            f"latency: p50={lat['p50']:.3f}s p90={lat['p90']:.3f}s "
            f"p99={lat['p99']:.3f}s mean={lat['mean']:.3f}s "
            f"(n={int(lat['count'])})"
        )
    if args.metrics_out:
        if args.metrics_out.endswith(".json"):
            with open(args.metrics_out, "w") as f:
                f.write(service.registry.to_json(indent=2))
        else:
            with open(args.metrics_out, "w") as f:
                f.write(service.registry.to_prometheus_text())
        print(f"metrics -> {args.metrics_out}")
    if spans is not None:
        if args.trace_out:
            spans.to_chrome_trace(args.trace_out)
            print(
                f"trace -> {args.trace_out} "
                f"({spans.count()} spans; open at https://ui.perfetto.dev)"
            )
        if args.events_out:
            spans.to_jsonl(args.events_out)
            print(f"events -> {args.events_out}")


if __name__ == "__main__":
    main()
