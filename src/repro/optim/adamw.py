"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine learning-rate schedule.

Implemented directly on pytrees (no optax dependency in this offline
container).  Moments are stored in float32 regardless of parameter dtype
(bf16 training needs f32 first/second moments); the moment pytrees
mirror the parameter tree exactly, so they inherit the parameter
sharding under pjit — ZeRO-style optimizer-state sharding falls out of
the same PartitionSpecs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2), the usual
        # exemption for norms/biases.
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
