"""End-to-end driver #3: the batched multi-scenario solve service.

Submits a mixed batch of parameterized beam scenarios (two material
sets, two tractions, two tolerances) to the ElasticityService, which
solves all of them in ONE compiled batched GMG-PCG program, then
re-submits the same key to show the hierarchy/program cache making the
second round's setup free.  One scenario is cross-checked against the
sequential solve_beam driver.  Round 3 drives the *continuous*
engine: requests are submitted while earlier ones are mid-flight,
converged rows retire immediately and their slots are refilled.
Round 4 goes heterogeneous: per-element ``(lam_e, mu_e)`` coefficient
fields — a piecewise-constant array that must reproduce its
attribute-dict twin bit-for-bit, plus a graded field no dict can
express — batched together with dict requests in the same programs.

    PYTHONPATH=src python examples/elasticity_service.py
"""

import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core.geometry import material_fields  # noqa: E402
from repro.fem.mesh import beam_hex  # noqa: E402
from repro.launch.solve import solve_beam  # noqa: E402
from repro.serve.elasticity_service import (  # noqa: E402
    ElasticityService,
    SolveRequest,
)


def main():
    service = ElasticityService(max_batch=8)
    requests = [
        SolveRequest(
            p=2,
            refine=1,
            materials={1: (50.0, 50.0), 2: (1.0, 1.0)}
            if i % 2 == 0
            else {1: (80.0, 60.0), 2: (2.0, 1.0)},
            traction=(0.0, 0.0, -1e-2) if i < 4 else (0.0, 5e-3, -5e-3),
            rel_tol=1e-8 if i % 4 < 2 else 1e-10,
            keep_solution=(i == 0),
        )
        for i in range(8)
    ]

    t0 = time.perf_counter()
    reports = service.solve(requests)
    dt1 = time.perf_counter() - t0
    print(f"round 1: 8 scenarios in {dt1:.2f}s "
          f"(setup {reports[0].t_setup:.2f}s + compile on first solve)")
    for i, r in enumerate(reports):
        print(f"  req {i}: iters={r.iterations:3d} converged={r.converged} "
              f"rel={r.final_rel_norm:.2e} cache_hit={r.cache_hit}")

    t0 = time.perf_counter()
    reports2 = service.solve(requests)
    dt2 = time.perf_counter() - t0
    print(f"round 2 (cached program): 8 scenarios in {dt2:.2f}s "
          f"-> {8 / dt2:.2f} scenarios/s, setup={reports2[0].t_setup:.3f}s")

    # Cross-check scenario 0 against the sequential driver.
    rep_seq = solve_beam(2, 1, assembly="paop", rel_tol=1e-8,
                         keep_solution=True)
    x_b = reports[0].x
    x_s = np.asarray(rep_seq.x)
    rel = np.linalg.norm(x_b - x_s) / np.linalg.norm(x_s)
    print(f"scenario 0 vs sequential solve_beam: rel err {rel:.2e}")
    assert rel < 1e-6

    # Continuous batching: non-blocking submit/step/drain.  The first
    # half of the workload is admitted, iterated in bounded chunks, and
    # as loose-tolerance rows converge their slots are refilled by the
    # requests submitted mid-flight — no generation boundary.
    print("round 3 (continuous): mid-flight submission + slot refill")
    tickets = [service.submit(r) for r in requests[:4]]
    service.step()  # first chunk is already running
    tickets += [service.submit(r) for r in requests[4:]]  # arrive mid-flight
    service.run_until_idle()
    reports3 = service.drain()
    assert len(reports3) == len(tickets)
    for i, r in enumerate(reports3):
        print(f"  req {i}: iters={r.iterations:3d} converged={r.converged} "
              f"retired_at_chunk={r.generation} t={r.t_solve:.2f}s")

    # Round 4: heterogeneous per-element material fields.  materials may
    # be a (lam_e, mu_e) array pair on the fine mesh instead of an
    # attribute dict — here (a) a piecewise-constant field equal to the
    # dict {1: (50, 50), 2: (1, 1)}, which must reproduce the dict
    # request exactly (same compiled program, same folded fields), and
    # (b) a graded stiffness ramp no attribute dict can express, batched
    # right next to it.
    print("round 4 (heterogeneous): per-element (lam_e, mu_e) fields")
    fine_mesh = beam_hex().refined(1)  # refine=1 below
    lam_pc, mu_pc = material_fields(fine_mesh, {1: (50.0, 50.0),
                                                2: (1.0, 1.0)})
    ramp = np.linspace(50.0, 1.0, fine_mesh.nelem)
    het_reqs = [
        SolveRequest(p=2, refine=1,
                     materials={1: (50.0, 50.0), 2: (1.0, 1.0)},
                     rel_tol=1e-8, keep_solution=True),
        SolveRequest(p=2, refine=1, materials=(lam_pc, mu_pc),
                     rel_tol=1e-8, keep_solution=True),
        SolveRequest(p=2, refine=1, materials=(ramp, 0.8 * ramp),
                     rel_tol=1e-8),
    ]
    rep_dict, rep_arr, rep_graded = service.solve_continuous(het_reqs)
    assert rep_arr.iterations == rep_dict.iterations
    assert np.array_equal(rep_arr.x, rep_dict.x)
    print(f"  piecewise-constant array == dict: iters="
          f"{rep_arr.iterations}, solutions bitwise equal")
    print(f"  graded ramp field: iters={rep_graded.iterations} "
          f"converged={rep_graded.converged}")
    print(f"service stats: {service.stats}")


if __name__ == "__main__":
    main()
