"""Quickstart: the paper's optimized matrix-free operator in 30 lines.

Builds the two-material beam at p=4, applies the PAop operator (the
paper's contribution) and solves the benchmark problem with GMG-PCG.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core.operators import ElasticityOperator  # noqa: E402
from repro.fem.mesh import beam_hex  # noqa: E402
from repro.fem.space import H1Space  # noqa: E402
from repro.launch.solve import solve_beam  # noqa: E402


def main():
    # --- the operator: y = A x without ever assembling A -----------------
    space = H1Space(beam_hex().refined(), p=4)
    op = ElasticityOperator(space, assembly="paop")
    x = jnp.ones((space.nscalar, 3))
    y = jax.jit(op.apply)(x)
    print(f"AddMult: {space.ndof} DoFs, |A.1| = {float(jnp.abs(y).max()):.3e} "
          "(rigid translation -> ~0: matrix-free operator is consistent)")

    # --- the solver: GMG-preconditioned CG on the beam benchmark ---------
    rep = solve_beam(p=4, n_h_refine=1, assembly="paop")
    print(
        f"GMG-PCG solve: p={rep.p} ndof={rep.ndof} iters={rep.iterations} "
        f"rel={rep.final_rel_norm:.2e} total={rep.t_total:.2f}s"
    )

    # --- the ablation: every stage of the paper's Table 7 is selectable --
    for assembly in ("pa_baseline", "pa_sumfact", "paop", "paop_pallas"):
        op = ElasticityOperator(space, assembly=assembly)
        yv = jax.jit(op.apply)(x)
        print(f"  {assembly:18s} max|y| = {float(jnp.abs(yv).max()):.6e}")


if __name__ == "__main__":
    main()
