"""End-to-end driver #2: train a ~100M-parameter LM for a few hundred
steps with the full substrate — deterministic sharded data, AdamW +
cosine, remat, checkpoint/restart, watchdog.

By default trains a 12-layer/768-wide xLSTM-family config (~125M params,
the assigned xlstm-125m architecture at full size but fp32 on CPU).  Use
--arch/--reduced for any other assigned architecture.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    # kill it at any point, rerun the same command: resumes bit-identically
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ShapeConfig, get_config, get_reduced
from repro.launch.train import train_loop
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="runs/train_lm_ckpt")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = dataclasses.replace(cfg, dtype="float32")
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    opt = AdamWConfig(lr=3e-4, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    state, hist = train_loop(
        cfg, shape, steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 4, 10), opt=opt, log_every=10,
    )
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({'improved' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
