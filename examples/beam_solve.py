"""End-to-end driver #1 (the paper's workload): solve the two-material
cantilever beam across polynomial degrees and assembly levels, printing
the paper's phase breakdown and the FA/PA/PAop comparison.

    PYTHONPATH=src python examples/beam_solve.py [--p 1 2 4] [--refine 1]
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)

from repro.launch.solve import solve_beam  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--refine", type=int, default=1)
    ap.add_argument("--assemblies", nargs="+",
                    default=["fa", "pa_sumfact_voigt", "paop"])
    args = ap.parse_args()

    print(f"{'p':>2} {'assembly':18s} {'ndof':>8} {'iters':>5} "
          f"{'prec(s)':>8} {'solve(s)':>8} {'total(s)':>8}")
    for p in args.p:
        for assembly in args.assemblies:
            rep = solve_beam(p, n_h_refine=args.refine, assembly=assembly)
            assert rep.final_rel_norm < 1e-6
            print(
                f"{rep.p:>2} {rep.assembly:18s} {rep.ndof:>8} "
                f"{rep.iterations:>5} {rep.t_precond:>8.2f} "
                f"{rep.t_solve:>8.2f} {rep.t_total:>8.2f}"
            )


if __name__ == "__main__":
    main()
