"""End-to-end driver #3: batched serving with continuous batching.

Prefill + jitted single-token decode over a queue of requests (more
requests than engine slots, exercising generational refill), greedy and
sampled, across three model families (attention / SSM-hybrid / MoE).

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import time

import numpy as np

from repro.configs.base import get_reduced
from repro.serve.engine import Request, ServeEngine


def run_family(arch: str, n_requests: int = 6):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    eng = ServeEngine(cfg, max_len=128, max_batch=4)
    rng = np.random.default_rng(0)
    shape = (12,) if not cfg.n_codebooks else (12, cfg.n_codebooks)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, shape).astype(np.int32),
                max_new_tokens=8, temperature=0.0 if i % 2 else 0.8)
        for i in range(n_requests)
    ]
    t0 = time.perf_counter()
    eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"{arch:16s} {n_requests} reqs, {total} tokens, {dt:6.2f}s "
          f"({total/dt:6.1f} tok/s)")


def main():
    for arch in ("qwen3-1.7b", "zamba2-2.7b", "mixtral-8x7b"):
        run_family(arch)


if __name__ == "__main__":
    main()
