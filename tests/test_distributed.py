"""Distribution utilities: sharding rules, compression, elastic remesh.

These run on the single real CPU device (spec-level checks, no SPMD
compile); the pipeline-parallel test uses the interpreter-friendly
jax.shard_map path only if >1 device is available, else it validates the
schedule math.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.distributed.compression import (
    int8_compress,
    int8_decompress,
    make_error_feedback_transform,
    topk_compress,
)
from repro.distributed.pipeline import bubble_fraction, split_stages
from repro.distributed.sharding import _spec_for, act_pspec, param_pspecs
from repro.models.transformer import init_params

LM_ARCHS = [a for a in ARCH_IDS if a != "elasticity"]

# the production mesh axis sizes (dry-run meshes), for divisibility checks
MESH_SINGLE = {"data": 16, "model": 16}
MESH_MULTI = {"pod": 2, "data": 16, "model": 16}


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("mesh_shape", [MESH_SINGLE, MESH_MULTI])
def test_param_specs_divide_evenly(arch, mesh_shape):
    """Every sharded dim of every FULL-config parameter divides its mesh
    axes — the precondition for pjit argument shardings."""
    cfg = get_config(arch)
    pshape = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
    )
    specs = param_pspecs(pshape, _FakeMesh(mesh_shape))
    flat_p = jax.tree.leaves(pshape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = 0
    for leaf, spec in zip(flat_p, flat_s):
        for i, axes in enumerate(spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            total = int(np.prod([mesh_shape[a] for a in axes]))
            assert leaf.shape[i] % total == 0, (arch, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0  # the rules actually fired


def test_large_tensors_are_fully_sharded():
    """Every parameter above 8M elements must shard over BOTH data and
    model axes (FSDP+TP) — otherwise 32B-param states can't fit."""
    cfg = get_config("qwen3_32b")
    pshape = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    specs = param_pspecs(pshape, _FakeMesh(MESH_SINGLE))
    import jax.tree_util as jtu

    for (kp, leaf), spec in zip(
        jtu.tree_flatten_with_path(pshape)[0],
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        if int(np.prod(leaf.shape)) < 8 * 2**20:
            continue
        flat_axes = [a for part in spec if part for a in
                     ((part,) if isinstance(part, str) else part)]
        assert "model" in flat_axes and "data" in flat_axes, (
            jtu.keystr(kp), leaf.shape, spec)


def test_act_pspec():
    assert act_pspec(("data", "model")) == P(("data",), "model", None)
    assert act_pspec(("pod", "data", "model")) == P(
        ("pod", "data"), "model", None)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bounded():
    g = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)))
    q, scale = int8_compress(g)
    back = int8_decompress(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-6


def test_topk_keeps_largest():
    g = jnp.asarray(np.arange(100, dtype=np.float64).reshape(10, 10))
    out, mask = topk_compress(g, frac=0.1)
    assert int(mask.sum()) == 10
    assert float(out.max()) == 99.0
    assert float(out[0, 0]) == 0.0


def test_error_feedback_telescopes():
    """Sum of compressed updates approaches sum of true gradients (the
    error-feedback residual telescopes)."""
    init_fn, tfm = make_error_feedback_transform("int8")
    rng = np.random.default_rng(1)
    g_true = [
        {"w": jnp.asarray(rng.standard_normal((16, 16)) * 0.01)}
        for _ in range(50)
    ]
    res = init_fn(g_true[0])
    acc_comp = jnp.zeros((16, 16))
    acc_true = jnp.zeros((16, 16))
    for g in g_true:
        comp, res = tfm(g, res)
        acc_comp += comp["w"]
        acc_true += g["w"]
    # relative error of accumulated sum far below single-step quant error
    rel = float(jnp.linalg.norm(acc_comp - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.02, rel


# ---------------------------------------------------------------------------
# pipeline / elastic
# ---------------------------------------------------------------------------
def test_split_stages_shapes():
    params = {"w": jnp.zeros((8, 3, 3))}
    sp = split_stages(params, 4)
    assert sp["w"].shape == (4, 2, 3, 3)


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)


def test_elastic_remesh_drops_stragglers():
    from repro.distributed.elastic import elastic_remesh, simulate_failures

    devs = list(range(64))  # fake device handles
    alive = simulate_failures(devs, 3)  # 61 left
    mesh = elastic_remesh(alive, model_parallel=16)
    assert mesh.shape["model"] == 16
    assert mesh.shape["data"] == 3  # 48 devices used, 13 dropped
    assert mesh.size == 48


def test_elastic_remesh_shrinks_tp_last():
    from repro.distributed.elastic import elastic_remesh

    mesh = elastic_remesh(list(range(8)), model_parallel=16)
    assert mesh.shape["model"] == 8
    assert mesh.shape["data"] == 1


def test_watchdog_fires_and_counts():
    import time

    from repro.distributed.elastic import StepWatchdog

    fired = []
    wd = StepWatchdog(timeout_s=0.05, on_timeout=lambda t: fired.append(t))
    with wd.step():
        time.sleep(0.12)
    assert wd.timeouts == 1 and len(fired) == 1
    with wd.step():
        pass
    assert wd.timeouts == 1
    assert wd.slowest > 0.1


def test_elastic_scenario_mesh_over_survivors():
    """The serving-side remesh: a 1-D scenario mesh over whatever
    devices survive — any count is valid (no architecture-bound axis),
    so losing devices never drops survivors the way the (data, model)
    training remesh must."""
    import jax

    from repro.distributed.elastic import (
        elastic_scenario_mesh,
        simulate_failures,
    )

    mesh = elastic_scenario_mesh()
    assert mesh.devices.size == jax.device_count()
    assert mesh.axis_names == ("scenario",)
    if jax.device_count() > 1:
        alive = simulate_failures(jax.devices(), 1)
        shrunk = elastic_scenario_mesh(alive)
        assert shrunk.devices.size == jax.device_count() - 1
    with pytest.raises(ValueError, match="every device"):
        simulate_failures(jax.devices(), jax.device_count())


def test_scenario_layout_mismatches_flags_wrong_sharding():
    """The restore-time layout assert: clean on a correctly pinned tree,
    names the offending leaf on an unsharded one, and is a no-op for a
    None mesh (single-device service)."""
    import jax
    import jax.numpy as jnp

    from repro.distributed.sharding import (
        device_put_scenario,
        scenario_layout_mismatches,
        scenario_mesh,
    )

    n = jax.device_count()
    mesh = scenario_mesh(n)
    tree = {
        "x": jnp.zeros((2 * n, 3)),
        "iters": jnp.zeros((2 * n,), jnp.int32),
        "scalar": jnp.asarray(1.0),  # rank-0: exempt from row sharding
    }
    pinned = device_put_scenario(tree, mesh)
    assert scenario_layout_mismatches(pinned, mesh) == []
    assert scenario_layout_mismatches(tree, None) == []
    if n > 1:
        bad = dict(pinned, x=np.zeros((2 * n, 3)))  # host leaf: unpinned
        flagged = scenario_layout_mismatches(bad, mesh)
        assert len(flagged) == 1 and "'x'" in flagged[0]
