"""Solver-stack tests: PCG semantics, Chebyshev smoother, GMG convergence,
assembly-level invariance of iteration counts (the paper's experimental
contract: FA+GMG / PA+GMG / PAop+GMG differ only in the operator)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operators import ElasticityOperator
from repro.fem.bc import eliminate_rhs
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space
from repro.launch.solve import solve_beam
from repro.solvers.cg import pcg
from repro.solvers.gmg import build_hierarchy, p_chain


def test_p_chain():
    assert p_chain(1) == [1]
    assert p_chain(4) == [1, 2, 4]
    assert p_chain(6) == [1, 2, 4, 6]
    assert p_chain(8) == [1, 2, 4, 8]


def test_pcg_matches_dense_solve():
    """PCG on a small SPD system reproduces the direct solve."""
    rng = np.random.default_rng(0)
    n = 40
    M = rng.standard_normal((n, n))
    A = M @ M.T + n * np.eye(n)
    b = rng.standard_normal((n, 1))
    res = pcg(lambda x: jnp.asarray(A) @ x, jnp.asarray(b), rel_tol=1e-12,
              maxiter=200)
    x_ref = np.linalg.solve(A, b)
    np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=1e-8)


def test_pcg_zero_rhs_converges_immediately():
    """b == 0 (nom0 == 0) must exit with x = 0, converged, 0 iterations,
    and no NaNs — also the contract padded batch rows rely on."""
    b = jnp.zeros((7, 3))
    res = pcg(lambda x: 2.0 * x, b, rel_tol=1e-8)
    assert int(res.iterations) == 0
    assert bool(res.converged)
    np.testing.assert_array_equal(np.asarray(res.x), 0.0)
    assert not np.isnan(np.asarray(res.x)).any()
    assert float(res.final_norm) == 0.0
    # identical semantics under jit
    res_j = jax.jit(lambda bv: pcg(lambda x: 2.0 * x, bv, rel_tol=1e-8))(b)
    assert bool(res_j.converged)
    assert not np.isnan(np.asarray(res_j.x)).any()


def test_pcg_x0_already_solved():
    """An x0 that already solves the system is another nom0 == 0 path."""
    rng = np.random.default_rng(7)
    m = rng.standard_normal((12, 12))
    a = jnp.asarray(m @ m.T + 12 * np.eye(12))
    x_true = jnp.asarray(rng.standard_normal(12))
    res = pcg(lambda x: a @ x, a @ x_true, x0=x_true, rel_tol=1e-8)
    assert int(res.iterations) == 0
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true))


@pytest.mark.parametrize("p", [1, 2, 4])
def test_gmg_pcg_converges(p):
    rep = solve_beam(p, n_h_refine=1, assembly="paop", rel_tol=1e-6)
    assert rep.final_rel_norm < 1e-6
    assert rep.iterations < 60  # GMG: order-independent-ish counts


def test_iteration_count_invariant_across_assemblies():
    """Same GMG, same problem -> identical iteration counts for FA/PA/PAop
    (paper Sec. 5.3: 'the iteration count is identical across the three
    variants at each polynomial degree')."""
    iters = {}
    for a in ("fa", "pa_baseline", "paop"):
        rep = solve_beam(2, n_h_refine=1, assembly=a, rel_tol=1e-6)
        iters[a] = rep.iterations
        assert rep.final_rel_norm < 1e-6
    assert len(set(iters.values())) == 1, iters


def test_solution_agrees_across_assemblies():
    xs = {}
    for a in ("fa", "paop"):
        rep = solve_beam(2, n_h_refine=1, assembly=a, rel_tol=1e-10,
                         keep_solution=True)
        xs[a] = np.asarray(rep.x)
    np.testing.assert_allclose(xs["paop"], xs["fa"], rtol=1e-6, atol=1e-12)


def test_beam_bends_downward():
    """Physics sanity: downward traction on the free end -> negative mean
    z-displacement, largest at the tip (x = L)."""
    rep = solve_beam(2, n_h_refine=1, assembly="paop", rel_tol=1e-8,
                     keep_solution=True)
    space = H1Space(beam_hex().refined(), 2)
    x = np.asarray(rep.x).reshape(space.nscalar, 3)
    coords = space.node_coords()
    uz = x[:, 2]
    assert uz.mean() < 0
    tip = coords[:, 0] > coords[:, 0].max() - 1e-9
    root = coords[:, 0] < 1e-9
    assert abs(uz[tip].mean()) > 10 * abs(uz[root].mean())


def test_chebyshev_smoother_reduces_residual():
    mesh = beam_hex().refined()
    space = H1Space(mesh, 2)
    op = ElasticityOperator(space, assembly="paop")
    cop = op.constrained()
    from repro.solvers.chebyshev import ChebyshevSmoother

    sm = ChebyshevSmoother.setup(cop, cop.diagonal(), shape=(space.nscalar, 3),
                                 dtype=jnp.float64)
    b = jnp.asarray(np.random.default_rng(1).standard_normal((space.nscalar, 3)))
    b = jnp.where(jnp.asarray(op.ess_mask), 0.0, b)
    x = sm(b)
    r = b - cop(x)
    assert float(jnp.linalg.norm(r)) < float(jnp.linalg.norm(b))
