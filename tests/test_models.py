"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED same-family config, run one forward + one train step on CPU,
assert output shapes and no NaNs.  The FULL configs are exercised only
via the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_config, get_reduced
from repro.data.pipeline import make_batch
from repro.models.transformer import (
    forward,
    init_params,
    loss_fn,
    param_count,
)
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import make_train_step, train_state_init

LM_ARCHS = [a for a in ARCH_IDS if a != "elasticity"]
SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)


def _cfg(arch):
    cfg = get_reduced(arch)
    return dataclasses.replace(
        cfg, dtype="float32", chunk_size=min(cfg.chunk_size, 16)
    )


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMOKE_SHAPE, 0).items()}
    hidden, aux = forward(params, batch, cfg, remat=False)
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_no_nans(arch):
    cfg = _cfg(arch)
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMOKE_SHAPE, 0).items()}
    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda x, y: float(jnp.abs(x - y).max()), state.params, state2.params
        ),
    )
    assert delta > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the published numbers (spot checks)."""
    cfg = get_config(arch)
    expected = {
        "qwen15_32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen3_17b": (28, 2048, 16, 8, 6144, 151936),
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "zamba2_27b": (54, 2560, 32, 32, 10240, 32000),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_param_counts_plausible():
    """Full-config parameter counts are within 20% of the marketing size."""
    approx = {
        "qwen15_32b": 32e9,
        "qwen3_32b": 32e9,
        "granite_8b": 8e9,
        "mixtral_8x7b": 46.7e9,
    }
    for arch, n in approx.items():
        cfg = get_config(arch)
        assert abs(cfg.n_params() - n) / n < 0.25, (arch, cfg.n_params())


def test_moe_active_params():
    cfg = get_config("mixtral_8x7b")
    # ~12.9B active for top-2 of 8 experts
    act = cfg.n_active_params()
    assert 10e9 < act < 16e9
    assert act < cfg.n_params()


def test_qkv_bias_only_where_specified():
    assert get_config("qwen15_32b").qkv_bias
    assert get_config("qwen2_vl_7b").qkv_bias
    assert not get_config("qwen3_32b").qkv_bias


def test_long_500k_skip_rule():
    from repro.launch.cells import skip_reason

    # full attention: skipped
    assert skip_reason("qwen3_32b", "long_500k") is not None
    assert skip_reason("musicgen_medium", "long_500k") is not None
    # ssm / hybrid / swa: run
    assert skip_reason("xlstm_125m", "long_500k") is None
    assert skip_reason("zamba2_27b", "long_500k") is None
    assert skip_reason("mixtral_8x7b", "long_500k") is None
    # other shapes never skip
    assert skip_reason("qwen3_32b", "train_4k") is None


def test_cell_matrix_size():
    from repro.launch.cells import cell_ids

    lm = [c for c in cell_ids(include_elasticity=False)]
    # 10 archs x 4 shapes - 7 skipped long_500k cells = 33 runnable,
    # but ALL 40 are assigned; skipped ones documented in DESIGN.md.
    assert len(lm) == 33
    fem = [c for c in cell_ids() if c[0] == "elasticity"]
    assert len(fem) == 3
