"""Checkpoint manager: roundtrip, atomicity, corruption fallback, GC."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(x=1.0):
    return {
        "params": {"w": jnp.full((4, 3), x), "b": jnp.zeros((3,))},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state(2.5)
    mgr.save(10, st, extra={"note": "hi"})
    restored, extra = mgr.restore(_state(0.0))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    assert extra == {"note": "hi"}
    assert mgr.latest() == 10


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.available_steps() == [3, 4]


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state())
    # simulate a crash mid-write: directory without manifest
    broken = tmp_path / "step_000000009"
    broken.mkdir()
    (broken / "leaf_00000.npy").write_bytes(b"junk")
    assert mgr.latest() == 5  # the manifest-less dir is invisible


def test_corrupt_checkpoint_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _state(1.0))
    mgr.save(2, _state(2.0))
    # corrupt the newest checkpoint's first leaf
    cdir = tmp_path / "step_000000002"
    leaf = cdir / "leaf_00000.npy"
    arr = np.load(leaf)
    arr = arr + 999
    np.save(leaf, arr)
    out = mgr.restore_latest(_state(0.0))
    assert out is not None
    restored, _, step = out
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 3), 1.0))


def test_restore_casts_dtype(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2, 2), jnp.float32)})
    like = {"w": jnp.zeros((2, 2), jnp.bfloat16)}
    restored, _ = mgr.restore(like)
    assert restored["w"].dtype == np.dtype("bfloat16") or str(
        restored["w"].dtype) == "bfloat16"


def test_stale_tmp_dirs_cleaned(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    stale = tmp_path / "step_000000003.tmp-9999"
    stale.mkdir()
    mgr.save(4, _state())
    assert not stale.exists()
