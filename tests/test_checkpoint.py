"""Checkpoint manager under the SERVING-state contract: flat
``{name: array}`` snapshots (per-flight BpcgState/prep leaves plus one
pickled host-metadata blob) restored WITHOUT a ``like`` tree through
``restore_items``/``restore_latest_items`` — what
:class:`repro.serve.recovery.ServiceRecovery` rides on — plus the
manager invariants every consumer relies on: atomic rename (torn
staging dirs invisible), per-leaf CRC fallback, keep-k GC, stale tmp
cleanup, and the solver-level host (de)serialization being bitwise.
The legacy pytree path (``restore(like)`` with dtype casting) keeps a
regression test; the fault-injection suite (tests/test_faults.py)
exercises the same surfaces under scripted crashes."""

import pickle

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _items(x=1.0):
    """A serving-style flat snapshot: solver array leaves + one pickled
    host blob (exactly the layout ServiceRecovery writes)."""
    blob = {"queue": [(0, "req")], "next_ticket": 3, "scale": x}
    return {
        "flight0/state/x": np.full((4, 3), x),
        "flight0/state/iters": np.asarray([2, 5, 0, 1], np.int32),
        "flight0/state/active": np.asarray([True, False, True, False]),
        "flight0/prep/chol": np.full((4, 6), 0.5 * x),
        "host": np.frombuffer(pickle.dumps(blob), dtype=np.uint8),
    }


def _assert_items_equal(got: dict, want: dict) -> None:
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
        assert got[k].dtype == want[k].dtype, k


def test_save_restore_items_roundtrip(tmp_path):
    """Flat serving snapshots round-trip bitwise — arrays, dtypes, and
    the pickled blob — without any ``like`` tree."""
    mgr = CheckpointManager(str(tmp_path))
    items = _items(2.5)
    mgr.save(10, items, extra={"format": 1, "devices": 1})
    got, extra = mgr.restore_items()
    _assert_items_equal(got, items)
    assert extra == {"format": 1, "devices": 1}
    blob = pickle.loads(got["host"].tobytes())
    assert blob["next_ticket"] == 3 and blob["scale"] == 2.5
    assert mgr.latest() == 10


def test_restore_items_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore_items()
    assert mgr.restore_latest_items() is None


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _items(float(s)))
    assert mgr.available_steps() == [3, 4]


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _items())
    # a crash mid-write leaves a directory without a manifest
    broken = tmp_path / "step_000000009"
    broken.mkdir()
    (broken / "leaf_00000.npy").write_bytes(b"junk")
    assert mgr.latest() == 5  # the manifest-less dir is invisible
    _, _, step = mgr.restore_latest_items()
    assert step == 5


def test_corrupt_checkpoint_falls_back(tmp_path):
    """A CRC-failing newest checkpoint is skipped: restore_latest_items
    lands on the newest INTACT step."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _items(1.0))
    mgr.save(2, _items(2.0))
    cdir = tmp_path / "step_000000002"
    leaf = cdir / "leaf_00000.npy"
    np.save(leaf, np.load(leaf) + 999)
    with pytest.raises(IOError, match="crc"):
        mgr.restore_items(2)
    got, _, step = mgr.restore_latest_items()
    assert step == 1
    _assert_items_equal(got, _items(1.0))


def test_restore_casts_dtype(tmp_path):
    """Legacy training-pytree path: restore-with-``like`` casts to the
    target leaf dtype (the serving path never casts — state_from_host
    re-establishes dtypes through the solver's precision policy)."""
    import jax.numpy as jnp

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2, 2), jnp.float32)})
    like = {"w": jnp.zeros((2, 2), jnp.bfloat16)}
    restored, _ = mgr.restore(like)
    assert str(restored["w"].dtype) == "bfloat16"


def test_stale_tmp_dirs_cleaned(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    stale = tmp_path / "step_000000003.tmp-9999"
    stale.mkdir()
    mgr.save(4, _items())
    assert not stale.exists()


def test_solver_state_host_roundtrip_bitwise(tmp_path):
    """The serving (de)serialization contract end to end at the solver
    level: a mid-solve BpcgState + prep pytree pushed through
    state_to_host/prep_to_host -> CheckpointManager -> restore_items ->
    state_from_host/prep_from_host restores every field bitwise, and a
    further chunk from the restored state is bitwise the chunk the
    original would have run (the chunk boundary is invisible)."""
    from repro.fem.mesh import beam_hex
    from repro.solvers.batched import BatchedGMGSolver

    solver = BatchedGMGSolver(beam_hex(), 0, 1, maxiter=100)
    mats = [{1: (50.0, 50.0), 2: (1.0, 1.0)}, {1: (9.0, 9.0), 2: (1.0, 3.0)}]
    tr = np.array([[0.0, 0.0, -1e-2], [0.0, 1e-3, -2e-2]])
    lam, mu = solver.pack_materials(mats)
    prep = solver.prepare(lam, mu, np.ones(2, bool), solver.empty_prep(2))
    state, _ = solver.run_chunk(
        tr, 1e-10, np.ones(2, bool), solver.empty_state(2), prep, 2,
        do_reset=True,
    )

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(
        1,
        {
            **{f"state/{k}": v for k, v in solver.state_to_host(state).items()},
            **{f"prep/{k}": v for k, v in solver.prep_to_host(prep).items()},
        },
        extra={"format": 1},
    )
    items, _ = mgr.restore_items()
    state2 = solver.state_from_host(
        {k[6:]: v for k, v in items.items() if k.startswith("state/")}
    )
    prep2 = solver.prep_from_host(
        {k[5:]: v for k, v in items.items() if k.startswith("prep/")}
    )
    for name, arr in solver.state_to_host(state).items():
        np.testing.assert_array_equal(
            arr, getattr(state2, name), err_msg=name
        )
        assert np.asarray(getattr(state2, name)).dtype == arr.dtype, name

    nxt, c = solver.run_chunk(
        tr, 1e-10, np.zeros(2, bool), state, prep, 3, do_reset=False
    )
    nxt2, c2 = solver.run_chunk(
        tr, 1e-10, np.zeros(2, bool), state2, prep2, 3, do_reset=False
    )
    np.testing.assert_array_equal(np.asarray(nxt.x), np.asarray(nxt2.x))
    np.testing.assert_array_equal(np.asarray(nxt.iters), np.asarray(nxt2.iters))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))
