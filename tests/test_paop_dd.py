"""Domain-decomposed AddMult (shard_map halo exchange) vs the global
operator.  Runs on however many devices exist (1 on CI = degenerate but
still exercises the block conversion + ppermute schedule)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operators import ElasticityOperator
from repro.launch.mesh import axis_type_kwargs
from repro.core.paop_dd import SlabDecomposition, choose_grid
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space


def _mesh_1d():
    n = len(jax.devices())
    return jax.make_mesh((n,), ("shard",), **axis_type_kwargs(1))


def test_choose_grid():
    assert choose_grid(128, 16, 256) == (16, 16)
    assert choose_grid(16, 2, 8) == (4, 2)
    assert choose_grid(8, 1, 4) == (4, 1)
    with pytest.raises(ValueError):
        choose_grid(3, 3, 7)


@pytest.mark.parametrize("p", [1, 2, 3])
def test_dd_matches_global(p):
    mesh = _mesh_1d()
    m = beam_hex().refined()  # (16, 2, 2)
    space = H1Space(m, p)
    op = ElasticityOperator(space, assembly="paop", dtype=jnp.float64)
    dd = SlabDecomposition(space, mesh, ("shard",), dtype=jnp.float64)
    x = jnp.asarray(np.random.default_rng(p).standard_normal((space.nscalar, 3)))
    y_ref = np.asarray(op.apply(x))
    y_dd = np.asarray(dd.apply(x))
    np.testing.assert_allclose(y_dd, y_ref, rtol=1e-11,
                               atol=1e-12 * np.abs(y_ref).max())


def test_block_roundtrip():
    mesh = _mesh_1d()
    space = H1Space(beam_hex().refined(), 2)
    dd = SlabDecomposition(space, mesh, ("shard",), dtype=jnp.float64)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((space.nscalar, 3)))
    np.testing.assert_array_equal(
        np.asarray(dd.from_blocks(dd.to_blocks(x))), np.asarray(x)
    )


def test_two_material_split_respected():
    """The per-shard quadrature blocks carry the 50:1 material contrast."""
    mesh = _mesh_1d()
    space = H1Space(beam_hex().refined(), 2)
    dd = SlabDecomposition(space, mesh, ("shard",), dtype=jnp.float64)
    lam = np.asarray(dd.lam_blocks)  # (n_shards, lne, Q, Q, Q)
    # per-ELEMENT means divide out the shared quadrature factor; both
    # materials must be present across the union of shards (and the
    # contrast must be exactly 50:1).
    per_elem = lam.reshape(-1, lam.shape[-3] * lam.shape[-2] * lam.shape[-1]).mean(axis=1)
    assert per_elem.max() / per_elem.min() == pytest.approx(50.0, rel=1e-10)
