"""Sharded-vs-single-device differential suite: scenario-axis sharding
must be a pure implementation detail.

Every test compares a `BatchedGMGSolver`/`ElasticityService` running on
a 1/2/4/8-device scenario mesh against the unsharded single-device
path: identical iteration counts, convergence and `born_converged`
flags, and solutions equal to machine precision (the partitioned
program fuses differently, so results are ~1 ulp rather than bitwise).

Device counts come from subsets of ``jax.devices()``: one pytest
process forced to 8 virtual host devices (``REPRO_HOST_DEVICES=8`` —
see conftest) covers meshes of 1, 2, 4 and 8 devices.  Tests needing
more than one device carry the ``multidevice`` marker and auto-skip on
a single-device run; the mesh-of-one cases run everywhere, keeping the
sharded code path exercised in the default lane too.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.distributed.sharding import scenario_mesh, scenario_sharding
from repro.fem.mesh import beam_hex
from repro.serve.elasticity_service import ElasticityService, SolveRequest
from repro.solvers.batched import BatchedGMGSolver, bpcg_result
from tests._hypothesis_compat import given, settings, st

# (coarse_mesh args, n_h_refine, p): p=1 exercises the h-transfer ladder,
# p=2 the p-embedding ladder; both stay small enough to compile the full
# bucket x device matrix on CPU.
DISCRETIZATIONS = {1: (1, 1), 2: (0, 2)}
BUCKETS = (1, 2, 4, 8)
MAXITER = 150


def dev_params():
    return [
        pytest.param(n, marks=pytest.mark.multidevice) if n > 1
        else pytest.param(n)
        for n in (1, 2, 4, 8)
    ]


def _skip_if_too_few(ndev):
    if ndev > jax.device_count():
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")


def scenarios(n: int):
    """Deterministic mixed batch: varied material contrasts, tractions
    and tolerances; row 1 (when present) has a zero traction, so it is
    born converged — the flag must survive sharding."""
    mats, tr, tol = [], [], []
    for i in range(n):
        stiff = 50.0 + 7.0 * (i % 3)
        soft = 1.0 + 0.5 * (i % 2)
        mats.append({1: (stiff, 0.9 * stiff), 2: (soft, soft)})
        if i == 1:
            tr.append((0.0, 0.0, 0.0))
        else:
            tr.append((0.0, 2e-3 * (i % 2), -1e-2 * (1 + 0.2 * (i % 4))))
        tol.append(1e-9 if i % 3 == 0 else 1e-6)
    return mats, np.asarray(tr), np.asarray(tol)


_SOLVERS: dict = {}
_REF_FULL: dict = {}


def _solver(p: int, ndev) -> BatchedGMGSolver:
    """One solver per (p, device count), shared across tests so compiled
    programs are paid for once per session."""
    key = (p, ndev)
    if key not in _SOLVERS:
        refine, p_target = DISCRETIZATIONS[p]
        _SOLVERS[key] = BatchedGMGSolver(
            beam_hex(),
            refine,
            p_target,
            maxiter=MAXITER,
            mesh=None if ndev is None else scenario_mesh(ndev),
        )
    return _SOLVERS[key]


def _ref_full(p: int, bucket: int):
    key = (p, bucket)
    if key not in _REF_FULL:
        mats, tr, tol = scenarios(bucket)
        _REF_FULL[key] = _solver(p, None).solve(mats, tr, tol)
    return _REF_FULL[key]


def assert_results_match(res, ref, context: str):
    np.testing.assert_array_equal(
        np.asarray(res.iterations), np.asarray(ref.iterations),
        err_msg=f"{context}: iteration counts diverged",
    )
    np.testing.assert_array_equal(
        np.asarray(res.converged), np.asarray(ref.converged),
        err_msg=f"{context}: convergence flags diverged",
    )
    scale = float(np.abs(np.asarray(ref.x)).max()) or 1.0
    np.testing.assert_allclose(
        np.asarray(res.x), np.asarray(ref.x), atol=1e-12 * scale, rtol=0,
        err_msg=f"{context}: solutions diverged",
    )
    np.testing.assert_allclose(
        np.asarray(res.final_norm), np.asarray(ref.final_norm),
        rtol=1e-8, atol=1e-300,
        err_msg=f"{context}: final norms diverged",
    )
    np.testing.assert_allclose(
        np.asarray(res.initial_norm), np.asarray(ref.initial_norm),
        rtol=1e-8, atol=1e-300,
        err_msg=f"{context}: initial norms diverged",
    )


# -- solver-level differentials ---------------------------------------------
@pytest.mark.parametrize("ndev", dev_params())
@pytest.mark.parametrize("p", [1, 2])
def test_sharded_full_solve_matches_single_device(p, ndev):
    """solve() on a 1/2/4/8-device mesh reproduces the unsharded result
    for every bucket size, including buckets smaller than the mesh
    (device padding) and non-dividing buckets; born-converged rows keep
    0 iterations."""
    _skip_if_too_few(ndev)
    solver = _solver(p, ndev)
    for bucket in BUCKETS:
        mats, tr, tol = scenarios(bucket)
        res = solver.solve(mats, tr, tol)
        ref = _ref_full(p, bucket)
        assert np.asarray(res.x).shape[0] == bucket  # padding sliced off
        assert_results_match(
            res, ref, f"p={p} bucket={bucket} devices={ndev}"
        )
        if bucket >= 2:  # the zero-traction row is born converged
            assert int(np.asarray(res.iterations)[1]) == 0
            assert float(np.asarray(res.initial_norm)[1]) == 0.0


def _chunked_solve(solver: BatchedGMGSolver, mats, tr, tol, k: int):
    """Drive the resumable step program the way the continuous engine
    does: prepare all rows, reset-chunk, then bounded chunks until no
    row is active.  Returns the first len(mats) rows of the result."""
    mats, tr, tol, s = solver.pad_scenarios(mats, tr, tol)
    n = len(mats)
    lam, mu = solver.pack_materials(mats)
    reset = np.ones((n,), dtype=bool)
    prep = solver.prepare(lam, mu, reset, solver.empty_prep(n))
    state, consumed = solver.run_chunk(
        tr, tol, reset, solver.empty_state(n), prep, k, do_reset=True
    )
    assert consumed.shape == (n,)  # per-row cadence signal rides along
    guard = 0
    while bool(np.asarray(state.active).any()):
        state, _ = solver.run_chunk(
            tr, tol, np.zeros((n,), dtype=bool), state, prep, k
        )
        guard += 1
        assert guard < 500, "chunked solve did not drain"
    res = bpcg_result(state)
    return dataclasses.replace(
        res,
        **{
            f.name: np.asarray(getattr(res, f.name))[:s]
            for f in dataclasses.fields(res)
        },
    )


@pytest.mark.parametrize("ndev", dev_params())
@pytest.mark.parametrize("p", [1, 2])
def test_sharded_chunked_solve_matches_single_device(p, ndev):
    """prepare + run_chunk on a device mesh == the unsharded full solve:
    chunk boundaries and sharding are both invisible to the iteration."""
    _skip_if_too_few(ndev)
    bucket = 4
    mats, tr, tol = scenarios(bucket)
    res = _chunked_solve(_solver(p, ndev), mats, tr, tol, k=3)
    assert_results_match(
        res, _ref_full(p, bucket), f"chunked p={p} devices={ndev}"
    )


@pytest.mark.multidevice
def test_sharded_state_and_prep_are_actually_distributed():
    """The differential tests prove correctness; this proves the point of
    the exercise — state rows and folded element fields really live on
    distinct devices (axis-0 NamedSharding over the scenario mesh)."""
    ndev = min(4, jax.device_count())
    assert ndev > 1
    solver = _solver(1, ndev)
    n = solver.pad_batch(ndev)
    mats, tr, tol = scenarios(n)
    lam, mu = solver.pack_materials(mats)
    reset = np.ones((n,), dtype=bool)
    prep = solver.prepare(lam, mu, reset, solver.empty_prep(n))
    state, _ = solver.run_chunk(
        tr, tol, reset, solver.empty_state(n), prep, 2, do_reset=True
    )
    def assert_sharded(x):
        want = scenario_sharding(solver.mesh, x.ndim)
        assert x.sharding.is_equivalent_to(want, x.ndim), (
            x.sharding, want,
        )
        assert len(x.sharding.device_set) == ndev

    assert_sharded(state.x)
    assert_sharded(state.r)
    for name in ("lam_w", "mu_w"):
        for w in prep[name]:
            assert_sharded(w)
    assert_sharded(prep["chol"])


# -- service-level differentials --------------------------------------------
def service_requests(n: int = 5):
    reqs = []
    for i in range(n):
        stiff = 50.0 + 6.0 * (i % 3)
        reqs.append(
            SolveRequest(
                p=1,
                refine=1,
                materials={1: (stiff, stiff), 2: (1.0 + 0.5 * (i % 2), 1.0)},
                # row 1: zero traction -> born converged, must be
                # reported (not confused with device padding).
                traction=(0.0, 0.0, 0.0) if i == 1
                else (0.0, 1e-3 * (i % 2), -1e-2 * (1 + 0.3 * (i % 3))),
                rel_tol=1e-9 if i % 3 == 0 else 1e-5,
                keep_solution=(i % 2 == 0),
            )
        )
    return reqs


def assert_reports_match(reps, refs, context: str):
    assert len(reps) == len(refs)
    for i, (a, b) in enumerate(zip(reps, refs)):
        ctx = f"{context} request {i}"
        assert a.iterations == b.iterations, ctx
        assert a.converged == b.converged, ctx
        assert a.born_converged == b.born_converged, ctx
        assert a.batch_size == b.batch_size, ctx
        assert a.generation == b.generation, ctx
        assert a.ndof == b.ndof, ctx
        np.testing.assert_allclose(
            a.final_rel_norm, b.final_rel_norm, rtol=1e-8, atol=1e-300,
            err_msg=ctx,
        )
        assert (a.x is None) == (b.x is None), ctx
        if a.x is not None:
            scale = float(np.abs(b.x).max()) or 1.0
            np.testing.assert_allclose(
                a.x, b.x, atol=1e-12 * scale, rtol=0, err_msg=ctx
            )


_SERVICES: dict = {}


def _service(ndev) -> ElasticityService:
    if ndev not in _SERVICES:
        _SERVICES[ndev] = ElasticityService(
            max_batch=4,
            chunk_iters=3,
            maxiter=MAXITER,
            mesh=None if ndev is None else scenario_mesh(ndev),
        )
    return _SERVICES[ndev]


@pytest.mark.parametrize(
    "ndev",
    [pytest.param(1), pytest.param(4, marks=pytest.mark.multidevice)],
)
def test_sharded_service_generational_matches_single_device(ndev):
    """Generational scheduling on a sharded service reproduces the
    single-device reports: iterations, flags, norms, solutions, and the
    generation/batch bookkeeping (device padding is invisible)."""
    _skip_if_too_few(ndev)
    reqs = service_requests()
    refs = _service(None).solve(list(reqs))
    reps = _service(ndev).solve(list(reqs))
    assert_reports_match(reps, refs, f"generational devices={ndev}")
    born = [r.born_converged for r in reps]
    assert born == [False, True, False, False, False]
    for r in reps:
        assert r.padded_rows >= r.batch_size
        assert r.padded_rows % max(ndev, 1) == 0


@pytest.mark.parametrize(
    "ndev",
    [pytest.param(1), pytest.param(4, marks=pytest.mark.multidevice)],
)
def test_sharded_service_continuous_matches_single_device(ndev):
    """Continuous scheduling (retire/refill/re-bucket) on a sharded
    service reproduces the single-device reports — step() reads sharded
    (S,) convergence vectors and per-row state exactly as before."""
    _skip_if_too_few(ndev)
    reqs = service_requests()
    base_ref = dict(_service(None).stats)
    base = dict(_service(ndev).stats)
    refs = _service(None).solve_continuous(list(reqs))
    reps = _service(ndev).solve_continuous(list(reqs))
    assert_reports_match(reps, refs, f"continuous devices={ndev}")
    # Host-side scheduling must be sharding-invariant, not just results:
    # same refill count, and the same number of prepare() calls — the
    # prep-row-reuse short-circuit must keep absorbing padding/refill
    # resets so sharding never adds power iterations/refactorizations.
    # (Deltas — the services are shared across parametrizations.
    # prep_row_copies is NOT compared: device padding and the coarser
    # re-bucket ladder legitimately change how many cheap row copies
    # happen.)
    for k in ("refills", "prep_calls"):
        assert (
            _service(ndev).stats[k] - base[k]
            == _service(None).stats[k] - base_ref[k]
        ), k


# -- retire/refill invariants under sharding (property-based) ---------------
@pytest.mark.multidevice
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 6),
    mat_idx=st.lists(st.integers(0, 2), min_size=6, max_size=6),
    tight=st.lists(st.booleans(), min_size=6, max_size=6),
    zero_row=st.integers(-1, 5),
)
def test_continuous_refill_invariants_under_sharding(
    n, mat_idx, tight, zero_row
):
    """Random workloads whose live-row count is rarely a multiple of the
    device count: the sharded continuous engine must (a) surface exactly
    the submitted tickets — device-padding rows never leak, (b) retire
    every row with the same iterations/flags as the unsharded engine —
    refills reset only their own rows, and (c) short-circuit prep for
    refills whose materials match a prepared row — identical
    prep_calls/prep_row_copies deltas to the unsharded engine."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    vocab = [
        {1: (50.0, 50.0), 2: (1.0, 1.0)},
        {1: (80.0, 60.0), 2: (2.0, 1.0)},
        {1: (9.0, 9.0), 2: (1.0, 3.0)},
    ]
    reqs = [
        SolveRequest(
            p=1,
            refine=1,
            materials=vocab[mat_idx[i]],
            traction=(0.0, 0.0, 0.0) if i == zero_row
            else (0.0, 0.0, -1e-2 * (1 + 0.1 * i)),
            rel_tol=1e-9 if tight[i] else 1e-4,
        )
        for i in range(n)
    ]
    svc_ref, svc = _service(None), _service(2)
    base_ref = dict(svc_ref.stats)
    base = dict(svc.stats)
    tickets_before = svc._next_ticket
    refs = svc_ref.solve_continuous(list(reqs))
    reps = svc.solve_continuous(list(reqs))
    # (a) exactly the submitted tickets surfaced, nothing in flight
    assert len(reps) == n and svc.idle()
    assert svc._next_ticket == tickets_before + n
    assert not svc._completed  # solve_continuous popped exactly ours
    # (b) per-request outcomes identical to the unsharded engine
    assert_reports_match(reps, refs, f"hypothesis n={n}")
    for i, r in enumerate(reps):
        assert r.born_converged == (i == zero_row)
    # (c) the expensive prep path is sharding-invariant: refills whose
    # materials match a prepared row still short-circuit the power
    # iterations, so sharding never adds prepare() calls.  (Cheap row
    # copies and re-buckets legitimately differ: device padding rows
    # and the device-aligned bucket ladder.)
    for k in ("refills", "prep_calls"):
        assert svc.stats[k] - base[k] == svc_ref.stats[k] - base_ref[k], k


# -- padding accounting -----------------------------------------------------
def test_bucket_for_rounds_to_device_multiple():
    """Pure host logic: buckets stay 1/2/4/../max_batch single-device and
    round up to a device multiple when sharded (including a non-power-of
    -two device count)."""
    svc = ElasticityService(max_batch=8)
    assert [svc.bucket_for(n) for n in (1, 2, 3, 5, 8, 9)] == [
        1, 2, 4, 8, 8, 8,
    ]
    svc.n_shards = 3  # as if mesh had 3 devices
    assert [svc.bucket_for(n) for n in (1, 2, 3, 5, 8)] == [3, 3, 6, 9, 9]
    svc.n_shards = 8
    assert [svc.bucket_for(n) for n in (1, 3, 8)] == [8, 8, 8]


def test_report_counts_real_vs_padding_rows():
    """SolveReport.padded_rows records the compiled program's total rows
    (bucket incl. padding) while batch_size counts real requests — the
    pair the throughput benchmark needs to stay honest."""
    svc = ElasticityService(max_batch=8, maxiter=MAXITER)
    reps = svc.solve(service_requests(3))
    assert len(reps) == 3  # padding never surfaced
    for r in reps:
        assert r.batch_size == 3
        assert r.padded_rows == 4  # bucket_for(3)
    reps = svc.solve_continuous(service_requests(3))
    assert len(reps) == 3
    for r in reps:
        assert r.batch_size <= 3
        assert r.padded_rows >= r.batch_size


@pytest.mark.multidevice
def test_report_counts_device_padding_rows():
    """With a device mesh, padded_rows grows to the device-aligned
    bucket while batch_size still counts only real requests."""
    ndev = 2
    _skip_if_too_few(ndev)
    svc = ElasticityService(
        max_batch=8, maxiter=MAXITER, mesh=scenario_mesh(ndev)
    )
    reps = svc.solve(service_requests(1))
    assert len(reps) == 1
    assert reps[0].batch_size == 1
    assert reps[0].padded_rows == 2  # bucket 1 rounded up to the mesh
    reps = svc.solve(service_requests(3))
    assert [r.padded_rows for r in reps] == [4, 4, 4]


# -- end-to-end CLI ---------------------------------------------------------
@pytest.mark.slow
def test_batched_throughput_devices_cli_end_to_end():
    """`batched_throughput.py --devices 8 --continuous` runs end-to-end
    on forced virtual host devices from a single-device parent process
    (the subprocess forces its own device count before backend init)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the CLI must force its own devices
    env.pop("REPRO_HOST_DEVICES", None)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run(
        [
            sys.executable, "-m", "benchmarks.batched_throughput",
            "--devices", "8", "--continuous", "--batch", "4",
            "--n-requests", "8", "--repeats", "1", "--chunk-iters", "4",
        ],
        cwd=root,
        env=env,
        capture_output=True,
        text=True,
        timeout=1800,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "scenario mesh: 8 devices (8 visible)" in res.stdout
    assert "continuous(fixed, k=4)" in res.stdout
