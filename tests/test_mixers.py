"""Mixer-level correctness: chunked scans vs naive recurrences, MoE
dispatch semantics, attention implementations."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import get_reduced
from repro.models import ssm as _ssm
from repro.models import xlstm as _xl
from repro.models.attention import attention, attn_init
from repro.models.moe import moe_apply, moe_init


def _zcfg(**kw):
    cfg = get_reduced("zamba2_27b")
    return dataclasses.replace(cfg, dtype="float32", **kw)


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [1, 4, 8, 16])
def test_ssd_chunk_invariance(chunk):
    """The chunked SSD factorization is exact: any chunk size gives the
    same output (the paper's 'factored action equals dense action')."""
    cfg = _zcfg(chunk_size=chunk)
    params = _ssm.mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    y, st = _ssm.mamba2_apply(params, x, cfg)
    cfg_ref = _zcfg(chunk_size=16)
    y_ref, st_ref = _ssm.mamba2_apply(params, x, cfg_ref)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(st["ssm"]), np.asarray(st_ref["ssm"]), atol=1e-5
    )


def test_ssd_matches_stepwise_recurrence():
    """Chunked scan == token-by-token recurrent decode (same params)."""
    cfg = _zcfg(chunk_size=4)
    params = _ssm.mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, L = 1, 8
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (B, L, cfg.d_model))
    x = x.astype(jnp.float32)
    y_full, st_full = _ssm.mamba2_apply(params, x, cfg)
    st = _ssm.init_mamba2_state(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        y_t, st = _ssm.mamba2_decode(params, x[:, t : t + 1], cfg, st)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(st["ssm"]), np.asarray(st_full["ssm"]), atol=2e-4
    )


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------
def test_mlstm_chunk_vs_decode():
    cfg = dataclasses.replace(get_reduced("xlstm_125m"), dtype="float32",
                              chunk_size=4)
    params = _xl.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, L = 2, 8
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model))
    x = x.astype(jnp.float32)
    y_full, _ = _xl.mlstm_apply(params, x, cfg)
    st = _xl.init_mlstm_state(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        y_t, st = _xl.mlstm_decode(params, x[:, t : t + 1], cfg, st)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, axis=1)), np.asarray(y_full), atol=2e-4
    )


def test_slstm_apply_vs_decode():
    cfg = dataclasses.replace(get_reduced("xlstm_125m"), dtype="float32")
    params = _xl.slstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, L = 2, 6
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model))
    x = x.astype(jnp.float32)
    y_full, _ = _xl.slstm_apply(params, x, cfg)
    carry = _xl.init_slstm_state(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        y_t, carry = _xl.slstm_decode(params, x[:, t : t + 1], cfg, carry)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, axis=1)), np.asarray(y_full), atol=1e-5
    )


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_lossless_matches_dense_expert_mix():
    """With capacity = S*k (no drops), MoE output equals the explicit
    weighted sum of chosen experts' FFN outputs."""
    cfg = dataclasses.replace(
        get_reduced("olmoe_1b_7b"), dtype="float32", capacity_factor=64.0
    )
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    y, aux = moe_apply(params, x, cfg)

    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, cfg.top_k)
    w = w / w.sum(-1, keepdims=True)

    def expert(e, t):
        g = t @ params["w_gate"][e]
        u = t @ params["w_up"][e]
        return (jax.nn.silu(g) * u) @ params["w_down"][e]

    ref = jnp.zeros_like(x)
    for b in range(B):
        for s in range(S):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(cfg.top_k):
                acc += w[b, s, j] * expert(int(idx[b, s, j]), x[b, s])
            ref = ref.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With tiny capacity, outputs are a (possibly zeroed) subset — never
    NaN, never amplified."""
    cfg = dataclasses.replace(
        get_reduced("olmoe_1b_7b"), dtype="float32", capacity_factor=0.25
    )
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    y, _ = moe_apply(params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# Attention impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen3_17b", "mixtral_8x7b"])
def test_chunked_attention_matches_full(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y_full, _ = attention(params, x, cfg, pos, impl="full")
    y_chunk, _ = attention(params, x, cfg, pos, impl="chunked",
                           q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full),
                               atol=2e-5)
