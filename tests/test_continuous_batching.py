"""Continuous-batching tests: resumable bpcg chunk semantics (property-
based: resumption is bit-identical, refilled slots match fresh solves),
the ElasticityService slot-refill engine (randomized-arrival stress
test), and the bucketed compile cache (smallest sufficient bucket, LRU
eviction, zero retraces on cache hits)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.fem.mesh import beam_hex
from repro.solvers.batched import (
    BatchedGMGSolver,
    bpcg,
    bpcg_chunk,
    bpcg_init,
    bpcg_result,
    merge_states,
)
from repro.serve.elasticity_service import ElasticityService, SolveRequest

from tests._hypothesis_compat import given, settings, st

MATS_A = {1: (50.0, 50.0), 2: (1.0, 1.0)}
MATS_B = {1: (80.0, 60.0), 2: (2.0, 1.0)}
MATS_C = {1: (9.0, 9.0), 2: (1.0, 3.0)}


def _spd_batch(seed: int, s: int, n: int):
    rng = np.random.default_rng(seed)
    mats, rhss = [], []
    for _ in range(s):
        m = rng.standard_normal((n, n))
        mats.append(m @ m.T + n * np.eye(n))
        rhss.append(rng.standard_normal(n))
    a = jnp.asarray(np.stack(mats))
    return a, jnp.asarray(np.stack(rhss))


def _matvec(a):
    return lambda x: jnp.einsum("sij,sj->si", a, x)


# -- property: chunked resumption ------------------------------------------
@settings(max_examples=200, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.integers(1, 4),
    n=st.integers(4, 16),
    k=st.integers(1, 7),
)
def test_chunk_resumption_bit_identical(seed, s, n, k):
    """run_chunk(k) repeated until convergence must produce *bitwise* the
    state of one uninterrupted bpcg run: frozen rows never move, so a
    chunk boundary is invisible to the iteration."""
    a, b = _spd_batch(seed, s, n)
    A = _matvec(a)
    full = bpcg(A, b, rel_tol=1e-10, maxiter=150)

    state = bpcg_init(A, b, rel_tol=1e-10)
    guard = 0
    while bool(jnp.any(state.active)):
        state = bpcg_chunk(A, state, k_iters=k, maxiter=150)
        guard += 1
        assert guard < 1000
    res = bpcg_result(state)

    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(full.x))
    np.testing.assert_array_equal(
        np.asarray(res.iterations), np.asarray(full.iterations)
    )
    np.testing.assert_array_equal(np.asarray(res.final_norm), np.asarray(full.final_norm))
    assert bool(jnp.all(res.converged == full.converged))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.integers(2, 4),
    n=st.integers(4, 12),
    warm=st.integers(1, 10),
    row=st.integers(0, 3),
)
def test_slot_refill_matches_fresh_solo_solve(seed, s, n, warm, row):
    """Resetting one row mid-flight (new matrix + RHS, the slot-refill
    primitive) must (a) leave the other rows' trajectories bitwise
    untouched and (b) converge the refilled row to the solution of a
    fresh, uninterrupted solve of its new system."""
    row = row % s
    a, b = _spd_batch(seed, s, n)
    a2, b2 = _spd_batch(seed + 1, s, n)
    A = _matvec(a)
    state = bpcg_init(A, b, rel_tol=1e-10)
    state = bpcg_chunk(A, state, k_iters=warm, maxiter=150)

    # refill `row` with a new system; other rows keep matrix + state
    a_new = a.at[row].set(a2[row])
    b_new = b.at[row].set(b2[row])
    A_new = _matvec(a_new)
    mask = np.zeros((s,), dtype=bool)
    mask[row] = True
    fresh = bpcg_init(A_new, b_new, rel_tol=1e-10)
    merged = merge_states(jnp.asarray(mask), fresh, state)
    # untouched rows: bitwise identical after the merge
    keep = ~mask
    np.testing.assert_array_equal(
        np.asarray(merged.x)[keep], np.asarray(state.x)[keep]
    )
    np.testing.assert_array_equal(
        np.asarray(merged.iters)[keep], np.asarray(state.iters)[keep]
    )
    assert int(merged.iters[row]) == 0

    final = bpcg_chunk(A_new, merged, k_iters=None, maxiter=150)
    res = bpcg_result(final)
    assert bool(res.converged[row])
    solo = bpcg(
        lambda x: jnp.einsum("ij,sj->si", a2[row], x),
        b2[row][None],
        rel_tol=1e-10,
        maxiter=150,
    )
    assert int(res.iterations[row]) == int(solo.iterations[0])
    np.testing.assert_allclose(
        np.asarray(res.x[row]), np.asarray(solo.x[0]), rtol=1e-8, atol=1e-12
    )


def test_chunk_resumption_bit_identical_deterministic():
    """Deterministic spot-check of the resumption property (runs even
    without hypothesis installed)."""
    a, b = _spd_batch(7, 3, 20)
    A = _matvec(a)
    full = bpcg(A, b, rel_tol=1e-12, maxiter=200)
    state = bpcg_init(A, b, rel_tol=1e-12)
    for k in (1, 2, 5, 3, 200):
        state = bpcg_chunk(A, state, k_iters=k, maxiter=200)
    res = bpcg_result(state)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(full.x))
    np.testing.assert_array_equal(
        np.asarray(res.iterations), np.asarray(full.iterations)
    )


# -- solver-level step program ---------------------------------------------
@pytest.fixture(scope="module")
def small_solver():
    return BatchedGMGSolver(beam_hex(), 1, 1, maxiter=100)


def test_solver_chunked_matches_monolithic(small_solver):
    """prepare + run_chunk driven to convergence reproduces the one-call
    compiled solve (same iteration counts, solutions to fp roundoff)."""
    solver = small_solver
    mats = [MATS_A, MATS_B]
    tr = np.array([[0.0, 0.0, -1e-2], [0.0, 1e-3, -2e-2]])
    ref = solver.solve(mats, tr, rel_tol=1e-8)

    lam, mu = solver.pack_materials(mats)
    prep = solver.prepare(lam, mu, np.ones(2, bool), solver.empty_prep(2))
    state, consumed = solver.run_chunk(
        tr, 1e-8, np.ones(2, bool), solver.empty_state(2), prep, 4,
        do_reset=True,
    )
    # consumed mirrors the per-row iteration delta of the chunk
    np.testing.assert_array_equal(
        np.asarray(consumed), np.asarray(state.iters)
    )
    guard = 0
    while bool(jnp.any(state.active)):
        prev = np.asarray(state.iters)
        state, consumed = solver.run_chunk(
            tr, 1e-8, np.zeros(2, bool), state, prep, 4, do_reset=False
        )
        np.testing.assert_array_equal(
            np.asarray(consumed), np.asarray(state.iters) - prev
        )
        guard += 1
        assert guard < 100
    np.testing.assert_array_equal(
        np.asarray(state.iters), np.asarray(ref.iterations)
    )
    scale = float(jnp.abs(ref.x).max())
    np.testing.assert_allclose(
        np.asarray(state.x), np.asarray(ref.x), atol=1e-12 * scale
    )


def test_solver_refill_row_matches_fresh_solve(small_solver):
    """Mid-flight slot refill at the solver level: the refilled row's
    final solution matches a fresh compiled solve of that scenario and
    the surviving row is not perturbed."""
    solver = small_solver
    mats = [MATS_A, MATS_B]
    tr = np.array([[0.0, 0.0, -1e-2], [0.0, 1e-3, -2e-2]])
    lam, mu = solver.pack_materials(mats)
    prep = solver.prepare(lam, mu, np.ones(2, bool), solver.empty_prep(2))
    state, _ = solver.run_chunk(
        tr, 1e-8, np.ones(2, bool), solver.empty_state(2), prep, 3,
        do_reset=True,
    )
    # refill row 0 with a new scenario while row 1 keeps iterating
    mats2 = [MATS_C, MATS_B]
    tr2 = np.array([[0.0, -2e-3, 5e-3], [0.0, 1e-3, -2e-2]])
    lam2, mu2 = solver.pack_materials(mats2)
    mask = np.array([True, False])
    prep = solver.prepare(lam2, mu2, mask, prep)
    state, _ = solver.run_chunk(tr2, 1e-8, mask, state, prep, 3, do_reset=True)
    guard = 0
    while bool(jnp.any(state.active)):
        state, _ = solver.run_chunk(
            tr2, 1e-8, np.zeros(2, bool), state, prep, 3, do_reset=False
        )
        guard += 1
        assert guard < 100
    ref = solver.solve(mats2, tr2, rel_tol=1e-8)
    for row in range(2):
        assert int(state.iters[row]) == int(ref.iterations[row])
        scale = float(jnp.abs(ref.x[row]).max())
        np.testing.assert_allclose(
            np.asarray(state.x[row]), np.asarray(ref.x[row]),
            atol=1e-10 * scale,
        )


# -- continuous service: stress --------------------------------------------
def _stress_requests():
    """12 mixed scenarios on the p=1/refine=1 key: three material sets,
    varied tractions, tolerances spanning 1e-4..1e-10."""
    reqs = []
    for i in range(12):
        reqs.append(
            SolveRequest(
                p=1,
                refine=1,
                materials=(MATS_A, MATS_B, MATS_C)[i % 3],
                traction=(0.0, 1e-3 * (i % 4), -1e-2 * (1 + 0.3 * i)),
                rel_tol=(1e-4, 1e-7, 1e-10)[i % 3],
                keep_solution=True,
            )
        )
    return reqs


@pytest.mark.slow
def test_continuous_stress_randomized_arrivals():
    """Randomized arrival order + mid-flight submissions: every request
    gets exactly one report, no slot is double-assigned (the admit path
    asserts), and per-request results are independent of arrival order
    and of which requests shared a batch."""
    base = _stress_requests()
    service = ElasticityService(max_batch=4, chunk_iters=3)

    # order A: staggered arrivals — a few up front, the rest submitted
    # mid-flight while earlier requests are still iterating.
    rng = np.random.default_rng(0)
    order_a = [int(i) for i in rng.permutation(len(base))]
    tickets = {}
    for idx in order_a[:3]:
        tickets[service.submit(base[idx])] = idx
    pending = order_a[3:]
    while pending:
        service.step()  # earlier requests iterate while these arrive
        k = int(rng.integers(1, 3))
        for idx in pending[:k]:
            tickets[service.submit(base[idx])] = idx
        pending = pending[k:]
    service.run_until_idle()
    done = service.drain()
    assert len(done) == len(base)  # exactly one report per request
    by_req_a = {}
    returned = sorted(tickets)
    for t, rep in zip(returned, done):
        by_req_a[tickets[t]] = rep
    assert set(by_req_a) == set(range(len(base)))

    # order B: reversed arrival, same service (warm cache, no retraces
    # needed) — reports must agree request-by-request.
    order_b = list(reversed(range(len(base))))
    tickets_b = {service.submit(base[i]): i for i in order_b}
    service.run_until_idle()
    done_b = service.drain()
    assert len(done_b) == len(base)
    by_req_b = {tickets_b[t]: rep for t, rep in zip(sorted(tickets_b), done_b)}

    for i in range(len(base)):
        ra, rb = by_req_a[i], by_req_b[i]
        assert ra.converged and rb.converged
        assert ra.final_rel_norm <= base[i].rel_tol
        assert ra.iterations == rb.iterations
        scale = max(np.abs(ra.x).max(), 1e-30)
        np.testing.assert_allclose(ra.x, rb.x, atol=1e-8 * scale)
        assert not ra.born_converged


def test_drain_is_incremental_and_ordered():
    """drain() pops completed reports in submission order and never
    yields a ticket twice."""
    service = ElasticityService(max_batch=2, chunk_iters=2)
    reqs = [
        SolveRequest(p=1, refine=0, materials=MATS_A, rel_tol=1e-6,
                     traction=(0.0, 0.0, -1e-2 * (i + 1)))
        for i in range(4)
    ]
    for r in reqs:
        service.submit(r)
    seen = []
    while not service.idle():
        service.step()
        seen += service.drain()
    assert service.drain() == []
    assert len(seen) == 4
    # submission order within the drained stream
    tzs = [r.request.traction[2] for r in seen]
    assert tzs == sorted(tzs, reverse=True)


# -- bucketed compile cache -------------------------------------------------
def test_bucket_for_picks_smallest_sufficient():
    service = ElasticityService(max_batch=8)
    assert [service.bucket_for(n) for n in range(1, 10)] == [
        1, 2, 4, 4, 8, 8, 8, 8, 8,
    ]
    odd = ElasticityService(max_batch=6)
    assert [odd.bucket_for(n) for n in (1, 2, 3, 4, 5, 6, 7)] == [
        1, 2, 4, 4, 6, 6, 6,
    ]


def test_generational_padding_uses_bucket(monkeypatch):
    """3 requests with max_batch=8 pad to bucket 4, not 8."""
    service = ElasticityService(max_batch=8)
    captured = {}
    orig = BatchedGMGSolver.solve

    def spy(self, materials, tractions, rel_tol):
        captured["rows"] = len(materials)
        return orig(self, materials, tractions, rel_tol)

    monkeypatch.setattr(BatchedGMGSolver, "solve", spy)
    reports = service.solve(
        [SolveRequest(p=1, refine=0, materials=MATS_A, rel_tol=1e-6)] * 3
    )
    assert captured["rows"] == 4
    assert len(reports) == 3
    assert all(r.converged for r in reports)


def test_continuous_cache_hit_zero_retrace():
    """Re-running an identical continuous workload must not retrace any
    compiled program: the (key, bucket) step/prepare programs all come
    from the jit cache."""
    service = ElasticityService(max_batch=4, chunk_iters=3)
    reqs = [
        SolveRequest(p=1, refine=0, materials=MATS_A if i % 2 else MATS_B,
                     rel_tol=1e-8, traction=(0.0, 0.0, -1e-2 * (i + 1)))
        for i in range(6)
    ]
    first = service.solve_continuous(reqs)
    assert all(r.converged for r in first)
    assert not first[0].cache_hit
    key = service.group_key(reqs[0])
    solver = service._solvers[key]
    traces = (
        solver._jit_chunk._cache_size(),
        solver._jit_prepare._cache_size(),
    )
    hits0 = service.stats["cache_hits"]

    second = service.solve_continuous(reqs)
    assert all(r.converged for r in second)
    assert second[0].cache_hit
    assert service.stats["cache_hits"] > hits0
    assert (
        solver._jit_chunk._cache_size(),
        solver._jit_prepare._cache_size(),
    ) == traces
    for ra, rb in zip(first, second):
        assert ra.iterations == rb.iterations


def test_prep_row_reuse_skips_power_iterations():
    """Refilled slots whose materials match an already-prepared row (the
    common serving case: bounded material vocabulary) copy that row's
    derived data instead of re-running prepare — after the initial
    batch, a repeat-material workload pays zero further prepare calls,
    and the results still match the generational path."""
    service = ElasticityService(max_batch=2, chunk_iters=3)
    reqs = [
        SolveRequest(p=1, refine=1, materials=MATS_A if i % 2 else MATS_B,
                     rel_tol=1e-8, traction=(0.0, 0.0, -1e-2 * (i + 1)),
                     keep_solution=True)
        for i in range(6)
    ]
    reports = service.solve_continuous(reqs)
    assert all(r.converged for r in reports)
    assert service.stats["prep_calls"] == 1  # the initial batch only
    assert service.stats["prep_row_copies"] >= 4  # every refill reused
    ref = ElasticityService(max_batch=2).solve(list(reqs))
    for rc, rg in zip(reports, ref):
        assert rc.iterations == rg.iterations
        scale = max(np.abs(rg.x).max(), 1e-30)
        np.testing.assert_allclose(rc.x, rg.x, atol=1e-8 * scale)


# -- scheduler invariants under random interleavings -------------------------
_SCHED_SERVICES: dict = {}


def _sched_service(policy: str) -> ElasticityService:
    """One service per policy, shared across hypothesis examples (the
    compiled programs are paid for once); every example drains fully, so
    only the cumulative counters carry over — tests use deltas.  A
    service left non-idle by a failing example is discarded, so later
    examples (and hypothesis shrinking) never see its leftovers."""
    svc = _SCHED_SERVICES.get(policy)
    if svc is not None and not svc.idle():
        svc = None  # poisoned by a failed example: rebuild
    if svc is None:
        svc = _SCHED_SERVICES[policy] = ElasticityService(
            max_batch=2, chunk_iters=2, chunk_policy=policy
        )
    svc.drain()  # discard any completed-but-undrained leftovers
    return svc


@settings(max_examples=12, deadline=None)
@given(
    policy=st.sampled_from(["fixed", "adaptive", "shard-adaptive"]),
    n_upfront=st.integers(1, 3),
    arrivals=st.lists(st.integers(0, 2), min_size=0, max_size=4),
    mat_idx=st.lists(st.integers(0, 2), min_size=7, max_size=7),
    tight=st.lists(st.booleans(), min_size=7, max_size=7),
)
def test_scheduler_no_starvation_and_stats_match_trace(
    policy, n_upfront, arrivals, mat_idx, tight
):
    """Random submit/step interleavings (slots retire and refill at
    arbitrary points) under every policy:

    * no live row is ever starved — every flight holding live rows
      dispatches exactly one chunk per ``step()`` (checked against the
      trace's per-step decisions);
    * every chunk choice respects the policy bounds;
    * the scheduler counters (``chunks``, ``chunk_iters_dispatched``,
      ``wasted_iters``, ``refills``) are exactly the trace's sums —
      stats can never drift from the replayable record."""
    service = _sched_service(policy)
    base = {
        k: service.stats[k]
        for k in (
            "chunks", "chunk_iters_dispatched", "wasted_iters", "refills"
        )
    }
    # Fresh trace per example: the record is bounded (maxlen trimming
    # drops the OLDEST decisions), so index-based slicing across shared
    # examples would eventually skew — clearing keeps exactly this
    # example's decisions while the cumulative stats counters (compared
    # as deltas) are unaffected.
    service.trace.clear()
    reqs = [
        SolveRequest(
            p=1,
            refine=0,
            materials=(MATS_A, MATS_B, MATS_C)[mat_idx[i]],
            traction=(0.0, 0.0, -1e-2 * (i + 1)),
            rel_tol=1e-10 if tight[i] else 1e-4,
        )
        for i in range(len(mat_idx))
    ]
    it = iter(reqs)
    submitted = 0

    def step_and_check():
        service.step()
        decided = {
            d.key for d in service.trace.decisions
            if d.step == service._step_index
        }
        # every flight still holding live rows was dispatched this step
        for key, flight in service._flights.items():
            assert not flight.live_rows() or key in decided, (
                f"flight {key} starved at step {service._step_index}"
            )

    for _ in range(n_upfront):
        service.submit(next(it))
        submitted += 1
    for k in arrivals:
        step_and_check()
        for _ in range(k):
            try:
                service.submit(next(it))
                submitted += 1
            except StopIteration:
                break
    guard = 0
    while not service.idle():
        step_and_check()
        guard += 1
        assert guard < 500
    done = service.drain()
    assert len(done) == submitted  # exactly one report per request
    assert all(r.converged for r in done)

    decisions = service.trace.decisions
    pol = service.chunk_policy
    for d in decisions:
        assert pol.min_chunk <= d.chunk <= pol.max_chunk
        assert d.wasted >= 0
        assert len(d.consumed) == d.bucket  # outcome was finalized
    delta = {k: service.stats[k] - v for k, v in base.items()}
    assert delta["chunks"] == len(decisions)
    assert delta["chunk_iters_dispatched"] == sum(d.chunk for d in decisions)
    assert delta["wasted_iters"] == sum(d.wasted for d in decisions)
    assert delta["refills"] == sum(len(d.refills) for d in decisions)
    # the recorded observations replay to the recorded choices
    assert [pol.chunk_for(d.observation) for d in decisions] == [
        d.chunk for d in decisions
    ]


def test_continuous_lru_eviction_fires_at_capacity():
    """cache_size=1: a second discretization key evicts the first's
    solver; re-solving the first key is a cache miss again."""
    service = ElasticityService(max_batch=2, cache_size=1, chunk_iters=4)
    service.solve_continuous([SolveRequest(p=1, refine=0, rel_tol=1e-6)])
    service.solve_continuous([SolveRequest(p=1, refine=1, rel_tol=1e-6)])
    assert len(service._solvers) == 1
    rep = service.solve_continuous(
        [SolveRequest(p=1, refine=0, rel_tol=1e-6)]
    )[0]
    assert not rep.cache_hit
    assert service.stats["cache_misses"] == 3


def test_in_flight_solver_never_evicted():
    """The LRU never drops a solver whose flight still has live rows:
    a new key arriving mid-flight evicts an idle entry instead."""
    service = ElasticityService(max_batch=2, cache_size=1, chunk_iters=1)
    t0 = service.submit(
        SolveRequest(p=1, refine=1, materials=MATS_A, rel_tol=1e-12)
    )
    service.step()  # key A in flight
    key_a = service.group_key(SolveRequest(p=1, refine=1))
    assert key_a in service._flights
    service.submit(SolveRequest(p=1, refine=0, materials=MATS_B, rel_tol=1e-8))
    service.run_until_idle()
    done = service.drain()
    assert len(done) == 2
    assert all(r.converged for r in done)
    assert done[0].request.rel_tol == 1e-12  # ticket t0 surfaced first
    assert t0 == 0
