"""Shared test configuration.

x64 is enabled globally: the FEM oracle comparisons need f64 tightness
(the paper's CPU arithmetic is double precision); LM-model tests pass
explicit f32 dtypes and are unaffected.  NOTE: no
xla_force_host_platform_device_count here — smoke tests and benches see
the real single device; only launch/dryrun.py fakes 512.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
