"""Shared test configuration.

Device-count policy, centralized so the default and multidevice CI
lanes cannot silently diverge:

* By default NO virtual device count is forced — smoke tests and
  benches see the real single device; only launch/dryrun.py fakes 512.
* ``REPRO_HOST_DEVICES=N`` (the multidevice lane sets 8) forces N
  virtual XLA host devices through the same
  ``force_host_device_count`` helper the ``--devices`` CLIs use.  It
  must be applied before jax initializes its backend, hence before the
  ``import jax`` below.
* Tests marked ``multidevice`` are auto-skipped when only one device is
  visible, so the default lane collects them harmlessly and the
  multidevice lane (`-m multidevice`) runs them all.

x64 is enabled globally: the FEM oracle comparisons need f64 tightness
(the paper's CPU arithmetic is double precision); LM-model tests pass
explicit f32 dtypes and are unaffected.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.distributed.sharding import force_host_device_count  # noqa: E402

force_host_device_count(int(os.environ.get("REPRO_HOST_DEVICES", "0") or 0))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", True)


def pytest_collection_modifyitems(config, items):
    if jax.device_count() > 1:
        return
    skip = pytest.mark.skip(
        reason="multidevice: needs >1 XLA device "
        "(run with REPRO_HOST_DEVICES=8)"
    )
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
