"""End-to-end system behaviour: training convergence, checkpoint/restart
determinism, gradient-compression training, the FEM solve driver, and
the dry-run cell machinery on the local device."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_reduced
from repro.launch.train import train_loop
from repro.optim.adamw import AdamWConfig


def _cfg():
    return dataclasses.replace(
        get_reduced("qwen3_17b"), dtype="float32", n_layers=2, d_model=64,
        d_ff=128, vocab=128, chunk_size=16,
    )


SHAPE = ShapeConfig("sys", "train", 64, 4)


def test_training_reduces_loss(tmp_path):
    cfg = _cfg()
    opt = AdamWConfig(lr=1e-3, total_steps=60, warmup_steps=5)
    _, hist = train_loop(cfg, SHAPE, steps=60, opt=opt, log_every=5)
    first = hist[0]["loss"]
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.2, (first, last)


def test_checkpoint_restart_bit_identical(tmp_path):
    """Train 12 steps straight vs 6 + kill + resume 6: identical loss."""
    cfg = _cfg()
    opt = AdamWConfig(lr=1e-3, total_steps=12, warmup_steps=2)

    _, hist_ref = train_loop(cfg, SHAPE, steps=12, opt=opt, log_every=1)

    d = str(tmp_path / "ck")
    train_loop(cfg, SHAPE, steps=6, ckpt_dir=d, ckpt_every=6, opt=opt,
               log_every=1)
    _, hist_resumed = train_loop(cfg, SHAPE, steps=12, ckpt_dir=d,
                                 ckpt_every=6, opt=opt, log_every=1)
    ref_last = [h for h in hist_ref if h["step"] == 12][0]["loss"]
    res_last = [h for h in hist_resumed if h["step"] == 12][0]["loss"]
    assert res_last == pytest.approx(ref_last, rel=1e-5), (ref_last, res_last)


def test_training_with_gradient_compression():
    """int8 error-feedback compression still trains (loss decreases)."""
    from repro.distributed.compression import make_error_feedback_transform

    cfg = _cfg()
    init_fn, tfm = make_error_feedback_transform("int8")
    residual = {}

    def grad_transform(grads):
        # stateless within-step hook: apply plain int8 (no feedback) —
        # the feedback variant is exercised in test_distributed.py
        from repro.distributed.compression import int8_compress, int8_decompress

        return jax.tree.map(
            lambda g: int8_decompress(*int8_compress(g)).astype(g.dtype), grads
        )

    opt = AdamWConfig(lr=1e-3, total_steps=40, warmup_steps=5)
    from repro.train.trainer import make_train_step, train_state_init
    from repro.data.pipeline import TokenPipeline

    state = train_state_init(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, opt, grad_transform=grad_transform))
    pipe = TokenPipeline(cfg, SHAPE, seed=0)
    losses = []
    for _ in range(40):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    pipe.close()
    assert np.mean(losses[-5:]) < losses[0] - 0.2


def test_solve_driver_all_assemblies_converge():
    from repro.launch.solve import solve_beam

    for a in ("paop", "paop_pallas"):
        rep = solve_beam(2, n_h_refine=0, assembly=a, rel_tol=1e-8)
        assert rep.final_rel_norm < 1e-8, a


def test_local_cell_lowering():
    """Cell machinery lowers + compiles on the single local device
    (1x1 mesh) — catches arg/sharding structure bugs without the 512-way
    dry run."""
    from repro.launch.cells import build_cell

    from repro.launch.mesh import axis_type_kwargs

    mesh = jax.make_mesh(
        (1, 1), ("data", "model"), **axis_type_kwargs(2)
    )
    import repro.configs.base as base

    small_shape = ShapeConfig("train_4k", "train", 128, 2)
    with _patched_shapes({"train_4k": small_shape}):
        cell = build_cell("qwen3_17b", "train_4k", mesh)
        compiled = cell.lower(mesh).compile()
        assert compiled.cost_analysis() is not None


class _patched_shapes:
    def __init__(self, shapes):
        self.shapes = shapes

    def __enter__(self):
        import repro.configs.base as base

        self.saved = dict(base.SHAPES)
        base.SHAPES.update(self.shapes)

    def __exit__(self, *a):
        import repro.configs.base as base

        base.SHAPES.clear()
        base.SHAPES.update(self.saved)


def test_jaxpr_cost_scan_awareness():
    """The roofline's cost walker must multiply scan bodies by length."""
    from repro.launch.jaxpr_cost import cost_of_fn

    W = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64,), jnp.float32)

    def once(w, x):
        return w @ x

    def scanned(w, x):
        def body(c, _):
            return w @ c, None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c1 = cost_of_fn(once, W, x)
    c10 = cost_of_fn(scanned, W, x)
    assert c10.flops == pytest.approx(10 * c1.flops, rel=1e-6)


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes

    hlo = """
  %ag = f32[4,256]{1,0} all-gather(%x), replica_groups=[8,4]<=[32], dimensions={1}
  %ar = (f32[128]{0}) all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = bf16[64,64]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    ag_r = 4 * 256 * 4
    ar_r = 128 * 4
    cp_r = 64 * 64 * 2
    assert out["operand_bytes"] == pytest.approx(ag_r / 4 + ar_r + cp_r)
    assert out["link_bytes"] == pytest.approx(
        ag_r * 3 / 4 + 2 * ar_r * 3 / 4 + cp_r
    )
