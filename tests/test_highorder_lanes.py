"""High-order (p=4, p=6) lane differentials.

The Pallas lane — compiled vs interpret, with automatic fallback — is
an implementation detail of the ``paop_pallas`` assembly: it must never
change what a solve computes.  These tests lock that down at the three
levels users touch:

* solver (``BatchedGMGSolver.solve``): compiled-lane and
  interpret-lane runs produce identical iteration counts and solutions,
  and both agree with the einsum ``paop`` reference assembly;
* service (``ElasticityService``): the batched/generational path
  reports the same outcome regardless of lane, and
  ``service.pallas_lane`` reports the lane that actually runs;
* sharded (8 virtual devices): the lane differential survives
  scenario-axis sharding.

On backends without native Pallas lowering (the CPU CI containers) the
compiled request falls back to the interpreter, so the two lanes are
bitwise identical — exercising exactly the fallback path a TPU-trained
artifact relies on when replayed on CPU.  Lane *resolution* plumbing is
covered by fast tests via the monkeypatched capability cache.
"""

import jax
import numpy as np
import pytest

from repro.distributed.sharding import scenario_mesh
from repro.fem.mesh import beam_hex
from repro.kernels.pa_elasticity import ops
from repro.serve.elasticity_service import ElasticityService, SolveRequest
from repro.solvers.batched import BatchedGMGSolver

MATS = [
    {1: (50.0, 50.0), 2: (1.0, 1.0)},
    {1: (57.0, 51.3), 2: (1.5, 1.5)},
]
TRACTIONS = np.array([[0.0, 0.0, -1e-2], [0.0, 1e-3, -2e-2]])
TOLS = np.array([1e-8, 1e-8])
MAXITER = 400


def _solve(p, assembly, lane=None, mesh=None, mats=MATS, tr=TRACTIONS,
           tol=TOLS):
    solver = BatchedGMGSolver(
        beam_hex(), 0, p, assembly=assembly, pallas_lane=lane,
        maxiter=MAXITER, mesh=mesh,
    )
    return solver, solver.solve(mats, tr, tol)


def _assert_same_solve(res, ref, context, *, exact=False):
    np.testing.assert_array_equal(
        np.asarray(res.iterations), np.asarray(ref.iterations),
        err_msg=f"{context}: iteration counts diverged",
    )
    np.testing.assert_array_equal(
        np.asarray(res.converged), np.asarray(ref.converged),
        err_msg=f"{context}: convergence flags diverged",
    )
    if exact:
        np.testing.assert_array_equal(
            np.asarray(res.x), np.asarray(ref.x),
            err_msg=f"{context}: solutions diverged",
        )
    else:
        scale = float(np.abs(np.asarray(ref.x)).max()) or 1.0
        np.testing.assert_allclose(
            np.asarray(res.x), np.asarray(ref.x),
            atol=1e-10 * scale, rtol=0,
            err_msg=f"{context}: solutions diverged",
        )


# -- fast: lane resolution plumbing ------------------------------------------


def test_lane_plumbing_solver_and_service(monkeypatch):
    """The lane resolves ONCE at construction in every layer, and the
    stored value is the lane that actually runs, not the request."""
    backend = jax.default_backend()

    monkeypatch.setitem(ops._SUPPORT_CACHE, backend, False)
    solver = BatchedGMGSolver(beam_hex(), 0, 1, assembly="paop_pallas")
    assert solver.pallas_lane == "interpret"  # auto fell back
    svc = ElasticityService(assembly="paop_pallas", pallas_lane="compiled")
    assert svc.pallas_lane == "interpret"  # request honestly downgraded
    assert svc.pallas_interpret is True

    monkeypatch.setitem(ops._SUPPORT_CACHE, backend, True)
    solver = BatchedGMGSolver(beam_hex(), 0, 1, assembly="paop_pallas")
    assert solver.pallas_lane == "compiled"
    assert solver._base_ops[-1].pallas_lane == "compiled"
    svc = ElasticityService(assembly="paop_pallas")
    assert svc.pallas_lane == "compiled"
    assert svc.pallas_interpret is False
    # the legacy bool still pins the interpreter even when capable
    svc = ElasticityService(assembly="paop_pallas", pallas_interpret=True)
    assert svc.pallas_lane == "interpret"


def test_build_hierarchy_threads_lane(monkeypatch):
    """Unlike the deferred-materials batched solver, build_hierarchy
    APPLIES the operator at construction (smoother power iterations),
    so it must already run the resolved lane — a compiled request on an
    incapable backend is recorded (and executed) as interpret on every
    pallas level."""
    from repro.solvers.gmg import build_hierarchy

    backend = jax.default_backend()
    monkeypatch.setitem(ops._SUPPORT_CACHE, backend, False)
    gmg = build_hierarchy(
        beam_hex(), 0, 2, assembly="paop_pallas", pallas_lane="compiled"
    )
    assert gmg.fine.operator.pallas_lane == "interpret"
    gmg = build_hierarchy(
        beam_hex(), 0, 2, assembly="paop_pallas", pallas_interpret=True
    )
    assert gmg.fine.operator.pallas_lane == "interpret"


# -- slow: solver differentials at p = 4 and p = 6 ---------------------------


@pytest.mark.slow
@pytest.mark.parametrize("p", [4, 6])
def test_solver_lane_differential(p):
    """compiled vs interpret vs the einsum paop reference at high
    order: identical iteration counts, matching solutions."""
    si, ri = _solve(p, "paop_pallas", "interpret")
    sc, rc = _solve(p, "paop_pallas", "compiled")
    _, ref = _solve(p, "paop")
    assert si.pallas_lane == "interpret"
    assert sc.pallas_lane == (
        "compiled" if ops.backend_supports_compiled() else "interpret"
    )
    # lanes of the SAME kernel: bitwise when compiled fell back
    _assert_same_solve(
        rc, ri, f"p={p} compiled vs interpret",
        exact=sc.pallas_lane == "interpret",
    )
    # kernel vs einsum reference assembly
    _assert_same_solve(ri, ref, f"p={p} paop_pallas vs paop")
    assert bool(np.all(np.asarray(ref.converged)))


# -- slow: service differential ----------------------------------------------


@pytest.mark.slow
def test_service_lane_differential():
    """The generational service path reports identical outcomes per
    lane at p=4, and each report's solver ran the resolved lane."""
    reports = {}
    for lane in ("interpret", "compiled"):
        svc = ElasticityService(
            assembly="paop_pallas", pallas_lane=lane, maxiter=MAXITER
        )
        reqs = [
            SolveRequest(p=4, refine=0, materials=m, traction=tuple(t),
                         rel_tol=1e-8, keep_solution=True)
            for m, t in zip(MATS, TRACTIONS)
        ]
        reports[lane] = svc.solve(reqs)
        assert svc.pallas_lane == (
            lane if lane == "interpret"
            else ("compiled" if ops.backend_supports_compiled()
                  else "interpret")
        )
    for a, b in zip(reports["interpret"], reports["compiled"]):
        assert a.iterations == b.iterations
        assert a.converged and b.converged
        np.testing.assert_allclose(
            np.asarray(a.x), np.asarray(b.x),
            atol=1e-10 * (float(np.abs(np.asarray(a.x)).max()) or 1.0),
            rtol=0,
        )


# -- slow + multidevice: sharded lane differential ---------------------------


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_lane_differential():
    """Scenario-sharding over 8 virtual devices composes with the lane
    machinery: the sharded compiled-lane solve reproduces the unsharded
    interpret-lane solve at p=4."""
    if jax.device_count() < 8:
        pytest.skip(f"needs 8 devices, have {jax.device_count()}")
    mats, tr, tol = [], [], []
    for i in range(8):
        mats.append({1: (50.0 + 3.0 * (i % 3), 50.0), 2: (1.0 + 0.25 * (i % 2), 1.0)})
        tr.append((0.0, 1e-3 * (i % 2), -1e-2))
        tol.append(1e-8)
    tr, tol = np.asarray(tr), np.asarray(tol)
    _, ref = _solve(4, "paop_pallas", "interpret", mats=mats, tr=tr, tol=tol)
    ss, rs = _solve(4, "paop_pallas", "compiled", mesh=scenario_mesh(8),
                    mats=mats, tr=tr, tol=tol)
    assert ss.n_shards == 8
    # sharded partitioning fuses differently: ~ulp, not bitwise
    _assert_same_solve(rs, ref, "sharded compiled vs unsharded interpret")
