"""Heterogeneous per-element material fields, end to end.

The service accepts ``SolveRequest.materials`` as an attribute dict or
a per-element ``(lam_e, mu_e)`` array pair; both are folded to
(S, nelem) fields on admission and coarser GMG levels see them through
an exact power-of-two descendant average.  This suite locks down:

* the fine-descendant map itself (attribute inheritance, coverage);
* the bit-for-bit differential: a piecewise-constant array request
  reproduces the equivalent attribute-dict request's solutions AND
  iteration counts exactly — generational and continuous scheduling, on
  1 device and (multidevice lane) an 8-device scenario mesh;
* form-invariance of the continuous engine under retire/refill (a
  hypothesis property): replacing any subset of a batch's dicts with
  their bitwise-equal array twins changes no report and no scheduling
  stat — prep-row reuse keys on field content, not on material form —
  and padding rows never surface;
* genuinely heterogeneous (graded/random) fields converge and differ
  from their homogenized counterparts;
* precise validation errors at ``submit()`` and ``pack_materials``:
  offending attribute / element index / expected shape by name.
"""

import jax
import numpy as np
import pytest

from repro.core.geometry import MATERIALS_BEAM, material_fields
from repro.distributed.sharding import scenario_mesh
from repro.fem.mesh import beam_hex, fine_descendants
from repro.serve.elasticity_service import ElasticityService, SolveRequest
from repro.solvers.batched import BatchedGMGSolver
from tests._hypothesis_compat import given, settings, st

MATS_A = {1: (50.0, 50.0), 2: (1.0, 1.0)}
MATS_B = {1: (80.0, 60.0), 2: (2.0, 1.0)}
MATS_C = {1: (9.0, 9.0), 2: (1.0, 3.0)}
VOCAB = (MATS_A, MATS_B, MATS_C)

FINE = beam_hex().refined(1)  # the p=1/refine=1 solve mesh (64 elements)
VOCAB_ARR = tuple(material_fields(FINE, m) for m in VOCAB)
MAXITER = 150


def _skip_if_too_few(ndev):
    if ndev > jax.device_count():
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")


def dev_params():
    return [
        pytest.param(1),
        pytest.param(8, marks=pytest.mark.multidevice),
    ]


# -- the descendant map ------------------------------------------------------
def test_fine_descendants_cover_and_inherit():
    """Every fine element appears exactly once in its parent's row and
    carries the parent's attribute (for 1 and 2 refinements); the
    same-mesh map is the identity."""
    coarse = beam_hex(4, 2, 1)
    for times in (1, 2):
        fine = coarse.refined(times)
        desc = fine_descendants(coarse, fine)
        assert desc.shape == (coarse.nelem, 8**times)
        assert sorted(desc.ravel().tolist()) == list(range(fine.nelem))
        fattr, cattr = fine.attributes(), coarse.attributes()
        for e in range(coarse.nelem):
            assert (fattr[desc[e]] == cattr[e]).all()
    ident = fine_descendants(coarse, coarse)
    np.testing.assert_array_equal(ident[:, 0], np.arange(coarse.nelem))
    with pytest.raises(ValueError, match="not a uniform"):
        fine_descendants(coarse, beam_hex(12, 2, 1))


def test_level_restriction_is_exact_for_piecewise_constant_fields():
    """The solver's per-level restriction (pairwise halving tree over
    descendants) returns the attribute value EXACTLY on every level when
    the fine field is constant per coarse element — the property the
    bit-for-bit differential rests on — and the plain mean of a graded
    field otherwise."""
    solver = BatchedGMGSolver(beam_hex(), 2, 1, maxiter=MAXITER)
    fine = solver.fine_space.mesh
    lam_e, mu_e = material_fields(fine, MATS_B)
    field = np.asarray(lam_e)[None]  # (1, nelem_fine)
    for i, sp in enumerate(solver.spaces):
        lvl = np.asarray(solver._restrict_field(field, i))
        expect = material_fields(sp.mesh, MATS_B)[0][None]
        np.testing.assert_array_equal(lvl, expect)  # bitwise
    ramp = np.linspace(1.0, 50.0, fine.nelem)[None]
    lvl0 = np.asarray(solver._restrict_field(ramp, 0))
    desc = fine_descendants(solver.spaces[0].mesh, fine)
    np.testing.assert_allclose(lvl0[0], ramp[0][desc].mean(axis=1), rtol=1e-14)


# -- bit-for-bit differential: array vs dict ---------------------------------
def _requests(forms, keep=True):
    """5 mixed scenarios on the p=1/refine=1 key; row 1 has zero
    traction (born converged).  ``forms[i]`` picks dict or array
    materials for request i."""
    reqs = []
    for i in range(5):
        m = VOCAB[i % 3] if forms[i] == "dict" else VOCAB_ARR[i % 3]
        reqs.append(
            SolveRequest(
                p=1,
                refine=1,
                materials=m,
                traction=(0.0, 0.0, 0.0) if i == 1
                else (0.0, 1e-3 * (i % 2), -1e-2 * (1 + 0.3 * i)),
                rel_tol=1e-9 if i % 3 == 0 else 1e-5,
                keep_solution=keep,
            )
        )
    return reqs


_SERVICES: dict = {}


def _service(ndev: int) -> ElasticityService:
    if ndev not in _SERVICES:
        _SERVICES[ndev] = ElasticityService(
            max_batch=4,
            chunk_iters=3,
            maxiter=MAXITER,
            mesh=None if ndev == 1 else scenario_mesh(ndev),
        )
    return _SERVICES[ndev]


def assert_reports_bitwise(reps, refs, context):
    assert len(reps) == len(refs)
    for i, (a, b) in enumerate(zip(reps, refs)):
        ctx = f"{context} request {i}"
        assert a.iterations == b.iterations, ctx
        assert a.converged == b.converged, ctx
        assert a.born_converged == b.born_converged, ctx
        assert a.final_rel_norm == b.final_rel_norm, ctx  # bitwise
        assert (a.x is None) == (b.x is None), ctx
        if a.x is not None:
            np.testing.assert_array_equal(a.x, b.x, err_msg=ctx)


@pytest.mark.parametrize("ndev", dev_params())
@pytest.mark.parametrize("mode", ["generational", "continuous"])
def test_array_request_reproduces_dict_request_bit_for_bit(mode, ndev):
    """A piecewise-constant (lam_e, mu_e) array request must reproduce
    the equivalent attribute-dict request EXACTLY — same iteration
    counts, same flags, bitwise-equal solutions — under both scheduling
    policies, single-device and on an 8-device scenario mesh."""
    _skip_if_too_few(ndev)
    svc = _service(ndev)
    solve = svc.solve if mode == "generational" else svc.solve_continuous
    refs = solve(_requests(["dict"] * 5))
    reps = solve(_requests(["array"] * 5))
    assert_reports_bitwise(reps, refs, f"{mode} ndev={ndev} all-array")
    assert [r.born_converged for r in reps] == [False, True, False, False,
                                                False]
    mixed = solve(_requests(["dict", "array", "array", "dict", "array"]))
    assert_reports_bitwise(mixed, refs, f"{mode} ndev={ndev} mixed")


# -- continuous retire/refill: hypothesis property ---------------------------
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 6),
    mat_idx=st.lists(st.integers(0, 2), min_size=6, max_size=6),
    as_array=st.lists(st.booleans(), min_size=6, max_size=6),
    tight=st.lists(st.booleans(), min_size=6, max_size=6),
    zero_row=st.integers(-1, 5),
)
def test_continuous_mixed_forms_survive_retire_refill(
    n, mat_idx, as_array, tight, zero_row
):
    """Random mixed dict/array workloads through the continuous engine:
    replacing any subset of dict materials with their bitwise-equal
    array twins must change (a) no report — iterations, flags, bitwise
    solutions — and (b) no scheduling stat: the same refill count, the
    same number of prepare() calls (prep-row reuse keys on field
    content, so bitwise-equal heterogeneous rows short-circuit power
    iterations exactly like repeated dicts), the same cheap row-copy
    count.  Padding rows never surface: exactly the submitted tickets
    come back."""

    def reqs(use_arrays):
        return [
            SolveRequest(
                p=1,
                refine=1,
                materials=(
                    VOCAB_ARR[mat_idx[i]]
                    if (use_arrays and as_array[i])
                    else VOCAB[mat_idx[i]]
                ),
                traction=(0.0, 0.0, 0.0) if i == zero_row
                else (0.0, 0.0, -1e-2 * (1 + 0.1 * i)),
                rel_tol=1e-9 if tight[i] else 1e-4,
                keep_solution=True,
            )
            for i in range(n)
        ]

    svc = _service(1)
    base = dict(svc.stats)
    refs = svc.solve_continuous(reqs(use_arrays=False))
    d_dict = {k: svc.stats[k] - base[k] for k in
              ("refills", "prep_calls", "prep_row_copies", "rebuckets")}
    base = dict(svc.stats)
    reps = svc.solve_continuous(reqs(use_arrays=True))
    d_mix = {k: svc.stats[k] - base[k] for k in d_dict}
    assert len(reps) == n and svc.idle() and not svc._completed
    assert_reports_bitwise(reps, refs, f"hypothesis n={n}")
    for i, r in enumerate(reps):
        assert r.born_converged == (i == zero_row)
    assert d_mix == d_dict


def test_prep_reuse_engages_across_forms():
    """Deterministic engagement check: an alternating dict/array stream
    whose folded fields are all bitwise-equal pays prepare() exactly
    once — every continuous refill (either form) copies the prepared
    row — and still matches the generational reports."""
    svc = ElasticityService(max_batch=2, chunk_iters=3, maxiter=MAXITER)
    arr_a = material_fields(FINE, MATS_A)

    def reqs():
        return [
            SolveRequest(
                p=1, refine=1,
                materials=arr_a if i % 2 else MATS_A,
                rel_tol=1e-8,
                traction=(0.0, 0.0, -1e-2 * (i + 1)),
                keep_solution=True,
            )
            for i in range(6)
        ]

    reports = svc.solve_continuous(reqs())
    assert all(r.converged for r in reports)
    assert svc.stats["prep_calls"] == 1  # the initial batch only
    assert svc.stats["prep_row_copies"] >= 4  # every refill reused
    ref = ElasticityService(max_batch=2, maxiter=MAXITER).solve(reqs())
    for rc, rg in zip(reports, ref):
        assert rc.iterations == rg.iterations
        np.testing.assert_array_equal(rc.x, rg.x)


# -- genuinely heterogeneous fields ------------------------------------------
def test_graded_field_converges_and_differs_from_homogenized():
    """A graded ramp converges like any scenario, and its solution
    genuinely differs from the arithmetic-homogenized constant field —
    per-element resolution is real, not decorative."""
    svc = _service(1)
    ramp = np.linspace(50.0, 1.0, FINE.nelem)
    const = np.full(FINE.nelem, ramp.mean())
    rep_ramp, rep_const = svc.solve([
        SolveRequest(p=1, refine=1, materials=(ramp, 0.8 * ramp),
                     rel_tol=1e-8, keep_solution=True),
        SolveRequest(p=1, refine=1, materials=(const, 0.8 * const),
                     rel_tol=1e-8, keep_solution=True),
    ])
    assert rep_ramp.converged and rep_const.converged
    assert rep_ramp.final_rel_norm <= 1e-8
    diff = np.abs(rep_ramp.x - rep_const.x).max()
    assert diff > 1e-3 * np.abs(rep_const.x).max()


# -- operator-layer material forms -------------------------------------------
def test_operator_accepts_mixed_scenario_sequences():
    """ElasticityOperator normalizes every material form to per-element
    fields: a sequence of (lam_e, mu_e) pairs is recognized per entry
    (never mis-stacked as one pair), and dict/pair entries mix freely
    with bitwise-identical weighted fields."""
    from repro.core.operators import ElasticityOperator
    from repro.fem.space import H1Space

    sp = H1Space(beam_hex(2, 1, 1), 1)
    pair = material_fields(sp.mesh, MATS_A)
    by_dicts = ElasticityOperator(sp, materials=[MATS_A] * 3)
    by_pairs = ElasticityOperator(sp, materials=[pair] * 3)
    by_mixed = ElasticityOperator(sp, materials=[MATS_A, pair, pair])
    assert by_pairs.nbatch == by_mixed.nbatch == 3
    for op in (by_pairs, by_mixed):
        np.testing.assert_array_equal(
            np.asarray(op.lam_w), np.asarray(by_dicts.lam_w)
        )
        np.testing.assert_array_equal(
            np.asarray(op.mu_w), np.asarray(by_dicts.mu_w)
        )
    solo = ElasticityOperator(sp, materials=pair)
    assert solo.nbatch is None  # a raw pair is one scenario, not two
    # a length-2 sequence of 1-D pairs reads two ways with DIFFERENT
    # lambda/mu pairings — it must refuse, not guess (either spelling)
    for ambiguous in (
        [pair, pair],
        ([pair[0], pair[0]], [pair[1], pair[1]]),
    ):
        with pytest.raises(ValueError, match="ambiguous materials"):
            ElasticityOperator(sp, materials=ambiguous)
    # ... while the unambiguous numpy-stacked raw form still works
    stacked = ElasticityOperator(
        sp, materials=(np.stack([pair[0]] * 3), np.stack([pair[1]] * 3))
    )
    np.testing.assert_array_equal(
        np.asarray(stacked.lam_w), np.asarray(by_dicts.lam_w)
    )
    with pytest.raises(TypeError, match="sequence of dicts / pairs"):
        ElasticityOperator(sp, materials="steel")


# -- validation precision ----------------------------------------------------
def test_submit_validation_names_the_offense():
    svc = ElasticityService()
    ne = FINE.nelem
    ok = np.ones(ne)
    with pytest.raises(ValueError, match=r"lam_e has shape \(63,\), "
                                         r"expected \(64,\)"):
        svc.submit(SolveRequest(p=1, refine=1,
                                materials=(np.ones(63), ok)))
    bad = ok.copy()
    bad[17] = -2.0
    with pytest.raises(ValueError, match=r"mu_e\[17\] = -2\.0 is not "
                                         r"positive"):
        svc.submit(SolveRequest(p=1, refine=1, materials=(ok, bad)))
    nan = ok.copy()
    nan[3] = np.nan
    with pytest.raises(ValueError, match=r"lam_e\[3\]"):
        svc.submit(SolveRequest(p=1, refine=1, materials=(nan, ok)))
    with pytest.raises(ValueError, match=r"missing mesh attributes \[2\]"):
        svc.submit(SolveRequest(p=1, refine=1, materials={1: (1.0, 1.0)}))
    with pytest.raises(ValueError, match=r"attribute 2 has non-positive "
                                         r"coefficients"):
        svc.submit(SolveRequest(p=1, refine=1,
                                materials={1: (1.0, 1.0), 2: (0.0, 1.0)}))
    with pytest.raises(ValueError, match=r"attribute 1 must map to a "
                                         r"\(lambda, mu\) pair"):
        svc.submit(SolveRequest(p=1, refine=1,
                                materials={1: 50.0, 2: (1.0, 1.0)}))
    with pytest.raises(TypeError, match="dict or a .lam_e, mu_e. array"):
        svc.submit(SolveRequest(p=1, refine=1, materials="steel"))
    # the queue stayed clean: nothing was admitted
    assert svc.idle()


def test_pack_materials_validation_names_scenario():
    solver = BatchedGMGSolver(beam_hex(), 1, 1, maxiter=MAXITER)
    ne = solver.fine_space.nelem
    with pytest.raises(ValueError, match=r"scenario 1 materials: lam_e "
                                         r"has shape"):
        solver.pack_materials([MATS_A, (np.ones(3), np.ones(3))])
    with pytest.raises(ValueError, match="scenario 0 materials: missing "
                                         "mesh attributes"):
        solver.pack_materials([{1: (1.0, 1.0)}])
    with pytest.raises(TypeError, match="scenario 2"):
        solver.pack_materials([MATS_A, MATS_B, 7])
    # the raw stacked (lam_2d, mu_2d) pair is NOT a scenario list —
    # unpacking its rows would cross-pair lambda/mu across scenarios,
    # so it must refuse loudly instead
    lam2d = np.full((2, ne), 10.0)
    mu2d = np.full((2, ne), 1.0)
    with pytest.raises(TypeError, match="2-D array as a scenario entry"):
        solver.pack_materials((lam2d, mu2d))
    lam, mu = solver.pack_materials(list(zip(lam2d, mu2d)))  # the fix
    np.testing.assert_array_equal(np.asarray(lam), lam2d)
    np.testing.assert_array_equal(np.asarray(mu), mu2d)
    lam, mu = solver.pack_materials([MATS_A, material_fields(FINE, MATS_A)])
    np.testing.assert_array_equal(np.asarray(lam[0]), np.asarray(lam[1]))
    assert lam.shape == (2, ne)
