"""Fault-injection suite for the continuous serving engine (``-m
faults``): torn-checkpoint atomicity (in-process and SIGKILL-subprocess),
crash/restore differentials at scripted kill points, a random-schedule
crash/restore property test, elastic restore across device counts, the
step watchdog, and the full ``serve_solve`` kill/--resume CLI
round-trip.

The load-bearing invariant everywhere: a killed-and-restored run must
finish every accepted request with BITWISE-identical solutions,
iteration counts and flags to an undisturbed run — checkpoints land at
step boundaries and chunked resumption is exact, so a crash is invisible
in the numerics (see docs/FAULT_TOLERANCE.md).  Elastic restores onto a
different device count keep that bitwise bar while the old bucket still
divides the new mesh, and degrade only to the usual cross-program-shape
~ulp wobble when re-bucketing.
"""

import glob
import json
import os
import signal
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.fem.mesh import beam_hex
from repro.serve import ElasticityService, ServiceRecovery, SolveRequest
from repro.solvers.batched import BatchedGMGSolver

from tests._hypothesis_compat import given, settings, st
from tests.faultinject import (
    FaultInjector,
    SimulatedCrash,
    run_schedule,
    torn_checkpoint_write,
)

pytestmark = pytest.mark.faults

SRC_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)

MATS_A = {1: (50.0, 50.0), 2: (1.0, 1.0)}
MATS_B = {1: (80.0, 60.0), 2: (2.0, 1.0)}
MATS_C = {1: (9.0, 9.0), 2: (1.0, 3.0)}


@pytest.fixture(scope="module")
def shared_solver():
    """One compiled p=1/refine=0 solver pre-seeded into every service
    these tests build (matching the service's solver config), so each
    fresh service skips the rebuild/recompile."""
    return BatchedGMGSolver(beam_hex(), 0, 1, maxiter=200)


def _req(i: int, keep: bool = True) -> SolveRequest:
    mats = (MATS_A, MATS_B, MATS_C)[i % 3]
    return SolveRequest(
        p=1,
        refine=0,
        materials=mats,
        traction=(0.0, 2e-3 * (i % 2), -1e-2 * (1.0 + 0.25 * i)),
        rel_tol=1e-8 if i % 2 else 1e-10,
        keep_solution=keep,
    )


def _service(solver=None, **kw) -> ElasticityService:
    kw.setdefault("max_batch", 4)
    kw.setdefault("chunk_iters", 2)
    svc = ElasticityService(**kw)
    if solver is not None:
        svc._solvers[svc.group_key(_req(0))] = solver
    return svc


def _by_ticket(reports):
    out = {r.ticket: r for r in reports}
    assert len(out) == len(reports), "duplicate tickets surfaced"
    return out


def assert_reports_identical(base, got, *, x_mode="bitwise"):
    """Differential oracle: same tickets, same iteration counts/flags,
    and (x_mode="bitwise") bit-identical solutions and residual norms —
    or allclose for cross-bucket-shape elastic restores."""
    assert set(base) == set(got)
    for t in sorted(base):
        a, b = base[t], got[t]
        assert a.iterations == b.iterations, (t, a.iterations, b.iterations)
        assert a.converged == b.converged, t
        assert a.precision == b.precision, t
        assert a.fallback == b.fallback, t
        assert not a.born_converged and not b.born_converged, (
            "padding/born-converged rows must never surface"
        )
        if x_mode == "bitwise":
            assert a.final_rel_norm == b.final_rel_norm, t
            np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
        else:
            np.testing.assert_allclose(
                a.final_rel_norm, b.final_rel_norm, rtol=1e-6, atol=1e-300
            )
            np.testing.assert_allclose(
                np.asarray(a.x), np.asarray(b.x), rtol=1e-9, atol=1e-14
            )


# -- torn checkpoints -------------------------------------------------------
def test_torn_checkpoint_write_in_process(tmp_path):
    """A crash mid-checkpoint-write leaves a manifest-less staging dir;
    latest()/restore skip it and the next good save GCs it."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"a": np.arange(4.0), "b": np.ones(3)}, extra={"k": 1})
    with torn_checkpoint_write(after_leaves=1):
        with pytest.raises(SimulatedCrash):
            mgr.save(2, {"a": np.zeros(4), "b": np.ones(3)}, extra={"k": 2})
    assert glob.glob(str(tmp_path / "*.tmp-*")), "expected a torn staging dir"
    assert mgr.latest() == 1
    items, extra, step = mgr.restore_latest_items()
    assert step == 1 and extra == {"k": 1}
    np.testing.assert_array_equal(items["a"], np.arange(4.0))
    mgr.save(3, {"a": np.full(4, 3.0), "b": np.ones(3)}, extra={"k": 3})
    assert not glob.glob(str(tmp_path / "*.tmp-*")), "stale tmp not GCed"
    assert mgr.latest() == 3


def test_sigkill_mid_checkpoint_write_subprocess(tmp_path):
    """Real SIGKILL between two leaf writes: the parent process finds an
    intact older checkpoint and a skippable torn one."""
    script = """
import os, signal, sys
import numpy as np
from repro.checkpoint.manager import CheckpointManager

mgr = CheckpointManager(sys.argv[1], keep=3)
mgr.save(1, {"a": np.arange(4.0), "b": np.ones(3)}, extra={"k": 1})
orig, calls = np.save, [0]
def bomb(path, arr, *a, **kw):
    calls[0] += 1
    if calls[0] > 1:
        os.kill(os.getpid(), signal.SIGKILL)
    return orig(path, arr, *a, **kw)
np.save = bomb
mgr.save(2, {"a": np.zeros(4), "b": np.ones(3)}, extra={"k": 2})
"""
    proc = subprocess.run(
        [sys.executable, "-c", script, str(tmp_path)],
        env={**os.environ, "PYTHONPATH": SRC_DIR},
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == -signal.SIGKILL, proc.stderr
    mgr = CheckpointManager(str(tmp_path), keep=3)
    assert mgr.latest() == 1
    items, extra, step = mgr.restore_latest_items()
    assert step == 1
    np.testing.assert_array_equal(items["a"], np.arange(4.0))


# -- crash/restore differentials -------------------------------------------
ARRIVALS = [(0, 0), (0, 1), (0, 2), (1, 3), (2, 4), (4, 5)]


def _schedule():
    return [(s, _req(i)) for s, i in ARRIVALS]


@pytest.mark.parametrize(
    "point", ["mid-chunk", "between-retire-and-refill"]
)
def test_crash_restore_differential(tmp_path, shared_solver, point):
    """Kill the engine at a scripted point mid-run; a fresh service
    restored from the last checkpoint and driven through the SAME
    arrival schedule drains bitwise-identical reports."""
    base = _by_ticket(run_schedule(_service(shared_solver), _schedule()))
    assert set(base) == set(range(len(ARRIVALS)))

    svc = _service(shared_solver)
    rec = ServiceRecovery(svc, str(tmp_path), every=1)
    FaultInjector(svc).arm(point, at_step=2)
    with pytest.raises(SimulatedCrash):
        run_schedule(svc, _schedule(), rec)
    assert rec.manager.latest() is not None

    svc2 = _service(shared_solver)
    rec2 = ServiceRecovery(svc2, str(tmp_path), every=1)
    assert rec2.restore()
    got = _by_ticket(run_schedule(svc2, _schedule(), rec2))
    assert_reports_identical(base, got)
    assert svc2.stats["restores"] == 1


def test_crash_during_checkpoint_then_resume(tmp_path, shared_solver):
    """Die MID-CHECKPOINT (torn write) and restart: the torn checkpoint
    is skipped, the previous one restores, and the drained reports are
    still bitwise identical — a checkpoint crash costs progress, never
    correctness."""
    up_front = [(0, _req(i)) for i in range(len(ARRIVALS))]
    base = _by_ticket(run_schedule(_service(shared_solver), up_front))

    svc = _service(shared_solver)
    rec = ServiceRecovery(svc, str(tmp_path), every=1)
    for r in [_req(i) for i in range(len(ARRIVALS))]:
        svc.submit(r)
    svc.step()
    rec.maybe_checkpoint()
    svc.step()
    with torn_checkpoint_write(after_leaves=3):
        with pytest.raises(SimulatedCrash):
            rec.checkpoint()
    assert rec.manager.latest() == 1  # step-2 checkpoint is torn

    svc2 = _service(shared_solver)
    rec2 = ServiceRecovery(svc2, str(tmp_path))
    assert rec2.restore()
    assert svc2._step_index == 1
    while not svc2.idle():
        svc2.step()
    got = _by_ticket(svc2.drain())
    assert_reports_identical(base, got)


def test_restore_preconditions(tmp_path, shared_solver):
    """restore() demands an empty service, reports absence honestly, and
    refuses a max_batch mismatch loudly."""
    svc = _service(shared_solver)
    rec = ServiceRecovery(svc, str(tmp_path))
    assert rec.restore() is False  # empty dir: nothing to restore
    svc.submit(_req(0))
    svc.step()
    rec.checkpoint()
    with pytest.raises(RuntimeError, match="empty service"):
        rec.restore()
    svc_bad = _service(shared_solver, max_batch=8)
    with pytest.raises(ValueError, match="max_batch"):
        ServiceRecovery(svc_bad, str(tmp_path)).restore()


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_random_schedule_crash_restore(seed, tmp_path_factory):
    """Property: for a RANDOM arrival/kill schedule, restart-and-drain
    is observationally identical to never having crashed (solutions,
    iteration counts, flags, tickets — bitwise), and padding rows never
    surface.  Runs under hypothesis in CI; skipped when the local
    container lacks it (tests/_hypothesis_compat)."""
    tmp_path = tmp_path_factory.mktemp(f"faults{seed}")
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 7))
    steps = np.sort(rng.integers(0, 5, size=n))
    arrivals = [(int(s), _req(i)) for i, s in enumerate(steps)]
    point = FaultInjector.POINTS[int(rng.integers(0, 2))]
    kill_at = int(rng.integers(1, 6))

    solver = BatchedGMGSolver(beam_hex(), 0, 1, maxiter=200)
    base = _by_ticket(run_schedule(_service(solver), arrivals))
    assert set(base) == set(range(n))

    svc = _service(solver)
    rec = ServiceRecovery(svc, str(tmp_path), every=1)
    FaultInjector(svc).arm(point, at_step=kill_at)
    try:
        got = _by_ticket(run_schedule(svc, arrivals, rec))
    except SimulatedCrash:
        svc2 = _service(solver)
        rec2 = ServiceRecovery(svc2, str(tmp_path), every=1)
        assert rec2.restore()
        got = _by_ticket(run_schedule(svc2, arrivals, rec2))
    assert_reports_identical(base, got)


# -- elastic restore across device counts ----------------------------------
@pytest.mark.multidevice
def test_elastic_restore_8_to_4_bitwise(tmp_path):
    """A solve checkpointed on 8 devices restores onto a 4-device mesh
    through the identity path (the old bucket still divides the new
    mesh): every leaf lands with axis-0 NamedSharding on the survivor
    mesh and the drained reports are BITWISE identical — sharding stays
    a pure implementation detail across the restart."""
    from repro.distributed.elastic import (
        elastic_scenario_mesh,
        simulate_failures,
    )
    from repro.distributed.sharding import scenario_layout_mismatches

    mesh8 = elastic_scenario_mesh()
    assert mesh8.devices.size == 8
    reqs = [_req(i) for i in range(6)]

    svc0 = _service(max_batch=8, mesh=mesh8)
    base = _by_ticket(run_schedule(svc0, [(0, r) for r in reqs]))

    svc1 = _service(max_batch=8, mesh=mesh8)
    rec1 = ServiceRecovery(svc1, str(tmp_path), every=1)
    for r in reqs:
        svc1.submit(r)
    svc1.step()
    rec1.maybe_checkpoint()

    # 4 devices fail; the survivors' scenario mesh hosts the restore.
    mesh4 = elastic_scenario_mesh(simulate_failures(jax.devices(), 4))
    assert mesh4.devices.size == 4
    svc2 = _service(max_batch=8, mesh=mesh4)
    rec2 = ServiceRecovery(svc2, str(tmp_path))
    assert rec2.restore()
    for fl in svc2._flights.values():
        assert fl.bucket % 4 == 0  # identity path: bucket kept
        assert fl.pending_reset is None
        assert scenario_layout_mismatches(fl.state, svc2.mesh) == []
        assert scenario_layout_mismatches(fl.prep, svc2.mesh) == []
    got = _by_ticket(run_schedule(svc2, [(0, r) for r in reqs], rec2))
    assert_reports_identical(base, got)


@pytest.mark.multidevice
def test_elastic_restore_2_to_8_rebucket(tmp_path):
    """Growing 2 -> 8 devices forces a re-bucket (old bucket 4 does not
    divide the 8-device mesh): take_rows re-lays the live rows onto a
    device-aligned bucket, filler rows restore as born-converged
    padding, iteration counts and flags stay exact, and solutions agree
    to the usual cross-bucket-shape fusion wobble."""
    from repro.distributed.sharding import (
        scenario_layout_mismatches,
        scenario_mesh,
    )

    mesh2 = scenario_mesh(2)
    reqs = [_req(i) for i in range(5)]

    svc0 = _service(mesh=mesh2)
    base = _by_ticket(run_schedule(svc0, [(0, r) for r in reqs]))

    svc1 = _service(mesh=mesh2)
    rec1 = ServiceRecovery(svc1, str(tmp_path), every=1)
    for r in reqs:
        svc1.submit(r)
    svc1.step()
    rec1.maybe_checkpoint()
    old_buckets = [fl.bucket for fl in svc1._flights.values()]
    assert any(b % 8 for b in old_buckets), "schedule must force a re-bucket"

    mesh8 = scenario_mesh(8)
    svc2 = _service(mesh=mesh8)
    rec2 = ServiceRecovery(svc2, str(tmp_path))
    assert rec2.restore()
    for fl in svc2._flights.values():
        assert fl.bucket % 8 == 0
        assert fl.pending_reset is not None and fl.pending_reset.any()
        assert scenario_layout_mismatches(fl.state, svc2.mesh) == []
    got = _by_ticket(run_schedule(svc2, [(0, r) for r in reqs], rec2))
    assert_reports_identical(base, got, x_mode="close")
    assert svc2.stats["restores"] == 1


# -- watchdog ----------------------------------------------------------------
def test_watchdog_fires_counter_and_span():
    """A step exceeding the armed timeout increments watchdog_fires and
    emits a watchdog_fire span on the engine track (the first step of a
    fresh service compiles, so it dwarfs the 1ms timeout)."""
    from repro.obs import SpanRecorder

    svc = _service()  # no pre-seeded solver: first step compiles
    svc.attach_spans(SpanRecorder())
    fired = []
    wd = svc.attach_watchdog(1e-3, on_timeout=fired.append)
    svc.submit(_req(0))
    while not svc.idle():
        svc.step()
    svc.drain()
    assert wd.timeouts >= 1
    assert fired and fired[0] > 1e-3
    assert svc.stats["watchdog_fires"] >= 1
    assert svc.spans.count("watchdog_fire") >= 1


# -- CLI acceptance: SIGKILL + --resume -------------------------------------
@pytest.mark.slow
def test_cli_kill_resume_bitwise(tmp_path):
    """The ISSUE acceptance run, automated: serve_solve --continuous
    SIGKILLed mid-flight (--kill-after-steps) and restarted with
    --resume completes every accepted request with bitwise-identical
    solutions and iteration counts vs an uninterrupted run — compared
    through --report-out JSON lines (solution vectors by sha256)."""
    common = [
        sys.executable, "-m", "repro.launch.serve_solve", "--continuous",
        "--n-requests", "6", "--max-batch", "4", "--p", "1",
        "--refine", "0", "--rel-tol", "1e-10", "--chunk-iters", "2",
    ]
    env = {**os.environ, "PYTHONPATH": SRC_DIR}
    run = lambda extra: subprocess.run(
        common + extra, env=env, cwd=tmp_path,
        capture_output=True, text=True, timeout=600,
    )
    a = run(["--report-out", "a.jsonl"])
    assert a.returncode == 0, a.stderr
    b = run([
        "--checkpoint-dir", "ckpt", "--checkpoint-every", "1",
        "--kill-after-steps", "1", "--report-out", "b.jsonl",
    ])
    assert b.returncode == -signal.SIGKILL, (b.returncode, b.stderr)
    assert not (tmp_path / "b.jsonl").exists()  # died mid-flight
    c = run([
        "--checkpoint-dir", "ckpt", "--resume", "--report-out", "c.jsonl",
    ])
    assert c.returncode == 0, c.stderr
    assert "resumed from checkpoint step" in c.stdout

    load = lambda p: {
        rec["ticket"]: rec
        for rec in map(json.loads, (tmp_path / p).read_text().splitlines())
    }
    base, got = load("a.jsonl"), load("c.jsonl")
    assert set(base) == set(got) == set(range(6))
    for t in base:
        assert base[t] == got[t], (t, base[t], got[t])
        assert base[t]["x_sha256"] is not None
