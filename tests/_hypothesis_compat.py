"""Optional-hypothesis shim.

``tests/test_basis.py`` (and three siblings) used to hard-import
hypothesis, so a container without it aborted the WHOLE tier-1 suite at
collection.  Importing ``given``/``settings``/``st`` from here instead
keeps every deterministic test runnable: when hypothesis is installed
the real objects are re-exported; when it is missing, ``@given(...)``
degrades to ``pytest.mark.skip`` on just the property-based tests
(the moral equivalent of ``pytest.importorskip("hypothesis")`` scoped
per-test instead of per-module).

Install the real thing with ``pip install -r requirements-dev.txt``.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` so module-level
        ``st.integers(...)``-style decorator arguments still evaluate."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
