"""Elasticity operator tests: assembly-level agreement, linear-operator
properties (property-based), constrained SPD structure, diagonal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.operators import ASSEMBLY_LEVELS, ElasticityOperator
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space


@pytest.fixture(scope="module")
def small_mesh():
    return beam_hex(2, 1, 1).refined()  # 16 elements, two materials


def _rand_x(space, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((space.nscalar, 3))
    )


@pytest.mark.parametrize("p", [1, 2, 3, 4])
@pytest.mark.parametrize("assembly", ASSEMBLY_LEVELS[1:])
def test_assembly_levels_agree_with_fa(small_mesh, p, assembly):
    space = H1Space(small_mesh, p)
    x = _rand_x(space)
    y_fa = ElasticityOperator(space, assembly="fa").apply(x)
    y = ElasticityOperator(space, assembly=assembly).apply(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_fa), rtol=1e-12,
                               atol=1e-12 * float(jnp.abs(y_fa).max()))


@pytest.mark.parametrize("p", [1, 2, 4])
def test_operator_symmetry(small_mesh, p):
    """x^T A y == y^T A x (the bilinear form is symmetric)."""
    space = H1Space(small_mesh, p)
    op = ElasticityOperator(space, assembly="paop")
    x, y = _rand_x(space, 1), _rand_x(space, 2)
    lhs = float(jnp.vdot(x, op.apply(y)))
    rhs = float(jnp.vdot(y, op.apply(x)))
    assert abs(lhs - rhs) <= 1e-10 * max(abs(lhs), 1.0)


@pytest.mark.parametrize("p", [1, 2])
def test_operator_positive_semidefinite_and_kernel(small_mesh, p):
    """A is PSD; rigid translations are in the kernel (pure Neumann)."""
    space = H1Space(small_mesh, p)
    op = ElasticityOperator(space, assembly="paop")
    x = _rand_x(space, 3)
    assert float(jnp.vdot(x, op.apply(x))) >= -1e-10
    # constant displacement field -> zero strain -> zero action
    const = jnp.ones((space.nscalar, 3))
    y = op.apply(const)
    assert float(jnp.abs(y).max()) < 1e-10


@given(a=st.floats(-3, 3, allow_nan=False), b=st.floats(-3, 3, allow_nan=False),
       p=st.sampled_from([1, 2, 3]))
@settings(max_examples=12, deadline=None)
def test_operator_linearity(a, b, p):
    mesh = beam_hex(2, 1, 1)
    space = H1Space(mesh, p)
    op = ElasticityOperator(space, assembly="paop")
    x, y = _rand_x(space, 4), _rand_x(space, 5)
    lhs = op.apply(a * x + b * y)
    rhs = a * op.apply(x) + b * op.apply(y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-9)


@pytest.mark.parametrize("p", [1, 2, 3])
def test_matrix_free_diagonal_matches_fa(small_mesh, p):
    space = H1Space(small_mesh, p)
    d_fa = ElasticityOperator(space, assembly="fa").diagonal()
    d_mf = ElasticityOperator(space, assembly="paop").diagonal()
    np.testing.assert_allclose(np.asarray(d_mf), np.asarray(d_fa), rtol=1e-10)


@pytest.mark.parametrize("p", [2])
def test_constrained_operator_identity_on_essential(small_mesh, p):
    """ConstrainedOperator acts as identity on Dirichlet DoFs."""
    space = H1Space(small_mesh, p)
    cop = ElasticityOperator(space, assembly="paop").constrained()
    x = _rand_x(space, 6)
    y = cop(x)
    mask = np.asarray(cop.ess_mask if hasattr(cop, "ess_mask") else
                      ElasticityOperator(space).ess_mask)
    np.testing.assert_allclose(
        np.asarray(y)[mask], np.asarray(x)[mask], rtol=1e-12
    )


def test_memory_footprint_ordering(small_mesh):
    """PA stores O(q-points) data; FA grows much faster with p (paper
    Fig. 4 memory story)."""
    for p in (2, 4):
        space = H1Space(small_mesh, p)
        m_fa = ElasticityOperator(space, assembly="fa").memory_bytes()
        m_pa = ElasticityOperator(space, assembly="paop").memory_bytes()
        assert m_pa < m_fa
