"""Precision-policy suite: dtype invariance of the prep/state pytrees,
f32/mixed-vs-f64 differentials, the honest mixed-tolerance acceptance
run, engineered stagnation -> automatic f64 fallback (solver and
service level), and the policy axis of the compile cache.

Run alone by the ``precision`` CI lane
(``pytest -q tests/test_precision.py -m "not slow"``); the slow-marked
acceptance test rides in the full lane.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import (
    PRECISION_POLICIES,
    PrecisionPolicy,
    resolve_precision,
)
from repro.fem.mesh import beam_hex
from repro.launch.solve import solve_beam
from repro.serve.elasticity_service import ElasticityService, SolveRequest
from repro.solvers.batched import BatchedGMGSolver
from repro.solvers.chebyshev import ChebyshevSmoother

MATS = {1: (50.0, 50.0), 2: (1.0, 1.0)}
TR = (0.0, 0.0, -1e-2)


def _true_rel_mnorm(f64_solver, mats, tractions, x):
    """Per-row honest convergence measure: sqrt((M r, r) / (M b, b))
    with r = b - A x, everything (operator, preconditioner, arithmetic)
    at f64 — the same B-norm the solver's rel_tol thresholds live in,
    recomputed from scratch so recurrence drift cannot hide."""
    assert f64_solver.precision.name == "f64"
    s = len(mats)
    lam, mu = f64_solver.pack_materials(mats)
    prep = f64_solver.prepare(
        lam, mu, np.ones(s, bool), f64_solver.empty_prep(s)
    )
    _, _, A, M = f64_solver._build_from_prep(prep)
    b = f64_solver._rhs(jnp.asarray(np.asarray(tractions), jnp.float64))
    r = b - A(jnp.asarray(np.asarray(x), jnp.float64))

    def mnorm(v):
        return np.sqrt(
            np.asarray(jnp.sum((M(v) * v).reshape(s, -1), axis=1))
        )

    return mnorm(r) / mnorm(b)


# -- policy resolution -------------------------------------------------------


def test_policy_registry_dtypes():
    f64 = PRECISION_POLICIES["f64"]
    f32 = PRECISION_POLICIES["f32"]
    mixed = PRECISION_POLICIES["mixed"]
    bf16 = PRECISION_POLICIES["mixed-bf16"]
    assert f64.uniform and not f64.reduced
    assert f32.uniform and f32.reduced
    assert not mixed.uniform and mixed.reduced
    assert (mixed.solve_dtype, mixed.precond_dtype, mixed.coarse_dtype) == (
        jnp.float64, jnp.float32, jnp.float32,
    )
    # bf16 smooths in bf16 but NEVER factors in it (too few mantissa
    # bits for a Cholesky): the coarse tier stays f32.
    assert bf16.precond_dtype == jnp.bfloat16
    assert bf16.coarse_dtype == jnp.float32


def test_resolve_precision_names_dtypes_and_conflicts():
    assert resolve_precision("mixed") is PRECISION_POLICIES["mixed"]
    # legacy dtype spelling -> the matching uniform policy
    assert resolve_precision(None, jnp.float32) is PRECISION_POLICIES["f32"]
    assert resolve_precision(None, np.float64) is PRECISION_POLICIES["f64"]
    assert resolve_precision(None) is PRECISION_POLICIES["f64"]
    # a policy object passes through untouched
    pol = PRECISION_POLICIES["mixed"]
    assert resolve_precision(pol) is pol
    with pytest.raises(ValueError):
        resolve_precision("float16")  # unknown name
    with pytest.raises(ValueError):
        resolve_precision("mixed", jnp.float32)  # conflicting dtype


# -- pytree dtype invariance (the bugfix-sweep regressions) ------------------


def test_pad_scenarios_respects_solver_dtype():
    """Regression: pad_scenarios used to cast tractions/tolerances to a
    hard-coded np.float64, silently promoting (and re-tracing) every
    non-f64 solve."""
    s32 = BatchedGMGSolver(beam_hex(), 0, 1, precision="f32")
    mats, tr, rel, n = s32.pad_scenarios(
        [MATS], [TR], 1e-6, n=4
    )
    assert n == 1 and len(mats) == 4
    assert tr.dtype == np.float32 and tr.shape == (4, 3)
    assert rel.dtype == np.float32 and rel.shape == (4,)
    # padding rows are born converged: zero traction, reused materials
    np.testing.assert_array_equal(tr[1:], 0.0)

    s64 = BatchedGMGSolver(beam_hex(), 0, 1)
    _, tr64, rel64, _ = s64.pad_scenarios([MATS], [TR], 1e-6, n=2)
    assert tr64.dtype == np.float64 and rel64.dtype == np.float64


@pytest.mark.parametrize("policy", ["f32", "mixed"])
def test_prep_leaves_carry_policy_dtypes(policy):
    s = BatchedGMGSolver(beam_hex(), 0, 2, precision=policy)
    pol = s.precision
    prep = s.empty_prep(2)
    for name in ("lam_w", "mu_w", "dinv", "lmax"):
        for leaf in prep[name]:
            assert leaf.dtype == pol.precond_dtype, (policy, name)
    assert prep["chol"].dtype == pol.coarse_dtype
    if pol.solve_dtype != pol.precond_dtype:  # split fine level
        assert prep["lam_w_solve"].dtype == pol.solve_dtype
        assert prep["mu_w_solve"].dtype == pol.solve_dtype
    else:
        assert "lam_w_solve" not in prep
    # prepare() must preserve every dtype (a promotion here would
    # re-trace run_chunk against a different pytree signature)
    lam, mu = s.pack_materials([MATS, MATS])
    out = s.prepare(lam, mu, np.ones(2, bool), prep)
    for k, v in prep.items():
        got = out[k] if not isinstance(v, tuple) else out[k][0]
        want = v if not isinstance(v, tuple) else v[0]
        assert jnp.asarray(got).dtype == jnp.asarray(want).dtype, (policy, k)


@pytest.mark.parametrize("policy", ["f64", "f32", "mixed"])
def test_state_leaves_carry_solve_dtype(policy):
    """Every float leaf of the resumable Krylov state lives at the
    policy's SOLVE dtype (the honest-accounting tier); the masks and
    counters stay int32/bool."""
    s = BatchedGMGSolver(beam_hex(), 0, 1, precision=policy)
    st = s.empty_state(2)
    sdt = np.dtype(s.precision.solve_dtype)
    for fld in dataclasses.fields(st):
        leaf = np.asarray(getattr(st, fld.name))
        if fld.name in ("iters", "stall"):
            assert leaf.dtype == np.int32, fld.name
        elif fld.name in ("active", "stalled"):
            assert leaf.dtype == np.bool_, fld.name
        else:
            assert leaf.dtype == sdt, (policy, fld.name)


def test_chebyshev_coefficients_follow_block_dtype():
    """Regression: the Chebyshev recurrence coefficients must live in
    the vector-block dtype, not lmax's — an f64 lmax against f32 blocks
    silently promoted every d/z update.  Also: a zero slipping into the
    diagonal must not poison dinv with inf."""
    n = 8
    A = lambda x: 2.0 * x
    # f64 lmax over an f32 block (the mixed hierarchy's shape): the
    # recurrence must stay f32 end to end
    sm = ChebyshevSmoother(
        A=A,
        dinv=0.5 * jnp.ones((n, 3), jnp.float32),
        lmax=jnp.asarray(1.0, jnp.float64),
    )
    out32 = sm(jnp.ones((n, 3), jnp.float32))
    assert out32.dtype == jnp.float32
    assert bool(jnp.isfinite(out32).all())
    # zero-diagonal guard: setup() must not produce inf in dinv
    diag = jnp.ones((n, 3), jnp.float64).at[0, 0].set(0.0)
    sm2 = ChebyshevSmoother.setup(A, diag, (n, 3), jnp.float64)
    assert bool(jnp.isfinite(sm2.dinv).all())
    out64 = sm2(jnp.ones((n, 3), jnp.float64))
    assert out64.dtype == jnp.float64 and bool(jnp.isfinite(out64).all())


def test_stall_detector_armed_only_for_reduced_policies():
    """The f64 program must stay bit-identical to the pre-stagnation
    build: stall_iters=0 compiles the detector out entirely."""
    assert BatchedGMGSolver(beam_hex(), 0, 1).stall_iters == 0
    assert BatchedGMGSolver(beam_hex(), 0, 1, precision="f32").stall_iters > 0
    assert (
        BatchedGMGSolver(beam_hex(), 0, 1, precision="mixed").stall_iters > 0
    )


# -- differentials against the f64 oracle ------------------------------------


def test_f32_matches_f64_at_loose_tolerance():
    mats = [MATS, {1: (10.0, 8.0), 2: (2.0, 1.5)}]
    trs = [TR, (0.0, 5e-3, -5e-3)]
    s64 = BatchedGMGSolver(beam_hex(), 0, 1)
    s32 = BatchedGMGSolver(beam_hex(), 0, 1, precision="f32")
    r64 = s64.solve(mats, trs, 1e-5)
    r32 = s32.solve(mats, trs, 1e-5)
    assert bool(r64.converged.all()) and bool(r32.converged.all())
    assert not bool(r32.fallback.any())  # 1e-5 is above the f32 floor
    assert r32.x.dtype == jnp.float32
    # honest check at f64: the f32 answer really sits at <= 1e-5
    rel = _true_rel_mnorm(s64, mats, trs, r32.x)
    assert (rel <= 1e-5).all(), rel


def test_mixed_matches_f64_iterations_and_tolerance():
    mats = [MATS, {1: (10.0, 8.0), 2: (2.0, 1.5)}]
    trs = [TR, (0.0, 5e-3, -5e-3)]
    s64 = BatchedGMGSolver(beam_hex(), 0, 1)
    smx = BatchedGMGSolver(beam_hex(), 0, 1, precision="mixed")
    r64 = s64.solve(mats, trs, 1e-8)
    rmx = smx.solve(mats, trs, 1e-8)
    assert bool(rmx.converged.all()) and not bool(rmx.fallback.any())
    assert rmx.x.dtype == jnp.float64  # outer Krylov at solve dtype
    rel = _true_rel_mnorm(s64, mats, trs, rmx.x)
    assert (rel <= 1e-8).all(), rel
    it64, itmx = np.asarray(r64.iterations), np.asarray(rmx.iterations)
    assert (itmx <= (1.3 * it64).astype(int) + 1).all(), (it64, itmx)


def test_scalar_solve_beam_precision_axis():
    f64 = solve_beam(1, 0, rel_tol=1e-6)
    mix = solve_beam(1, 0, rel_tol=1e-6, precision="mixed")
    assert f64.precision == "f64" and mix.precision == "mixed"
    assert mix.final_rel_norm <= 1e-6  # f64 residual accounting
    assert mix.iterations <= int(1.3 * f64.iterations) + 1


@pytest.mark.slow
def test_mixed_tolerance_batch16_acceptance():
    """The PR's acceptance run: a 16-row mixed-tolerance, mixed-material
    corpus under the ``mixed`` policy converges EVERY row to its
    requested tolerance — verified against a from-scratch f64 residual,
    not the solver's own recurrence — within 1.3x the f64 iteration
    count, with no fallback engaged."""
    rng = np.random.default_rng(7)
    ne = beam_hex().nelem * 8  # refine=1
    mats, trs, tols = [], [], []
    for i in range(16):
        if i % 3 == 0:
            ramp = np.linspace(50.0, 1.0, ne) * (1.0 + 0.1 * i)
            mats.append((ramp, 0.8 * ramp))
        else:
            mats.append({1: (50.0 / (i + 1), 50.0), 2: (1.0, 1.0 + 0.2 * i)})
        trs.append((0.0, float(rng.uniform(-5e-3, 5e-3)), -1e-2))
        tols.append(float(10.0 ** rng.uniform(-10, -4)))
    s64 = BatchedGMGSolver(beam_hex(), 1, 1)
    smx = BatchedGMGSolver(beam_hex(), 1, 1, precision="mixed")
    r64 = s64.solve(mats, trs, tols)
    rmx = smx.solve(mats, trs, tols)
    assert bool(r64.converged.all())
    assert bool(rmx.converged.all())
    assert not bool(rmx.fallback.any())
    rel = _true_rel_mnorm(s64, mats, trs, rmx.x)
    assert (rel <= np.asarray(tols)).all(), (rel, tols)
    it64 = np.asarray(r64.iterations)
    itmx = np.asarray(rmx.iterations)
    assert (itmx <= (1.3 * it64).astype(int) + 1).all(), (it64, itmx)


# -- engineered stagnation -> f64 fallback -----------------------------------


def test_solver_level_stagnation_falls_back_to_f64():
    """A tolerance below the f32 residual floor stalls (or audits as
    dishonest); solve() re-solves exactly that row on the f64 twin and
    merges it back with honest accounting."""
    s32 = BatchedGMGSolver(beam_hex(), 0, 1, precision="f32")
    res = s32.solve([MATS, MATS], [TR, TR], [1e-4, 1e-13])
    fb = np.asarray(res.fallback)
    assert not fb[0] and fb[1]  # only the impossible row fell back
    assert bool(res.converged.all())
    assert res.x.dtype == jnp.float64  # merged result promoted
    # honest cost accounting: the fallback row paid both passes
    assert int(res.iterations[1]) > int(res.iterations[0])
    # 1e-13 sits below even f64's recurrence-drift floor for this
    # system, so the interesting honest claim is that the f64 re-solve
    # pushed the TRUE residual orders of magnitude past the f32 floor
    # (~1e-4 in this norm), not that it literally reached 1e-13
    s64 = BatchedGMGSolver(beam_hex(), 0, 1)
    rel = _true_rel_mnorm(s64, [MATS, MATS], [TR, TR], res.x)
    assert rel[1] <= 1e-6


def test_service_level_stagnation_requeues_onto_f64():
    svc = ElasticityService(max_batch=2)
    reports = svc.solve_continuous([
        SolveRequest(p=1, refine=0, rel_tol=1e-4, precision="f32"),
        SolveRequest(p=1, refine=0, rel_tol=1e-13, precision="f32"),
    ])
    ok, hard = reports
    assert ok.precision == "f32" and not ok.fallback
    assert hard.precision == "f64" and hard.fallback
    assert all(r.converged for r in reports)
    assert all(r.final_rel_norm <= r.request.rel_tol for r in reports)
    assert svc.stats["precision_fallbacks"] >= 1


def test_generational_path_reports_fallback():
    svc = ElasticityService(max_batch=2, precision="f32")
    reports = svc.solve([
        SolveRequest(p=1, refine=0, rel_tol=1e-4),
        SolveRequest(p=1, refine=0, rel_tol=1e-13),
    ])
    assert [r.fallback for r in reports] == [False, True]
    assert all(r.converged for r in reports)
    assert all(r.precision == "f32" for r in reports)  # solver-level merge


# -- the policy axis of the compile cache ------------------------------------


def test_policies_get_distinct_cache_entries_and_no_retrace():
    """Two policies never share a compiled program (their group_keys
    differ in the policy slot), while repeat requests of one policy hit
    the cache with zero re-traces."""
    svc = ElasticityService(max_batch=2)
    k64 = svc.group_key(SolveRequest(p=1, refine=0))
    k32 = svc.group_key(SolveRequest(p=1, refine=0, precision="f32"))
    kmx = svc.group_key(SolveRequest(p=1, refine=0, precision="mixed"))
    assert k64[:-1] == k32[:-1] == kmx[:-1]  # same discretization...
    assert len({k64, k32, kmx}) == 3  # ...distinct policy slot
    svc.solve([SolveRequest(p=1, refine=0)])
    svc.solve([SolveRequest(p=1, refine=0, precision="mixed")])
    assert len(svc._solvers) == 2
    assert {s.precision.name for s in svc._solvers.values()} == {
        "f64", "mixed",
    }
    misses = svc.stats["cache_misses"]
    solver = svc._solvers[kmx]
    traces0 = solver._jit_solve._cache_size()
    svc.solve([SolveRequest(p=1, refine=0, precision="mixed")])
    assert svc.stats["cache_misses"] == misses  # cache hit
    assert solver._jit_solve._cache_size() == traces0  # zero re-trace
    # the digest axis: identical materials under different policies must
    # not alias each other's prepared state
    from repro.serve.elasticity_service import _material_digest

    lam, mu = np.ones(3), np.ones(3)
    assert _material_digest(lam, mu, precision="f32") != _material_digest(
        lam, mu, precision="f64"
    )


def test_metrics_labels_carry_precision():
    svc = ElasticityService(max_batch=2)
    svc.solve([SolveRequest(p=1, refine=0, precision="f32")])
    snap = svc.registry.snapshot()
    cells = snap["families"]["service_cache_misses_total"]["cells"]
    assert any(c["labels"].get("precision") == "f32" for c in cells)
