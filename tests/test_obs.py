"""Observability subsystem tests.

Four layers, mirroring the obs package:

* metrics registry — counter/gauge/histogram semantics, exact
  snapshot round-trips, merge/diff algebra, Prometheus text format,
  histogram bucket invariants (property-based where hypothesis is
  available);
* spans — injected-clock lifecycle (no span left open, the per-ticket
  identity queue_wait + compute + overhead == submit-to-retire wall),
  Chrome trace / JSON-lines export;
* service integration — the migrated ``stats`` view is value-identical
  to the registry counters (at 1 device and, in the multidevice lane,
  8), and span counts reconcile EXACTLY with SchedulerTrace decisions
  and the registry counters;
* artifact schemas — the dependency-free validator enforces the
  checked-in BENCH_*.json contracts, and the instrumentation-overhead
  guard (slow lane) bounds the cost of recording.
"""

from __future__ import annotations

import json
import math
import time

import numpy as np
import pytest

from repro.obs.metrics import (
    Histogram,
    MetricsRegistry,
    default_latency_edges,
    diff_snapshots,
    merge_snapshots,
)
from repro.obs.schema import SchemaError, validate_json, validation_errors
from repro.obs.spans import SpanRecorder

from tests._hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# metrics: counters / gauges / registry semantics
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_counter_accumulates_and_rejects_negative(self):
        reg = MetricsRegistry()
        c = reg.counter("requests_total", "n", p=2)
        c.inc()
        c.inc(3.0)
        assert reg.value("requests_total", p=2) == 4.0
        with pytest.raises(ValueError, match=">= 0"):
            c.inc(-1)

    def test_gauge_set_inc_dec(self):
        reg = MetricsRegistry()
        g = reg.gauge("inflight", "n")
        g.set(5)
        g.inc()
        g.dec(2)
        assert reg.value("inflight") == 4.0

    def test_label_sets_are_independent_and_total_sums(self):
        reg = MetricsRegistry()
        reg.counter("chunks_total", "n", p=1).inc(2)
        reg.counter("chunks_total", "n", p=2).inc(5)
        assert reg.value("chunks_total", p=1) == 2.0
        assert reg.value("chunks_total", p=2) == 5.0
        assert reg.total("chunks_total") == 7.0
        # never-touched label set of a known family reads 0; unknown
        # family totals 0 (callers aggregate optimistically)
        assert reg.value("chunks_total", p=9) == 0.0
        assert reg.total("nope_total") == 0.0

    def test_kind_conflict_is_an_error(self):
        reg = MetricsRegistry()
        reg.counter("x_total", "n")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x_total", "n")

    def test_histogram_edge_conflict_is_an_error(self):
        reg = MetricsRegistry()
        reg.histogram("lat", "s", edges=(1.0, 2.0))
        with pytest.raises(ValueError, match="different"):
            reg.histogram("lat", "s", edges=(1.0, 3.0))
        # same edges: fine (same family, new label set)
        reg.histogram("lat", "s", edges=(1.0, 2.0), p=4)

    def test_invalid_metric_name_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="invalid metric name"):
            reg.counter("bad name", "n")

    def test_default_latency_edges_cover_serving_range(self):
        edges = default_latency_edges()
        assert all(a < b for a, b in zip(edges, edges[1:]))
        assert edges[0] <= 1e-3 and edges[-1] >= 100.0
        assert all(math.isfinite(e) for e in edges)


# ---------------------------------------------------------------------------
# metrics: histogram invariants
# ---------------------------------------------------------------------------
class TestHistogram:
    def test_le_convention(self):
        # Prometheus le convention: bucket i counts v <= edges[i].
        h = Histogram(edges=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0):
            h.observe(v)
        assert h.counts == [2, 2, 2, 1]  # (<=1], (1,2], (2,4], (4,inf)
        assert h.count == 7
        assert h.vmin == 0.5 and h.vmax == 5.0

    def test_quantile_clamps_to_observed_range(self):
        h = Histogram(edges=(1.0, 100.0))
        h.observe(40.0)
        # one sample in a huge bucket: the estimate must be the sample,
        # not the bucket midpoint
        assert h.quantile(0.5) == 40.0
        assert h.quantile(0.0) == 40.0
        assert h.quantile(1.0) == 40.0

    def test_quantile_empty_and_bad_q(self):
        h = Histogram(edges=(1.0,))
        assert math.isnan(h.quantile(0.5))
        h.observe(0.5)
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_edges_must_increase_and_be_finite(self):
        with pytest.raises(ValueError, match="strictly increasing"):
            Histogram(edges=(1.0, 1.0))
        with pytest.raises(ValueError, match="finite"):
            Histogram(edges=(1.0, math.inf))
        with pytest.raises(ValueError, match="at least one"):
            Histogram(edges=())

    def test_quantiles_monotone_against_numpy(self):
        rng = np.random.default_rng(3)
        vals = rng.lognormal(mean=-2.0, sigma=1.0, size=500)
        h = Histogram(default_latency_edges())
        for v in vals:
            h.observe(v)
        qs = [0.1, 0.5, 0.9, 0.99]
        est = h.quantiles(qs)
        assert est == sorted(est)
        # bucket resolution is ~33%/bucket: estimates must land within
        # one bucket of numpy's exact percentiles
        for q, e in zip(qs, est):
            exact = float(np.percentile(vals, 100 * q))
            assert e / exact < 10 ** (1 / 8) * 1.05
            assert exact / e < 10 ** (1 / 8) * 1.05

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(
            st.floats(
                min_value=1e-6,
                max_value=1e3,
                allow_nan=False,
                allow_infinity=False,
            ),
            min_size=1,
            max_size=100,
        )
    )
    def test_bucket_invariants_property(self, values):
        h = Histogram(default_latency_edges())
        for v in values:
            h.observe(v)
        # conservation: every observation lands in exactly one bucket
        assert sum(h.counts) == h.count == len(values)
        assert h.vmin == min(values) and h.vmax == max(values)
        assert np.isclose(h.sum, sum(values))
        # every quantile estimate stays inside the observed range
        for q in (0.0, 0.25, 0.5, 0.75, 0.9, 1.0):
            assert h.vmin <= h.quantile(q) <= h.vmax


# ---------------------------------------------------------------------------
# metrics: snapshot / merge / diff / export
# ---------------------------------------------------------------------------
def _loaded_registry():
    reg = MetricsRegistry(clock=lambda: 123.0)
    reg.counter("service_chunks_total", "chunks", p=1, policy="fixed").inc(7)
    reg.counter("service_chunks_total", "chunks", p=2, policy="fixed").inc(3)
    reg.gauge("inflight", "rows", p=1).set(2)
    h = reg.histogram("lat_seconds", "latency", p=1)
    for v in (0.002, 0.4, 1.7, 22.0):
        h.observe(v)
    return reg


class TestSnapshots:
    def test_round_trip_exact(self):
        reg = _loaded_registry()
        snap = reg.snapshot()
        again = MetricsRegistry.from_snapshot(snap).snapshot()
        assert again == snap
        # the JSON round-trip is exact too (plain data only)
        assert json.loads(json.dumps(snap)) == snap

    def test_from_snapshot_restores_live_cells(self):
        reg = MetricsRegistry.from_snapshot(_loaded_registry().snapshot())
        assert reg.total("service_chunks_total") == 10.0
        h = reg.get_histogram("lat_seconds", p=1)
        assert h.count == 4 and h.vmin == 0.002 and h.vmax == 22.0
        # restored registry keeps accumulating
        reg.counter("service_chunks_total", p=1, policy="fixed").inc()
        assert reg.total("service_chunks_total") == 11.0

    def test_unknown_schema_rejected(self):
        with pytest.raises(ValueError, match="schema"):
            MetricsRegistry.from_snapshot({"schema": "bogus/v9", "families": {}})

    def test_merge_adds_counters_and_buckets(self):
        a = _loaded_registry().snapshot()
        m = merge_snapshots(a, a)
        reg = MetricsRegistry.from_snapshot(m)
        assert reg.total("service_chunks_total") == 20.0
        h = reg.get_histogram("lat_seconds", p=1)
        assert h.count == 8 and h.sum == pytest.approx(2 * (0.002 + 0.4 + 1.7 + 22.0))
        assert h.vmin == 0.002 and h.vmax == 22.0
        # gauges take the right-hand snapshot's value, not the sum
        assert reg.value("inflight", p=1) == 2.0

    def test_merge_disjoint_label_sets(self):
        a = MetricsRegistry()
        a.counter("c_total", "n", p=1).inc(1)
        b = MetricsRegistry()
        b.counter("c_total", "n", p=2).inc(5)
        reg = MetricsRegistry.from_snapshot(
            merge_snapshots(a.snapshot(), b.snapshot())
        )
        assert reg.value("c_total", p=1) == 1.0
        assert reg.value("c_total", p=2) == 5.0

    def test_diff_is_the_window_between_snapshots(self):
        reg = _loaded_registry()
        before = reg.snapshot()
        reg.counter("service_chunks_total", "chunks", p=1, policy="fixed").inc(5)
        reg.get_histogram("lat_seconds", p=1).observe(0.1)
        window = diff_snapshots(reg.snapshot(), before)
        w = MetricsRegistry.from_snapshot(window)
        assert w.value("service_chunks_total", p=1, policy="fixed") == 5.0
        assert w.value("service_chunks_total", p=2, policy="fixed") == 0.0
        assert w.get_histogram("lat_seconds", p=1).count == 1

    def test_diff_rejects_backwards_counters(self):
        a = MetricsRegistry()
        a.counter("c_total", "n").inc(5)
        big = a.snapshot()
        b = MetricsRegistry()
        b.counter("c_total", "n").inc(2)
        with pytest.raises(ValueError, match="backwards"):
            diff_snapshots(b.snapshot(), big)

    def test_prometheus_text_format(self):
        text = _loaded_registry().to_prometheus_text()
        assert "# TYPE service_chunks_total counter" in text
        assert 'service_chunks_total{p="1",policy="fixed"} 7' in text
        assert "# TYPE lat_seconds histogram" in text
        assert '# HELP lat_seconds latency' in text
        # cumulative le buckets ending in +Inf == count
        assert 'lat_seconds_bucket{p="1",le="+Inf"} 4' in text
        assert 'lat_seconds_count{p="1"} 4' in text
        # cumulative: the largest finite bucket holds <= the total count
        lines = [
            ln for ln in text.splitlines() if ln.startswith("lat_seconds_bucket")
        ]
        cums = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
        assert cums == sorted(cums)

    def test_to_json_stamps_injected_clock(self):
        doc = json.loads(_loaded_registry().to_json())
        assert doc["generated_unix"] == 123.0
        assert doc["schema"] == "repro.obs.metrics/v1"


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
class _FakeClock:
    """Deterministic clock: advances 1.0 per call."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


class TestSpans:
    def test_begin_end_lifecycle(self):
        rec = SpanRecorder(clock=_FakeClock())
        sid = rec.begin("prep", cat="flight", tid=3, key="k")
        assert rec.open_count == 1
        span = rec.end(sid, rows=2)
        assert rec.open_count == 0
        assert span.start == 1.0 and span.end == 2.0 and span.duration == 1.0
        assert span.args == {"key": "k", "rows": 2}
        assert rec.count("prep") == 1 and rec.count() == 1

    def test_clear_refuses_open_spans(self):
        rec = SpanRecorder(clock=_FakeClock())
        rec.begin("x")
        with pytest.raises(RuntimeError, match="still open"):
            rec.clear()

    def test_chrome_trace_events(self, tmp_path):
        rec = SpanRecorder(clock=_FakeClock())
        rec.thread_name(0, "engine")
        rec.emit("a", cat="c", tid=0, start=10.0, end=10.5, n=1)
        rec.emit("b", cat="c", tid=1, start=10.25, end=11.0)
        events = rec.to_events()
        meta = [e for e in events if e["ph"] == "M"]
        xs = [e for e in events if e["ph"] == "X"]
        assert meta[0]["args"]["name"] == "engine"
        # microseconds, rebased to the earliest span start
        assert xs[0]["ts"] == 0.0 and xs[0]["dur"] == pytest.approx(0.5e6)
        assert xs[1]["ts"] == pytest.approx(0.25e6)
        path = tmp_path / "trace.json"
        rec.to_chrome_trace(str(path))
        doc = json.loads(path.read_text())
        assert len(doc["traceEvents"]) == 3
        assert doc["otherData"]["schema"] == "repro.obs.spans/v1"

    def test_jsonl_round_trip(self, tmp_path):
        rec = SpanRecorder(clock=_FakeClock())
        rec.emit("a", tid=2, start=1.0, end=2.0, k=3)
        path = tmp_path / "events.jsonl"
        rec.to_jsonl(str(path))
        rows = [json.loads(ln) for ln in path.read_text().splitlines()]
        assert rows == [
            {
                "name": "a",
                "cat": "",
                "tid": 2,
                "start": 1.0,
                "end": 2.0,
                "dur": 1.0,
                "args": {"k": 3},
            }
        ]


# ---------------------------------------------------------------------------
# service integration
# ---------------------------------------------------------------------------
def _mixed_requests(n, p=1, refine=1):
    from repro.serve.elasticity_service import SolveRequest

    return [
        SolveRequest(
            p=p,
            refine=refine,
            materials={1: (50.0 + 5 * (i % 2), 50.0), 2: (1.0, 1.0)},
            traction=(0.0, 0.0, -1e-2 * (1 + 0.1 * (i % 3))),
            rel_tol=1e-8 if i % 3 == 0 else 1e-4,
        )
        for i in range(n)
    ]


LEGACY_KEYS = {
    "cache_hits", "cache_misses", "generations", "chunks",
    "chunk_iters_dispatched", "wasted_iters", "refills", "rebuckets",
    "prep_calls", "prep_row_copies", "precision_fallbacks",
    # recovery counters (PR 9): checkpoint writes, restores, and step
    # watchdog fires ride the same registry/stats surface
    "checkpoints_written", "restores", "watchdog_fires",
}


class TestServiceIntegration:
    def test_stats_view_matches_registry(self):
        from repro.serve.elasticity_service import ElasticityService

        svc = ElasticityService(max_batch=4, chunk_iters=6)
        reports = svc.solve_continuous(_mixed_requests(6))
        assert all(r.converged for r in reports)
        assert set(svc.stats) == LEGACY_KEYS
        legacy = dict(svc.stats)
        for k in LEGACY_KEYS:
            assert legacy[k] == int(
                svc.registry.total(f"service_{k}_total")
            ), k
            assert isinstance(legacy[k], int)
        # the view is read-only: it has no __setitem__
        with pytest.raises(TypeError):
            svc.stats["chunks"] = 0

    def test_counters_carry_uniform_labels(self):
        from repro.serve.elasticity_service import ElasticityService

        svc = ElasticityService(max_batch=2, chunk_iters=6)
        svc.solve_continuous(_mixed_requests(2))
        v = svc.registry.value(
            "service_chunks_total", p=1, refine=1, policy="fixed", devices=1,
            precision="f64",
        )
        assert v == svc.stats["chunks"] > 0

    def test_span_trace_counter_reconciliation(self):
        """The acceptance invariant: span counts == SchedulerTrace
        decision count == registry counters, exactly."""
        from repro.serve.elasticity_service import ElasticityService

        rec = SpanRecorder()
        svc = ElasticityService(max_batch=4, chunk_iters=6, spans=rec)
        n = 6
        reports = svc.solve_continuous(_mixed_requests(n))
        assert len(reports) == n
        assert rec.open_count == 0, [s.name for s in rec.open_spans()]
        assert (
            rec.count("chunk_dispatch")
            == rec.count("chunk_device")
            == len(svc.trace.decisions)
            == svc.stats["chunks"]
        )
        assert rec.count("queue_wait") == svc.stats["refills"] == n
        assert rec.count("solve") == n
        # prep spans: one per step that reset rows (refills/rebuckets)
        assert rec.count("prep") >= 1
        # chunk_device args reconcile with the trace decisions
        for span, dec in zip(rec.by_name("chunk_dispatch"), svc.trace.decisions):
            assert span.args["chunk"] == dec.chunk
            assert span.args["bucket"] == dec.bucket

    def test_injected_clock_lifecycle_identity(self):
        """With a deterministic clock: no span left open, every span
        well-ordered, and per ticket queue_wait + compute + overhead
        sums EXACTLY to the submit-to-retire wall."""
        from repro.serve.elasticity_service import ElasticityService

        clock = _FakeClock()
        rec = SpanRecorder(clock=clock)
        svc = ElasticityService(
            max_batch=2, chunk_iters=6, spans=rec, clock=clock
        )
        reports = svc.solve_continuous(_mixed_requests(3))
        assert all(r.converged for r in reports)
        assert rec.open_count == 0
        assert all(s.end >= s.start for s in rec.spans)
        solves = rec.by_name("solve")
        assert len(solves) == 3
        for s in solves:
            a = s.args
            wall_admit_to_retire = s.end - s.start
            assert a["queue_wait"] >= 0
            assert a["compute"] >= 0
            assert a["overhead"] >= 0
            assert a["padding_overhead"] >= 0
            # compute + overhead == admit->retire wall (exact by
            # construction); + queue_wait == submit->retire wall
            assert a["compute"] + a["overhead"] == pytest.approx(
                wall_admit_to_retire, abs=1e-12
            )
        # chunk device time within each flight is fully attributed: the
        # sum of per-ticket compute equals sum over chunks of
        # (chunk_device wall * live rows riding it)
        total_compute = sum(s.args["compute"] for s in solves)
        expected = sum(
            s.duration * s.args["live"] for s in rec.by_name("chunk_device")
        )
        assert total_compute == pytest.approx(expected, abs=1e-9)

    def test_latency_summary_quantiles(self):
        from repro.serve.elasticity_service import ElasticityService

        svc = ElasticityService(max_batch=4, chunk_iters=6)
        assert svc.latency_summary() == {}
        n = 4
        svc.solve_continuous(_mixed_requests(n))
        lat = svc.latency_summary()
        assert lat["count"] == n
        assert 0 < lat["p50"] <= lat["p90"] <= lat["p99"]
        h = svc.registry.merged_histogram("request_latency_seconds")
        assert h.count == n

    def test_generational_path_observability(self):
        from repro.serve.elasticity_service import ElasticityService

        rec = SpanRecorder()
        svc = ElasticityService(max_batch=4, spans=rec)
        n = 5  # 2 generations: 4 + 1
        reports = svc.solve(_mixed_requests(n))
        assert all(r.converged for r in reports)
        assert svc.stats["generations"] == 2 == rec.count("generation")
        assert (
            svc.registry.merged_histogram("request_latency_seconds").count
            == n
        )

    def test_no_fence_when_spans_disabled(self):
        """Without a recorder the service must not fence chunks: no
        chunk_device histogram family ever appears."""
        from repro.serve.elasticity_service import ElasticityService

        svc = ElasticityService(max_batch=2, chunk_iters=6)
        svc.solve_continuous(_mixed_requests(2))
        assert svc.registry.get_histogram(
            "chunk_device_seconds", p=1, refine=1, policy="fixed", devices=1,
            precision="f64",
        ) is None

    def test_shared_registry_across_services(self):
        """Two services can share one registry (merge-at-source); totals
        accumulate across both."""
        from repro.serve.elasticity_service import ElasticityService

        reg = MetricsRegistry()
        a = ElasticityService(max_batch=2, chunk_iters=6, registry=reg)
        b = ElasticityService(max_batch=2, chunk_iters=6, registry=reg)
        a.solve_continuous(_mixed_requests(2))
        chunks_a = reg.total("service_chunks_total")
        b.solve_continuous(_mixed_requests(2))
        assert reg.total("service_chunks_total") > chunks_a
        assert a.stats["chunks"] == b.stats["chunks"]  # shared view

    @pytest.mark.multidevice
    def test_stats_view_differential_8_devices(self):
        """The migrated stats view stays value-identical to the registry
        under scenario sharding, and span counts still reconcile."""
        import jax

        from repro.distributed.sharding import scenario_mesh
        from repro.serve.elasticity_service import ElasticityService

        if jax.device_count() < 8:
            pytest.skip(
                f"needs 8 devices, have {jax.device_count()} "
                "(run with REPRO_HOST_DEVICES=8)"
            )
        rec = SpanRecorder()
        svc = ElasticityService(
            max_batch=8, chunk_iters=6, mesh=scenario_mesh(8), spans=rec
        )
        n = 6
        reports = svc.solve_continuous(_mixed_requests(n))
        assert all(r.converged for r in reports)
        for k in LEGACY_KEYS:
            assert svc.stats[k] == int(
                svc.registry.total(f"service_{k}_total")
            ), k
        assert svc.registry.value(
            "service_chunks_total",
            p=1, refine=1, policy="fixed", devices=8, precision="f64",
        ) == svc.stats["chunks"]
        assert rec.open_count == 0
        assert rec.count("chunk_dispatch") == svc.stats["chunks"]
        assert rec.count("solve") == n


# ---------------------------------------------------------------------------
# benchmark consolidation + artifact schemas
# ---------------------------------------------------------------------------
class TestArtifactSchemas:
    def test_validator_reports_paths(self):
        schema = {
            "type": "object",
            "required": ["rows"],
            "properties": {
                "rows": {
                    "type": "array",
                    "items": {
                        "type": "object",
                        "required": ["dofs_per_s"],
                        "properties": {
                            "dofs_per_s": {
                                "type": "number",
                                "exclusiveMinimum": 0,
                            }
                        },
                    },
                }
            },
        }
        errs = validation_errors(
            {"rows": [{"dofs_per_s": 1.0}, {"dofs_per_s": -2.0}, {}]}, schema
        )
        assert any("rows[1].dofs_per_s" in e for e in errs)
        assert any("rows[2]" in e and "dofs_per_s" in e for e in errs)
        with pytest.raises(SchemaError, match="rows"):
            validate_json({"rows": [{}]}, schema)

    def test_validator_type_discipline(self):
        assert validation_errors(3, {"type": "integer"}) == []
        assert validation_errors(3.0, {"type": "integer"}) == []
        assert validation_errors(True, {"type": "integer"}) != []
        assert validation_errors(True, {"type": "boolean"}) == []
        assert validation_errors(3, {"type": "number"}) == []
        assert validation_errors(None, {"type": ["number", "null"]}) == []
        assert validation_errors(float("nan"), {"type": "number"}) != []
        assert validation_errors("x", {"enum": ["memory", "compute"]}) != []
        assert (
            validation_errors(
                {"a": 1, "b": 2},
                {
                    "type": "object",
                    "properties": {"a": {}},
                    "additionalProperties": False,
                },
            )
            != []
        )

    def test_checked_in_schemas_are_loadable(self):
        import os

        here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sdir = os.path.join(here, "benchmarks", "schemas")
        names = sorted(os.listdir(sdir))
        assert names == [
            "bench_operator_sweep.schema.json",
            "bench_serving.schema.json",
        ]
        for n in names:
            with open(os.path.join(sdir, n)) as f:
                schema = json.load(f)
            assert schema["type"] == "object"
            assert "rows" in schema["properties"]

    def test_operator_throughput_row_matches_schema(self):
        """One real measured row (tiny: p=1, refine=0, batch=1)
        validates against the checked-in artifact row schema — the
        producer and the contract cannot drift."""
        import os

        from repro.launch.roofline import place_measured
        from repro.obs.throughput import operator_throughput

        row = operator_throughput(
            1, 0, 1, repeats=1, min_time_s=0.0
        )
        placed = place_measured(
            flops_per_apply=row["flops_per_apply"],
            bytes_per_apply=row["bytes_per_apply"],
            t_apply_s=row["t_apply_s"],
        )
        row["v5e_roof_fraction"] = placed.fraction
        row["v5e_bound"] = placed.bound
        here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(
            os.path.join(
                here, "benchmarks", "schemas",
                "bench_operator_sweep.schema.json",
            )
        ) as f:
            schema = json.load(f)
        validate_json(row, schema["properties"]["rows"]["items"])
        # physical sanity: DoF/s and the models agree with each other
        assert row["dofs_per_s"] == pytest.approx(
            row["dofs"] / row["t_apply_s"]
        )
        assert row["oi_model"] == pytest.approx(
            row["flops_per_apply"] / row["bytes_per_apply"]
        )

    def test_streaming_bytes_model_matches_fig6(self):
        """obs.throughput and fig6_roofline must use the SAME
        streaming-bytes model."""
        from repro.obs.throughput import streaming_bytes_per_elem

        for p in (1, 2, 4, 8):
            D, Q = p + 1, p + 2
            assert streaming_bytes_per_elem(p, 8) == 8 * (
                2 * 3 * D**3 + 2 * Q**3
            )

    def test_latency_percentiles_consolidated(self):
        """The benchmark's percentile helper must agree with the obs
        histogram quantiles (same estimator, not np.percentile)."""
        from benchmarks.batched_throughput import _latency_percentiles

        vals = [0.01, 0.02, 0.03, 0.5, 1.2, 3.0, 7.7, 20.0]
        p50, p95 = _latency_percentiles(vals)
        h = Histogram(default_latency_edges())
        for v in vals:
            h.observe(v)
        assert p50 == h.quantile(0.5)
        assert p95 == h.quantile(0.95)


# ---------------------------------------------------------------------------
# instrumentation overhead (slow lane)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_instrumentation_overhead_under_2_percent():
    """Recording spans WITHOUT fencing (the no-exporter config) must add
    < 2% wall vs a span-free service on the batch-16 mixed-tolerance
    workload.  min-of-repeats on warmed services to suppress CPU noise;
    a small absolute floor keeps the bound meaningful if the workload
    ever gets very fast."""
    from repro.serve.elasticity_service import ElasticityService

    def run_workload(svc, n):
        t0 = time.perf_counter()
        reports = svc.solve_continuous(_mixed_requests(n, p=1, refine=1))
        dt = time.perf_counter() - t0
        assert all(r.converged for r in reports)
        return dt

    n, repeats = 16, 3
    base_svc = ElasticityService(max_batch=16, chunk_iters=6)
    obs_svc = ElasticityService(
        max_batch=16, chunk_iters=6, spans=SpanRecorder(fence=False)
    )
    run_workload(base_svc, n)  # warm: hierarchy + compiles
    run_workload(obs_svc, n)
    base = min(run_workload(base_svc, n) for _ in range(repeats))
    obs = min(run_workload(obs_svc, n) for _ in range(repeats))
    assert obs <= base * 1.02 + 0.05, (
        f"instrumentation overhead too high: {obs:.3f}s vs {base:.3f}s "
        f"({(obs / base - 1) * 100:.1f}%)"
    )
