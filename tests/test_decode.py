"""Serving-path equivalence: prefill + decode_step must reproduce the
training-path forward logits at the same position, for every family."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_reduced
from repro.data.pipeline import make_batch
from repro.models.transformer import (
    _head_weight,
    decode_step,
    forward,
    init_params,
    prefill,
)

LM_ARCHS = [a for a in ARCH_IDS if a != "elasticity"]
SHAPE = ShapeConfig("smoke", "train", 16, 2)


def _cfg(arch):
    cfg = get_reduced(arch)
    kw = dict(dtype="float32", chunk_size=min(cfg.chunk_size, 8))
    if cfg.is_moe:
        # lossless routing so forward == decode (GShard capacity drops
        # differ between batched-train and single-token paths by design)
        kw["capacity_factor"] = float(cfg.n_experts)
    return dataclasses.replace(cfg, **kw)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    hidden, _ = forward(params, batch, cfg, remat=False)
    S = SHAPE.seq_len
    pre = {k: (v[:, : S - 1] if k != "vision_embeds" else v)
           for k, v in batch.items()}
    _, state = prefill(params, pre, cfg, max_len=S + 4)
    logits, _ = decode_step(
        params, batch["tokens"][:, S - 1 : S], state, jnp.int32(S - 1), cfg
    )
    w = _head_weight(params, cfg)
    if cfg.n_codebooks:
        ref = jnp.einsum("bd,cdv->bcv", hidden[:, -1], w)
    else:
        ref = hidden[:, -1] @ w
    err = float(jnp.max(jnp.abs(logits - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert err < 2e-2, f"{arch}: rel err {err}"


@pytest.mark.parametrize("arch", ["qwen3_17b", "zamba2_27b", "xlstm_125m"])
def test_multi_step_decode_consistency(arch):
    """Decoding T tokens step-by-step == forward over the full sequence."""
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    toks = batch["tokens"]
    S = SHAPE.seq_len
    T = 4
    pre = {"tokens": toks[:, : S - T]}
    _, state = prefill(params, pre, cfg, max_len=S + 4)
    hidden, _ = forward(params, batch, cfg, remat=False)
    w = _head_weight(params, cfg)
    for t in range(T):
        pos = S - T + t
        logits, state = decode_step(
            params, toks[:, pos : pos + 1], state, jnp.int32(pos), cfg
        )
        ref = hidden[:, pos] @ w
        err = float(
            jnp.max(jnp.abs(logits - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9)
        )
        assert err < 2e-2, f"{arch} step {t}: rel err {err}"


def test_serve_engine_end_to_end():
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    cfg = _cfg("qwen3_17b")
    eng = ServeEngine(cfg, max_len=64, max_batch=4)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                max_new_tokens=6)
        for _ in range(5)  # > max_batch: exercises generational batching
    ]
    eng.generate(reqs)
    for r in reqs:
        assert r.done and len(r.out_tokens) == 6
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_greedy_decode_deterministic():
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    cfg = _cfg("qwen3_17b")
    eng = ServeEngine(cfg, max_len=32, max_batch=2)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
    r1 = Request(prompt=prompt.copy(), max_new_tokens=5)
    r2 = Request(prompt=prompt.copy(), max_new_tokens=5)
    eng.generate([r1])
    eng.generate([r2])
    assert r1.out_tokens == r2.out_tokens
