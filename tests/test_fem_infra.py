"""Mesh / space / transfer / geometry infrastructure tests."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.geometry import make_quadrature_data, MATERIALS_BEAM
from repro.core.basis import basis_tables
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space
from repro.fem.transfer import make_transfer


def test_mesh_refinement_counts():
    m = beam_hex()
    assert m.nelem == 8
    r = m.refined()
    assert r.nelem == 64
    assert r.refined().nelem == 512


def test_beam_two_materials():
    m = beam_hex().refined()
    # attribute 1 on x < L/2, attribute 2 on x >= L/2 (MFEM ex2 convention)
    attrs = np.asarray(m.attributes())
    assert set(attrs.tolist()) == {1, 2}
    assert (attrs == 1).sum() == (attrs == 2).sum()


@pytest.mark.parametrize("p", [1, 2, 3])
def test_evec_roundtrip_multiplicity(p):
    """G^T G == diag(multiplicity): scatter(gather(x)) multiplies each node
    by the number of elements sharing it."""
    space = H1Space(beam_hex(2, 1, 1), p)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((space.nscalar, 3)))
    y = space.scatter_add(space.to_evec(x))
    mult = jnp.asarray(space.dof_multiplicity, x.dtype)[:, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(x * mult), rtol=1e-12)


def test_quadrature_data_affine_constant():
    m = beam_hex().refined()
    tb = basis_tables(2)
    qd = make_quadrature_data(m, tb, MATERIALS_BEAM)
    # uniform box refinement: J is mesh-constant
    assert qd.jinv.ndim == 2
    # lambda_w carries the 50:1 two-material contrast; per-element means
    # divide out the (element-independent) w*detJ quadrature factor.
    lw = np.asarray(qd.lambda_w).reshape(m.nelem, -1).mean(axis=1)
    assert lw.max() / lw.min() == pytest.approx(50.0, rel=1e-10)


@pytest.mark.parametrize("pc,pf", [(1, 2), (2, 4), (4, 8)])
def test_p_prolongation_exact_on_coarse_polys(pc, pf):
    """p-transfer must reproduce degree-pc polynomials exactly."""
    mesh = beam_hex()
    coarse, fine = H1Space(mesh, pc), H1Space(mesh, pf)
    t = make_transfer(coarse, fine)
    xc, yc, zc = coarse.node_coords_1d
    Xc = coarse.node_coords()
    f = Xc[:, 0] ** pc + 2.0 * Xc[:, 1] - Xc[:, 2] ** min(pc, 2)
    uc = jnp.asarray(np.stack([f, -f, 0.5 * f], axis=1))
    uf = t.prolong(uc)
    Xf = fine.node_coords()
    ff = Xf[:, 0] ** pc + 2.0 * Xf[:, 1] - Xf[:, 2] ** min(pc, 2)
    np.testing.assert_allclose(np.asarray(uf)[:, 0], ff, atol=1e-9)


def test_h_prolongation_exact_on_linears():
    mesh = beam_hex()
    coarse = H1Space(mesh, 1)
    fine = H1Space(mesh.refined(), 1)
    t = make_transfer(coarse, fine)
    Xc, Xf = coarse.node_coords(), fine.node_coords()
    uc = jnp.asarray(np.stack([Xc[:, 0], Xc[:, 1], Xc[:, 2]], axis=1))
    uf = t.prolong(uc)
    np.testing.assert_allclose(np.asarray(uf), Xf, atol=1e-10)


def test_restriction_is_prolongation_transpose():
    mesh = beam_hex()
    coarse, fine = H1Space(mesh, 1), H1Space(mesh, 2)
    t = make_transfer(coarse, fine)
    rng = np.random.default_rng(2)
    xc = jnp.asarray(rng.standard_normal((coarse.nscalar, 3)))
    yf = jnp.asarray(rng.standard_normal((fine.nscalar, 3)))
    lhs = float(jnp.vdot(t.prolong(xc), yf))
    rhs = float(jnp.vdot(xc, t.restrict(yf)))
    assert abs(lhs - rhs) < 1e-9 * max(abs(lhs), 1.0)


def test_traction_rhs_total_force():
    """Assembled traction RHS must sum to traction * face area."""
    space = H1Space(beam_hex().refined(), 2)
    t = (0.0, 0.0, -1e-2)
    F = space.traction_rhs("x1", t)
    area = 1.0  # beam cross-section is 1 x 1
    np.testing.assert_allclose(F.sum(axis=0), np.asarray(t) * area, atol=1e-12)
