"""Batched-solver tests: bpcg vs a Python loop of scalar pcg on random
SPD systems, masked convergence with mixed per-scenario tolerances,
zero-RHS rows, the batch-threaded Chebyshev smoother, and the batched
GMG hierarchy against its scalar counterpart."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operators import ElasticityOperator
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space
from repro.solvers.batched import BatchedGMGSolver, bpcg
from repro.solvers.cg import pcg
from repro.solvers.chebyshev import ChebyshevSmoother
from repro.solvers.gmg import build_hierarchy


def _random_spd_batch(rng, s, n):
    mats, rhss = [], []
    for _ in range(s):
        m = rng.standard_normal((n, n))
        mats.append(m @ m.T + n * np.eye(n))
        rhss.append(rng.standard_normal(n))
    return jnp.asarray(np.stack(mats)), jnp.asarray(np.stack(rhss))


def _batch_matvec(a):
    return lambda x: jnp.einsum("sij,sj->si", a, x)


def test_bpcg_matches_scalar_pcg_loop(rng):
    """bpcg == a Python loop of scalar pcg, per scenario, including the
    per-scenario iteration counts (the masking must not perturb rows)."""
    s, n = 5, 32
    a, b = _random_spd_batch(rng, s, n)
    res = bpcg(_batch_matvec(a), b, rel_tol=1e-10, maxiter=300)
    assert res.iterations.shape == (s,)
    for i in range(s):
        ref = pcg(lambda x: a[i] @ x, b[i], rel_tol=1e-10, maxiter=300)
        assert int(res.iterations[i]) == int(ref.iterations)
        assert bool(res.converged[i])
        np.testing.assert_allclose(
            np.asarray(res.x[i]), np.asarray(ref.x), rtol=1e-8, atol=1e-12
        )


def test_bpcg_masked_convergence_mixed_tolerances(rng):
    """Loose-tolerance scenarios retire early (fewer iterations) while
    tight ones keep iterating; each matches its scalar run exactly."""
    s, n = 4, 40
    a, b = _random_spd_batch(rng, s, n)
    tols = jnp.asarray([1e-2, 1e-6, 1e-12, 1e-4])
    res = bpcg(_batch_matvec(a), b, rel_tol=tols, maxiter=300)
    iters = np.asarray(res.iterations)
    assert iters[0] < iters[2] and iters[3] < iters[2]
    for i in range(s):
        ref = pcg(lambda x: a[i] @ x, b[i], rel_tol=float(tols[i]),
                  maxiter=300)
        assert int(iters[i]) == int(ref.iterations)
        np.testing.assert_allclose(
            np.asarray(res.x[i]), np.asarray(ref.x), rtol=1e-8, atol=1e-12
        )
        # the frozen row really stopped at ITS tolerance, not the batch's
        assert float(res.final_norm[i]) <= float(
            tols[i] * res.initial_norm[i]
        )


def test_bpcg_zero_rhs_row_is_free_and_does_not_pollute(rng):
    """A zero-RHS scenario (the padding row of a partial generation) is
    born converged with 0 iterations and must not NaN the live rows."""
    s, n = 3, 24
    a, b = _random_spd_batch(rng, s, n)
    b = b.at[1].set(0.0)
    res = bpcg(_batch_matvec(a), b, rel_tol=1e-8, maxiter=200)
    assert int(res.iterations[1]) == 0
    assert bool(res.converged[1])
    np.testing.assert_array_equal(np.asarray(res.x[1]), 0.0)
    assert not np.isnan(np.asarray(res.x)).any()
    for i in (0, 2):
        ref = pcg(lambda x: a[i] @ x, b[i], rel_tol=1e-8, maxiter=200)
        assert int(res.iterations[i]) == int(ref.iterations)


def test_bpcg_maxiter_reports_unconverged(rng):
    s, n = 2, 50
    a, b = _random_spd_batch(rng, s, n)
    res = bpcg(_batch_matvec(a), b, rel_tol=1e-14, maxiter=3)
    assert np.asarray(res.iterations).tolist() == [3, 3]
    assert not np.asarray(res.converged).any()


def test_chebyshev_smoother_batched_matches_scalar():
    """The batch-threaded smoother applied to stacked scenarios must act
    exactly like per-scenario scalar smoothers (different materials give
    different lambda_max, so the coefficients genuinely differ per row)."""
    space = H1Space(beam_hex(2, 1, 1).refined(), 2)
    mats = [{1: (50.0, 50.0), 2: (1.0, 1.0)}, {1: (5.0, 2.0), 2: (3.0, 4.0)}]
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal((2, space.nscalar, 3)))

    opb = ElasticityOperator(space, assembly="paop", materials=mats)
    copb = opb.constrained()
    smb = ChebyshevSmoother.setup(
        copb, copb.diagonal(), shape=(2, space.nscalar, 3),
        dtype=jnp.float64, batch_dims=1,
    )
    xb = smb(b)
    assert float(jnp.linalg.norm((b - copb(xb)).reshape(-1))) < float(
        jnp.linalg.norm(b.reshape(-1))
    )
    for i, m in enumerate(mats):
        op = ElasticityOperator(space, assembly="paop", materials=m)
        cop = op.constrained()
        sm = ChebyshevSmoother.setup(
            cop, cop.diagonal(), shape=(space.nscalar, 3), dtype=jnp.float64
        )
        np.testing.assert_allclose(
            np.asarray(smb.lmax[i]), np.asarray(sm.lmax), rtol=1e-10
        )
        np.testing.assert_allclose(
            np.asarray(xb[i]), np.asarray(sm(b[i])), rtol=1e-10, atol=1e-14
        )


def test_batched_hierarchy_solves_match_sequential():
    """bpcg + a scenario-batched GMG hierarchy reproduces per-scenario
    sequential GMG-PCG solves to tight accuracy."""
    from repro.fem.bc import eliminate_rhs

    mats = [{1: (50.0, 50.0), 2: (1.0, 1.0)}, {1: (10.0, 5.0), 2: (2.0, 2.0)}]
    gmg = build_hierarchy(beam_hex(), 1, 2, assembly="paop", materials=mats)
    fine = gmg.fine
    b1 = jnp.asarray(fine.space.traction_rhs("x1", (0.0, 0.0, -1e-2)))
    b = jnp.where(jnp.asarray(fine.ess_mask), 0.0, jnp.stack([b1, 2.0 * b1]))
    res = bpcg(fine.constrained, b, M=gmg, rel_tol=1e-10, maxiter=200)
    assert np.asarray(res.converged).all()

    for i, m in enumerate(mats):
        g1 = build_hierarchy(beam_hex(), 1, 2, assembly="paop", materials=m)
        f1 = g1.fine
        bs = eliminate_rhs(f1.operator.apply, f1.ess_mask, b[i])
        ref = pcg(f1.constrained, bs, M=g1, rel_tol=1e-10, maxiter=200)
        assert int(res.iterations[i]) == int(ref.iterations)
        scale = float(jnp.abs(ref.x).max())
        np.testing.assert_allclose(
            np.asarray(res.x[i]), np.asarray(ref.x), atol=1e-10 * scale
        )


def test_batched_gmg_solver_compiled_program(rng):
    """BatchedGMGSolver: one jitted program, materials/tractions/tols as
    runtime args — new scenario data must NOT retrace."""
    solver = BatchedGMGSolver(beam_hex(), 1, 1, maxiter=100)
    mats = [{1: (50.0, 50.0), 2: (1.0, 1.0)}] * 2
    tr = np.array([[0.0, 0.0, -1e-2], [0.0, 1e-3, -2e-2]])
    res = solver.solve(mats, tr, rel_tol=1e-8)
    assert np.asarray(res.converged).all()
    n_traces = solver._jit_solve._cache_size()
    mats2 = [{1: (80.0, 70.0), 2: (2.0, 1.0)}, {1: (9.0, 9.0), 2: (1.0, 3.0)}]
    res2 = solver.solve(mats2, 0.5 * tr, rel_tol=1e-10)
    assert np.asarray(res2.converged).all()
    assert solver._jit_solve._cache_size() == n_traces
    # different materials genuinely change the answer
    assert float(jnp.abs(res.x[0] - res2.x[0]).max()) > 0
