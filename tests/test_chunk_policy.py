"""Chunk-policy tests: the deterministic scheduler-trace harness (seed
corpus under tests/data/sched_traces/, no solver in the loop), the
scheduling-invariance differentials at 1 and 8 devices (every policy
reproduces the fixed policy's SolveReports — exact iterations/flags,
solutions to machine precision; bitwise when the decision sequences
coincide — and adaptive beats fixed's wasted-iteration count on the
mixed-tolerance batch-16 run), policy placement/bound units, the
row->device map, and the policy-bound validation messages."""

import json
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.distributed.sharding import scenario_mesh, scenario_row_devices
from repro.serve.chunk_policy import (
    AdaptiveChunkPolicy,
    ChunkObservation,
    FixedChunkPolicy,
    ShardAdaptiveChunkPolicy,
    make_chunk_policy,
    simulate_cadence_trace,
)
from repro.serve.elasticity_service import ElasticityService, SolveRequest

TRACE_DIR = pathlib.Path(__file__).parent / "data" / "sched_traces"
TRACE_NAMES = sorted(p.name for p in TRACE_DIR.glob("*.json"))

MATS = [
    {1: (50.0, 50.0), 2: (1.0, 1.0)},
    {1: (80.0, 60.0), 2: (2.0, 1.0)},
    {1: (9.0, 9.0), 2: (1.0, 3.0)},
]


def load_trace(name: str) -> dict:
    with open(TRACE_DIR / name) as f:
        return json.load(f)


def policies(default_chunk: int = 8):
    return [
        FixedChunkPolicy(default_chunk),
        AdaptiveChunkPolicy(1, 32, default_chunk=default_chunk),
        ShardAdaptiveChunkPolicy(1, 32, default_chunk=default_chunk),
    ]


# -- deterministic scheduler-trace harness (no solver in the loop) ----------
def test_seed_corpus_exists():
    """The harness has real inputs: the corpus covers single- and
    multi-shard layouts, staggered arrivals and a mixed-tolerance mix."""
    assert {
        "mixed_tol_16.json",
        "staggered_8x2.json",
        "uniform_4.json",
        "bursty_8x4.json",
    } <= set(TRACE_NAMES)


@pytest.mark.parametrize("name", TRACE_NAMES)
def test_trace_decisions_reproducible_and_bounded(name):
    """Driving a policy over a recorded cadence trace twice yields the
    identical decision sequence (chunks, placements, consumed, waste),
    every chunk respects [min_chunk, max_chunk], and the recorded
    observations replay to the recorded choices."""
    trace = load_trace(name)
    for policy in policies():
        a = simulate_cadence_trace(policy, trace)
        b = simulate_cadence_trace(policy, trace)
        assert a.chunks() == b.chunks()
        assert [d.refills for d in a.decisions] == [
            d.refills for d in b.decisions
        ]
        assert [d.consumed for d in a.decisions] == [
            d.consumed for d in b.decisions
        ]
        assert a.summary() == b.summary()
        for d in a.decisions:
            assert policy.min_chunk <= d.chunk <= policy.max_chunk
            assert d.wasted >= 0
        assert a.replay(policy) == a.chunks()
        # every request retired exactly once
        assert a.summary()["refills"] == len(trace["requests"])


@pytest.mark.parametrize("name", TRACE_NAMES)
def test_adaptive_clamped_to_constant_reproduces_fixed(name):
    """An adaptive policy clamped to min_chunk == max_chunk == k is the
    fixed policy, decision-for-decision: same chunk choices, same refill
    placements, same waste — the clamp is the only thing between the
    two."""
    trace = load_trace(name)
    fixed = simulate_cadence_trace(FixedChunkPolicy(8), trace)
    clamped = simulate_cadence_trace(
        AdaptiveChunkPolicy(8, 8, default_chunk=8), trace
    )
    assert clamped.chunks() == fixed.chunks()
    assert [d.refills for d in clamped.decisions] == [
        d.refills for d in fixed.decisions
    ]
    assert clamped.summary() == fixed.summary()


def test_adaptive_wastes_fewer_iterations_on_heterogeneous_cadence():
    """On every heterogeneous-cadence trace in the corpus the adaptive
    policy's wasted-iteration count is strictly below the fixed
    default's — the point of cadence-driven chunking."""
    for name in ("mixed_tol_16.json", "staggered_8x2.json", "bursty_8x4.json"):
        trace = load_trace(name)
        fixed = simulate_cadence_trace(FixedChunkPolicy(8), trace).summary()
        adapt = simulate_cadence_trace(
            AdaptiveChunkPolicy(1, 32, default_chunk=8), trace
        ).summary()
        assert adapt["wasted_iters"] < fixed["wasted_iters"], name
        assert adapt["refills"] == fixed["refills"], name


def test_adaptive_snaps_chunks_to_uniform_cadence():
    """Uniform cadence (every row retires at 9): after one observed
    retirement the adaptive policy chunks straight to the retire point,
    dispatching fewer, longer chunks than the fixed default for the
    same zero waste."""
    trace = load_trace("uniform_4.json")
    fixed = simulate_cadence_trace(FixedChunkPolicy(8), trace).summary()
    adapt = simulate_cadence_trace(
        AdaptiveChunkPolicy(1, 32, default_chunk=8), trace
    ).summary()
    assert adapt["wasted_iters"] == fixed["wasted_iters"] == 0
    assert adapt["chunks"] < fixed["chunks"]


# -- policy units -----------------------------------------------------------
def test_fixed_policy_ignores_observations():
    p = FixedChunkPolicy(5)
    obs = ChunkObservation(
        live_iters=(3, 40), live_devices=(0, 0), history=(7, 9),
        bucket=4,
    )
    assert p.chunk_for(obs) == 5
    assert p.min_chunk == p.max_chunk == 5


def test_adaptive_predicts_next_retire_distance():
    p = AdaptiveChunkPolicy(1, 32, default_chunk=8)
    # no history -> fixed fallback
    obs = ChunkObservation((0, 0), (0, 0), (), bucket=2)
    assert p.chunk_for(obs) == 8
    # nearest cadence strictly ahead of a live row wins: row at 10 with
    # history {12, 45} is 2 iterations from the next predicted retire
    obs = ChunkObservation((10, 3), (0, 0), (12, 45), bucket=2)
    assert p.chunk_for(obs) == 2
    # all history behind every live row -> fallback again
    obs = ChunkObservation((50,), (0,), (12, 45), bucket=2)
    assert p.chunk_for(obs) == 8
    # clamping
    assert AdaptiveChunkPolicy(4, 32, default_chunk=8).chunk_for(
        ChunkObservation((10,), (0,), (12,), bucket=1)
    ) == 4
    assert AdaptiveChunkPolicy(1, 16, default_chunk=8).chunk_for(
        ChunkObservation((0,), (0,), (45,), bucket=1)
    ) == 16


def test_shard_adaptive_chunk_uses_per_device_mix():
    p = ShardAdaptiveChunkPolicy(1, 32, default_chunk=8)
    # device 0's rows see no cadence ahead (fallback 8); device 1's row
    # predicts a retire in 3 -> the chunk stops at the earliest shard.
    obs = ChunkObservation(
        live_iters=(50, 9), live_devices=(0, 1), history=(12,),
        bucket=4, n_devices=2,
    )
    assert p.chunk_for(obs) == 3
    # single device degenerates to the adaptive estimate
    a = AdaptiveChunkPolicy(1, 32, default_chunk=8)
    obs1 = ChunkObservation((10, 3), (0, 0), (12, 45), bucket=2)
    assert p.chunk_for(obs1) == a.chunk_for(obs1)


def test_shard_adaptive_placement_targets_least_loaded_device():
    p = ShardAdaptiveChunkPolicy(1, 32, default_chunk=8)
    slot_devices = [0, 0, 1, 1, 2, 2, 3, 3]
    # device 0 carries both live rows; free slots should fill devices
    # 1, 2, 3 first (lowest device wins ties), then rebalance.
    order = p.placement(
        [0, 1, 2, 3, 4, 5, 6, 7], slot_devices, live_devices=[0, 0]
    )
    assert order == [2, 4, 6, 3, 5, 7, 0, 1]
    # the default placement (fixed/adaptive) is ascending slot index
    assert FixedChunkPolicy(8).placement(
        [5, 1, 3], slot_devices, [0]
    ) == [5, 1, 3]
    assert AdaptiveChunkPolicy(1, 8).placement(
        [5, 1, 3], slot_devices, [0]
    ) == [5, 1, 3]


def test_scenario_row_devices_contiguous_blocks():
    np.testing.assert_array_equal(
        scenario_row_devices(8, 2), [0, 0, 0, 0, 1, 1, 1, 1]
    )
    np.testing.assert_array_equal(
        scenario_row_devices(8, 4), [0, 0, 1, 1, 2, 2, 3, 3]
    )
    np.testing.assert_array_equal(scenario_row_devices(3, 1), [0, 0, 0])
    with pytest.raises(ValueError, match="do not divide"):
        scenario_row_devices(6, 4)
    with pytest.raises(ValueError, match="n_shards must be >= 1"):
        scenario_row_devices(4, 0)


@pytest.mark.multidevice
def test_scenario_row_devices_matches_actual_sharding():
    """The host-side row->device map the shard-adaptive policy uses must
    agree with where NamedSharding actually places each row."""
    ndev = min(4, jax.device_count())
    assert ndev > 1
    mesh = scenario_mesh(ndev)
    from repro.distributed.sharding import device_put_scenario

    s = 2 * ndev
    x = device_put_scenario(np.zeros((s, 3)), mesh)
    want = scenario_row_devices(s, ndev)
    mesh_devs = list(mesh.devices.flat)
    for dev, idx in x.sharding.devices_indices_map((s, 3)).items():
        rows = range(*idx[0].indices(s))
        for r in rows:
            assert mesh_devs[want[r]] == dev, (r, dev)


# -- validation messages ----------------------------------------------------
def test_policy_bound_validation_messages():
    with pytest.raises(ValueError, match=r"min_chunk must be >= 1, got 0"):
        AdaptiveChunkPolicy(0, 8)
    with pytest.raises(ValueError, match=r"max_chunk must be >= 1, got -3"):
        AdaptiveChunkPolicy(1, -3)
    with pytest.raises(
        ValueError, match=r"min_chunk \(9\) must be <= max_chunk \(4\)"
    ):
        ShardAdaptiveChunkPolicy(9, 4)
    with pytest.raises(
        TypeError, match=r"min_chunk must be an integer >= 1, got 2\.5"
    ):
        AdaptiveChunkPolicy(2.5, 8)
    with pytest.raises(
        TypeError, match=r"max_chunk must be an integer >= 1, got True"
    ):
        AdaptiveChunkPolicy(1, True)
    with pytest.raises(
        ValueError, match=r"fixed policy: chunk_iters must be >= 1, got 0"
    ):
        FixedChunkPolicy(0)
    with pytest.raises(
        TypeError,
        match=r"fixed policy: chunk_iters must be an integer >= 1, got '8'",
    ):
        FixedChunkPolicy("8")
    with pytest.raises(
        ValueError, match=r"default_chunk must be >= 1, got 0"
    ):
        AdaptiveChunkPolicy(1, 8, default_chunk=0)
    # a bad chunk_iters on the adaptive path blames chunk_iters, not
    # the max_chunk bound derived from it
    with pytest.raises(
        ValueError, match=r"adaptive policy: chunk_iters must be >= 1, got -2"
    ):
        make_chunk_policy("adaptive", chunk_iters=-2)
    with pytest.raises(
        TypeError,
        match=r"shard-adaptive policy: chunk_iters must be an integer "
              r">= 1, got 2\.5",
    ):
        make_chunk_policy("shard-adaptive", chunk_iters=2.5)
    with pytest.raises(ValueError, match=r"unknown chunk policy 'greedy'"):
        make_chunk_policy("greedy")
    # bounds on a fixed (or prebuilt) policy are an error, not a no-op
    with pytest.raises(
        ValueError, match=r"min_chunk/max_chunk only apply to the adaptive"
    ):
        make_chunk_policy("fixed", max_chunk=2)
    with pytest.raises(
        ValueError, match=r"chunk policy is 'adaptive'"
    ):
        make_chunk_policy(AdaptiveChunkPolicy(1, 8), min_chunk=2)
    # a prebuilt policy ignores chunk_iters but cannot hide a bad one
    with pytest.raises(
        ValueError, match=r"fixed policy: chunk_iters must be >= 1, got 0"
    ):
        make_chunk_policy(FixedChunkPolicy(8), chunk_iters=0)
    assert make_chunk_policy(FixedChunkPolicy(5)).min_chunk == 5


def test_scheduler_trace_is_bounded():
    """A long-lived service cannot grow the trace without bound: only
    the most recent maxlen decisions are retained (cumulative stats are
    independent of the trimming)."""
    from repro.serve.chunk_policy import ChunkDecision, SchedulerTrace

    tr = SchedulerTrace(maxlen=3)
    obs = ChunkObservation((0,), (0,), (), bucket=1)
    for i in range(7):
        tr.append(
            ChunkDecision(
                step=i, key="k", policy="fixed", bucket=1,
                observation=obs, chunk=1,
            )
        )
    assert [d.step for d in tr.decisions] == [4, 5, 6]
    assert SchedulerTrace().maxlen == 4096
    with pytest.raises(ValueError, match=r"maxlen must be >= 1, got 0"):
        SchedulerTrace(maxlen=0)


def test_service_validates_policy_bounds_at_construction():
    """The old chunk_iters < 1 check generalized: the service rejects
    bad policy bounds up front, naming the offending parameter."""
    with pytest.raises(
        ValueError, match=r"chunk_iters must be >= 1, got 0"
    ):
        ElasticityService(chunk_iters=0)
    with pytest.raises(
        ValueError, match=r"chunk_iters must be >= 1, got -2"
    ):
        ElasticityService(chunk_iters=-2)
    with pytest.raises(
        ValueError, match=r"min_chunk \(5\) must be <= max_chunk \(2\)"
    ):
        ElasticityService(
            chunk_policy="adaptive", min_chunk=5, max_chunk=2
        )
    with pytest.raises(ValueError, match=r"min_chunk must be >= 1"):
        ElasticityService(chunk_policy="shard-adaptive", min_chunk=0)
    with pytest.raises(ValueError, match=r"unknown chunk policy"):
        ElasticityService(chunk_policy="nope")
    # clamps silently ignored by the fixed default would be a footgun
    with pytest.raises(
        ValueError, match=r"min_chunk/max_chunk only apply to the adaptive"
    ):
        ElasticityService(max_chunk=2)


# -- scheduling-invariance differential -------------------------------------
def mixed_tol_requests(n: int, p: int = 1, refine: int = 1):
    """Mixed-tolerance workload on one key: one tight row per four loose
    ones, varied materials/tractions — retire cadence is genuinely
    heterogeneous, so the policies schedule differently."""
    return [
        SolveRequest(
            p=p,
            refine=refine,
            materials=MATS[i % 3],
            traction=(0.0, 1e-3 * (i % 2), -1e-2 * (1 + 0.2 * i)),
            rel_tol=1e-10 if i % 4 == 0 else 1e-4,
            keep_solution=True,
        )
        for i in range(n)
    ]


def assert_reports_numerically_identical(reps, refs, context, bitwise=True):
    """Scheduling must never change numerics: solutions, iteration
    counts and flags match row-for-row.  Scheduling metadata
    (generation, batch_size, timings) legitimately differs.

    ``bitwise=True`` is for runs whose *decision sequences* coincide
    (e.g. adaptive clamped to the fixed constant): identical decisions
    -> identical compiled-program sequence -> bitwise-equal reports.
    Policies that actually schedule differently route rows through
    different bucket-shape programs, which XLA fuses differently — the
    same ~1 ulp wobble the sharded differential suite pins — so those
    comparisons use machine precision (exact iterations/flags, solutions
    to 1e-12 * scale), the repo's established bar for "identical
    numerics" across program shapes."""
    assert len(reps) == len(refs)
    for i, (a, b) in enumerate(zip(reps, refs)):
        ctx = f"{context} request {i}"
        assert a.iterations == b.iterations, ctx
        assert a.converged == b.converged, ctx
        assert a.born_converged == b.born_converged, ctx
        assert (a.x is None) == (b.x is None), ctx
        if bitwise:
            assert a.final_rel_norm == b.final_rel_norm, ctx
            if a.x is not None:
                np.testing.assert_array_equal(a.x, b.x, err_msg=ctx)
        else:
            np.testing.assert_allclose(
                a.final_rel_norm, b.final_rel_norm, rtol=1e-8,
                atol=1e-300, err_msg=ctx,
            )
            if a.x is not None:
                scale = float(np.abs(b.x).max()) or 1.0
                np.testing.assert_allclose(
                    a.x, b.x, atol=1e-12 * scale, rtol=0, err_msg=ctx
                )


@pytest.mark.parametrize(
    "ndev",
    [pytest.param(1), pytest.param(8, marks=pytest.mark.multidevice)],
)
def test_policies_reproduce_fixed_reports(ndev):
    """The PR's core invariant at 1 and 8 devices: adaptive and
    shard-adaptive continuous scheduling reproduce the fixed default's
    SolveReports — exact iteration counts, convergence and
    born_converged flags, solutions to machine precision, padding never
    surfaced — and the generational path agrees too.  Waste/chunk
    counters are the ONLY things allowed to move."""
    if ndev > jax.device_count():
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")
    mesh = None if ndev == 1 else scenario_mesh(ndev)
    reqs = mixed_tol_requests(10)
    svc_fixed = ElasticityService(max_batch=4, chunk_iters=6, mesh=mesh)
    refs = svc_fixed.solve_continuous(list(reqs))
    assert len(refs) == len(reqs)  # padding rows never surfaced
    gen_refs = ElasticityService(max_batch=4, mesh=mesh).solve(list(reqs))
    assert_reports_numerically_identical(
        refs, gen_refs, f"continuous-vs-generational ndev={ndev}",
        bitwise=False,
    )
    for policy in ("adaptive", "shard-adaptive"):
        svc = ElasticityService(
            max_batch=4, chunk_iters=6, chunk_policy=policy, mesh=mesh
        )
        reps = svc.solve_continuous(list(reqs))
        assert_reports_numerically_identical(
            reps, refs, f"{policy} ndev={ndev}", bitwise=False
        )
        # decisions are replayable from the recorded observations
        assert svc.trace.replay(svc.chunk_policy) == svc.trace.chunks()
        for d in svc.trace.decisions:
            assert (
                svc.chunk_policy.min_chunk
                <= d.chunk
                <= svc.chunk_policy.max_chunk
            )


@pytest.mark.parametrize(
    "ndev",
    [pytest.param(1), pytest.param(8, marks=pytest.mark.multidevice)],
)
def test_clamped_adaptive_is_bitwise_identical_to_fixed(ndev):
    """Adaptive clamped to min_chunk == max_chunk == chunk_iters makes
    the SAME decisions as the fixed policy, so the whole run — every
    chunk choice, every refill placement, every report field including
    the solution arrays — is bitwise identical at 1 and 8 devices.
    This pins the true bit-for-bit claim: only a *different* decision
    sequence may move anything, and then only scheduling metadata."""
    if ndev > jax.device_count():
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")
    mesh = None if ndev == 1 else scenario_mesh(ndev)
    reqs = mixed_tol_requests(10)
    svc_fixed = ElasticityService(max_batch=4, chunk_iters=6, mesh=mesh)
    refs = svc_fixed.solve_continuous(list(reqs))
    svc_clamped = ElasticityService(
        max_batch=4, chunk_iters=6, chunk_policy="adaptive",
        min_chunk=6, max_chunk=6, mesh=mesh,
    )
    reps = svc_clamped.solve_continuous(list(reqs))
    # decision-for-decision: same chunks, same placements
    assert svc_clamped.trace.chunks() == svc_fixed.trace.chunks()
    assert [
        (d.bucket, d.live_slots, d.refills, d.consumed, d.wasted)
        for d in svc_clamped.trace.decisions
    ] == [
        (d.bucket, d.live_slots, d.refills, d.consumed, d.wasted)
        for d in svc_fixed.trace.decisions
    ]
    assert_reports_numerically_identical(
        reps, refs, f"clamped ndev={ndev}", bitwise=True
    )
    for k in ("chunks", "chunk_iters_dispatched", "wasted_iters", "refills"):
        assert svc_clamped.stats[k] == svc_fixed.stats[k], k


@pytest.mark.slow
def test_adaptive_beats_fixed_waste_on_batch16_service_run():
    """Acceptance criterion, on the real engine: a mixed-tolerance
    batch-16 continuous run under the adaptive policy wastes strictly
    fewer slot-iterations than the fixed default — while producing
    bit-identical reports."""
    reqs = mixed_tol_requests(20)
    svc_fixed = ElasticityService(max_batch=16, chunk_iters=8)
    svc_adapt = ElasticityService(
        max_batch=16, chunk_iters=8, chunk_policy="adaptive"
    )
    refs = svc_fixed.solve_continuous(list(reqs))
    reps = svc_adapt.solve_continuous(list(reqs))
    assert_reports_numerically_identical(
        reps, refs, "adaptive batch16", bitwise=False
    )
    assert (
        svc_adapt.stats["wasted_iters"] < svc_fixed.stats["wasted_iters"]
    ), (svc_adapt.stats, svc_fixed.stats)
    # both traces are internally consistent with the stats counters
    for svc in (svc_fixed, svc_adapt):
        s = svc.trace.summary()
        assert s["chunks"] == svc.stats["chunks"]
        assert s["wasted_iters"] == svc.stats["wasted_iters"]
        assert s["refills"] == svc.stats["refills"]


@pytest.mark.multidevice
def test_shard_adaptive_placement_balances_live_rows_across_shards():
    """With 4 forced devices and a drained mixed workload, every refill
    the shard-adaptive policy placed landed on a device that was
    (weakly) least-loaded among the free slots at that decision —
    recorded in the trace, so this is a pure host-side check."""
    ndev = 4
    if ndev > jax.device_count():
        pytest.skip(f"needs {ndev} devices")
    svc = ElasticityService(
        max_batch=8, chunk_iters=4, chunk_policy="shard-adaptive",
        mesh=scenario_mesh(ndev),
    )
    reps = svc.solve_continuous(mixed_tol_requests(12))
    assert len(reps) == 12
    placed = [r for d in svc.trace.decisions for r in d.refills]
    assert placed  # the policy actually placed refills
    devs = {r.device for r in placed}
    assert len(devs) > 1  # refills spread across shards
    for d in svc.trace.decisions:
        assert d.policy == "shard-adaptive"


# -- CLI smoke (slow lane) --------------------------------------------------
@pytest.mark.slow
def test_batched_throughput_chunk_policy_cli_smoke():
    """`batched_throughput.py --continuous --chunk-policy adaptive` runs
    end-to-end and reports the scheduler-stats columns."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run(
        [
            sys.executable, "-m", "benchmarks.batched_throughput",
            "--continuous", "--chunk-policy", "adaptive",
            "--batch", "4", "--n-requests", "8", "--repeats", "1",
            "--chunk-iters", "4",
        ],
        cwd=root,
        env=env,
        capture_output=True,
        text=True,
        timeout=1800,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "continuous(adaptive, k=4)" in res.stdout
    assert "wasted_iters" in res.stdout
