"""Doc-snippet lane: every fenced ``python`` block in README.md and
docs/*.md is extracted and executed, so the documentation cannot rot.

Conventions for documentation authors:

* each ``python`` block must be self-contained (its own imports; no
  state shared between blocks) and cheap — p=1 / refine<=1 / small
  batches, a few seconds per block;
* shell examples belong in ``bash`` blocks, which are not executed;
* a block that intentionally must not run can use a ``python-norun``
  fence, which this collector ignores (none exist today — prefer
  executable blocks).

Each snippet is one parametrized test (marker ``docs``), so a failure
names the file and block index; the CI docs lane runs exactly
``pytest -q -m docs``.
"""

from __future__ import annotations

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

_FENCE = re.compile(
    r"^```python[ \t]*\n(.*?)^```[ \t]*$", re.MULTILINE | re.DOTALL
)


def extract_snippets() -> list[pytest.param]:
    params = []
    for path in DOC_FILES:
        text = path.read_text()
        for i, m in enumerate(_FENCE.finditer(text)):
            line = text[: m.start()].count("\n") + 2  # first code line
            sid = f"{path.relative_to(ROOT)}:{line}"
            params.append(pytest.param(sid, m.group(1), id=sid))
    return params


SNIPPETS = extract_snippets()


@pytest.mark.docs
def test_docs_exist_and_have_snippets():
    """The docs/ subsystem itself is load-bearing: README plus both
    architecture and materials pages exist and carry executable
    examples."""
    names = {p.name for p in DOC_FILES}
    assert {
        "README.md", "ARCHITECTURE.md", "KERNELS.md", "MATERIALS.md",
        "SCHEDULING.md", "OBSERVABILITY.md", "PRECISION.md",
        "FAULT_TOLERANCE.md",
    } <= names
    by_file = {}
    for param in SNIPPETS:
        by_file.setdefault(param.id.split(":")[0], 0)
        by_file[param.id.split(":")[0]] += 1
    assert by_file.get("README.md", 0) >= 1
    assert by_file.get("docs/ARCHITECTURE.md", 0) >= 2
    assert by_file.get("docs/KERNELS.md", 0) >= 3
    assert by_file.get("docs/MATERIALS.md", 0) >= 4
    assert by_file.get("docs/SCHEDULING.md", 0) >= 5
    assert by_file.get("docs/OBSERVABILITY.md", 0) >= 4
    assert by_file.get("docs/PRECISION.md", 0) >= 5
    assert by_file.get("docs/FAULT_TOLERANCE.md", 0) >= 4


@pytest.mark.docs
@pytest.mark.parametrize("sid,code", SNIPPETS)
def test_doc_snippet_executes(sid: str, code: str):
    """Execute one fenced python block in a fresh namespace.  Snippets
    assert their own claims (bitwise equality, convergence, error
    messages), so green means the documented behavior is real."""
    exec(compile(code, sid, "exec"), {"__name__": f"doc_snippet[{sid}]"})
