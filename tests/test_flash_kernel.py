"""Flash-attention Pallas kernel vs oracle: shapes, dtypes, GQA, SWA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention, pick_block
from repro.kernels.flash_attention.ref import flash_ref


def _qkv(B, S, H, K, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,K,D", [
    (2, 128, 4, 2, 16),
    (1, 256, 8, 8, 32),   # MHA
    (2, 64, 8, 1, 8),     # MQA
    (1, 512, 4, 2, 64),
])
def test_flash_matches_ref(B, S, H, K, D):
    q, k, v = _qkv(B, S, H, K, D, jnp.float32)
    out = flash_attention(q, k, v, block_q=min(64, S), block_k=min(64, S))
    ref = flash_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [16, 48, 128])
def test_flash_sliding_window(window):
    q, k, v = _qkv(2, 128, 4, 2, 16, jnp.float32, seed=1)
    out = flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    ref = flash_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 128, 4, 4, 32, jnp.bfloat16, seed=2)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = flash_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )


def test_block_shape_invariance():
    q, k, v = _qkv(1, 256, 4, 2, 16, jnp.float32, seed=3)
    o1 = flash_attention(q, k, v, block_q=32, block_k=64)
    o2 = flash_attention(q, k, v, block_q=128, block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_pick_block():
    assert pick_block(4096) == 128
    assert pick_block(96) == 96
    assert pick_block(100, 64) == 50
