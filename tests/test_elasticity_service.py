"""ElasticityService tests: mixed-discretization queue routing, LRU
cache behavior on repeated keys, generational padding, and agreement of
batched solutions with the sequential solve_beam driver."""

import numpy as np
import pytest

from repro.launch.solve import solve_beam
from repro.serve.elasticity_service import (
    ElasticityService,
    SolveRequest,
)

MATS_A = {1: (50.0, 50.0), 2: (1.0, 1.0)}  # the paper's beam materials
MATS_B = {1: (80.0, 60.0), 2: (2.0, 1.0)}


@pytest.fixture(scope="module")
def service():
    return ElasticityService(max_batch=8, cache_size=4)


@pytest.fixture(scope="module")
def mixed_batch_reports(service):
    """One mixed batch of 8 scenarios (2 material sets x 2 tractions x 2
    tolerances) solved in a single batched program."""
    requests = [
        SolveRequest(
            p=2,
            refine=1,
            materials=MATS_A if i % 2 == 0 else MATS_B,
            traction=(0.0, 0.0, -1e-2) if i < 4 else (0.0, 5e-3, -5e-3),
            rel_tol=1e-10 if i % 4 < 2 else 1e-8,
            keep_solution=True,
        )
        for i in range(8)
    ]
    return requests, service.solve(requests)


def test_mixed_batch_converges_with_per_request_iterations(mixed_batch_reports):
    requests, reports = mixed_batch_reports
    assert len(reports) == 8
    assert all(r.converged for r in reports)
    assert all(r.final_rel_norm <= r.request.rel_tol for r in reports)
    assert all(r.batch_size == 8 and r.generation == 0 for r in reports)
    iters = [r.iterations for r in reports]
    assert all(it > 0 for it in iters)
    # different tolerances within the batch -> different retire points
    assert len(set(iters)) >= 2


def test_mixed_batch_matches_sequential_solve_beam(mixed_batch_reports):
    """Each batched solution must match the one-scenario-at-a-time
    driver to <= 1e-8 relative error (acceptance criterion)."""
    requests, reports = mixed_batch_reports
    # Scenario 0 uses the paper's exact benchmark setup.
    rep_seq = solve_beam(2, 1, assembly="paop", rel_tol=1e-10,
                         keep_solution=True)
    x_seq = np.asarray(rep_seq.x)
    x_b = reports[0].x
    rel = np.linalg.norm(x_b - x_seq) / np.linalg.norm(x_seq)
    assert rel <= 1e-8
    assert reports[0].iterations == rep_seq.iterations


def test_second_same_key_batch_hits_cache(service, mixed_batch_reports):
    """Repeating a discretization key must skip hierarchy build and
    recompilation: cache_hit=True and ~zero setup time."""
    requests, first = mixed_batch_reports
    assert not first[0].cache_hit
    assert first[0].t_setup > 0
    again = service.solve(
        [SolveRequest(p=2, refine=1, materials=MATS_B, rel_tol=1e-8)]
    )
    assert again[0].cache_hit
    assert again[0].t_setup == 0.0
    assert again[0].converged
    assert service.stats["cache_hits"] >= 1


def test_partial_generation_padding(service, mixed_batch_reports):
    """3 requests with max_batch=8: the generation is padded with
    zero-traction rows, which must not affect the real solutions and
    must never surface as reports."""
    reqs = [
        SolveRequest(p=2, refine=1, materials=MATS_A, rel_tol=1e-8,
                     traction=(0.0, 0.0, -1e-2 * (i + 1)))
        for i in range(3)
    ]
    reports = service.solve(reqs)
    assert len(reports) == 3  # padding rows are internal only
    assert all(r.converged for r in reports)
    assert all(r.batch_size == 3 for r in reports)
    # real rows are never marked as padding-style born-converged
    assert not any(r.born_converged for r in reports)


def test_zero_rhs_request_distinguished_from_padding():
    """A real request with a zero traction converges before iteration 1
    just like a padding row — the report must say so (born_converged)
    instead of a bare residual 0.0, on both scheduling paths."""
    service = ElasticityService(max_batch=4)
    reqs = [
        SolveRequest(p=1, refine=0, materials=MATS_A, rel_tol=1e-8,
                     traction=(0.0, 0.0, 0.0)),
        SolveRequest(p=1, refine=0, materials=MATS_A, rel_tol=1e-8),
    ]
    zero_rep, live_rep = service.solve(list(reqs))
    assert zero_rep.born_converged
    assert zero_rep.converged and zero_rep.iterations == 0
    assert zero_rep.final_rel_norm == 0.0
    assert not live_rep.born_converged and live_rep.iterations > 0

    zero_rep2, live_rep2 = service.solve_continuous(list(reqs))
    assert zero_rep2.born_converged and zero_rep2.iterations == 0
    assert not live_rep2.born_converged
    assert live_rep2.iterations == live_rep.iterations


def test_mixed_discretization_queue():
    """Requests with different (p, refine) keys are grouped and solved
    per key; reports come back in submission order."""
    service = ElasticityService(max_batch=4, cache_size=4)
    reqs = [
        SolveRequest(p=1, refine=1, materials=MATS_A, rel_tol=1e-8),
        SolveRequest(p=1, refine=0, materials=MATS_A, rel_tol=1e-8),
        SolveRequest(p=1, refine=1, materials=MATS_B, rel_tol=1e-8),
        SolveRequest(p=1, refine=0, materials=MATS_B, rel_tol=1e-8),
    ]
    reports = service.solve(reqs)
    assert [r.key[:2] for r in reports] == [(1, 1), (1, 0), (1, 1), (1, 0)]
    assert all(r.converged for r in reports)
    assert service.stats["cache_misses"] == 2
    # each key solved its two members in one generation
    assert service.stats["generations"] == 2
    assert {r.batch_size for r in reports} == {2}


def test_lru_eviction():
    """cache_size=1: a second key evicts the first; re-solving the first
    key is a miss again."""
    service = ElasticityService(max_batch=2, cache_size=1)
    service.solve([SolveRequest(p=1, refine=0, rel_tol=1e-6)])
    service.solve([SolveRequest(p=1, refine=1, rel_tol=1e-6)])
    rep = service.solve([SolveRequest(p=1, refine=0, rel_tol=1e-6)])[0]
    assert not rep.cache_hit
    assert service.stats["cache_misses"] == 3
