"""1D basis/quadrature unit + property tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.basis import basis_tables, gauss_points, gll_nodes, lagrange_tables


@pytest.mark.parametrize("p", range(1, 11))
def test_gll_nodes_structure(p):
    x = gll_nodes(p)
    assert len(x) == p + 1
    assert x[0] == -1.0 and x[-1] == 1.0
    assert np.all(np.diff(x) > 0)
    # symmetric about 0
    np.testing.assert_allclose(x, -x[::-1], atol=1e-13)


@pytest.mark.parametrize("p", range(1, 10))
def test_partition_of_unity(p):
    tb = basis_tables(p)
    # sum_i phi_i(x) = 1 and sum_i phi_i'(x) = 0 at all quadrature points
    np.testing.assert_allclose(tb.B.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(tb.G.sum(axis=1), 0.0, atol=1e-10)


@pytest.mark.parametrize("p", range(1, 9))
def test_interpolation_reproduces_polynomials(p):
    """Degree-p Lagrange basis interpolates any poly of degree <= p exactly."""
    tb = basis_tables(p)
    coeffs = np.random.default_rng(p).standard_normal(p + 1)
    f = np.polynomial.polynomial.Polynomial(coeffs)
    vals_at_nodes = f(tb.nodes)
    interp = tb.B @ vals_at_nodes
    np.testing.assert_allclose(interp, f(tb.qpts), atol=1e-11)
    df = f.deriv()
    np.testing.assert_allclose(tb.G @ vals_at_nodes, df(tb.qpts), atol=1e-10)


@given(q=st.integers(1, 16), deg=st.integers(0, 8))
@settings(max_examples=40, deadline=None)
def test_gauss_quadrature_exactness(q, deg):
    """q-point Gauss rule integrates degree <= 2q-1 exactly."""
    if deg > 2 * q - 1:
        return
    pts, wts = gauss_points(q)
    exact = 2.0 / (deg + 1) if deg % 2 == 0 else 0.0
    np.testing.assert_allclose(np.sum(wts * pts**deg), exact, atol=1e-12)


def test_lagrange_at_nodes_is_identity():
    for p in (1, 3, 6):
        tb = basis_tables(p)
        B, G = lagrange_tables(tb.nodes, tb.nodes)
        np.testing.assert_allclose(B, np.eye(p + 1), atol=1e-12)
        # derivative rows sum to zero (differentiation matrix property)
        np.testing.assert_allclose(G.sum(axis=1), 0.0, atol=1e-10)
