"""Data pipeline determinism/shard-invariance + optimizer tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ShapeConfig, get_reduced
from repro.data.pipeline import TokenPipeline, batch_spec, make_batch
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule

CFG = dataclasses.replace(get_reduced("qwen3_17b"), dtype="float32")
SHAPE = ShapeConfig("t", "train", 16, 8)


def test_batch_deterministic():
    b1 = make_batch(CFG, SHAPE, step=7, seed=3)
    b2 = make_batch(CFG, SHAPE, step=7, seed=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(CFG, SHAPE, step=8, seed=3)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


@pytest.mark.parametrize("count", [2, 4, 8])
def test_shard_invariance(count):
    """Concatenating shard batches == the global batch, for ANY shard
    count (the elastic-rescale invariant)."""
    full = make_batch(CFG, SHAPE, step=5, seed=1)
    parts = [
        make_batch(CFG, SHAPE, step=5, seed=1, shard=(i, count))
        for i in range(count)
    ]
    glued = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(glued, full["tokens"])


def test_labels_are_shifted_tokens():
    b = make_batch(CFG, SHAPE, step=0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_batch_spec_matches_make_batch():
    spec = batch_spec(CFG, SHAPE)
    batch = make_batch(CFG, SHAPE, 0)
    assert set(spec) == set(batch)
    for k in spec:
        assert spec[k].shape == batch[k].shape
        assert spec[k].dtype == batch[k].dtype


def test_pipeline_resume_bit_identical():
    p1 = TokenPipeline(CFG, SHAPE, seed=0, start_step=0)
    batches = [next(p1) for _ in range(4)]
    sd = p1.state_dict()
    p1.close()
    assert sd["step"] == 4
    p2 = TokenPipeline(CFG, SHAPE, seed=0, start_step=4)
    b4 = next(p2)
    p2.close()
    p3 = TokenPipeline(CFG, SHAPE, seed=0, start_step=0)
    ref = [next(p3) for _ in range(5)]
    p3.close()
    np.testing.assert_array_equal(b4["tokens"], ref[4]["tokens"])
    np.testing.assert_array_equal(batches[2]["tokens"], ref[2]["tokens"])


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = np.array([float(cosine_schedule(cfg, s)) for s in range(101)])
    assert lrs[0] == 0.0
    assert np.isclose(lrs[10], 1e-3, rtol=1e-5)
    assert np.isclose(lrs[100], 1e-4, rtol=1e-3)
    assert (np.diff(lrs[:10]) > 0).all()
    assert (np.diff(lrs[11:]) < 1e-9).all()


def test_adamw_quadratic_convergence():
    """AdamW drives a quadratic to its minimum."""
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros((3, 1))}
    opt_cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=500, min_lr_ratio=1.0)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"][:, 0] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(opt_cfg, params, g, state)
    assert float(loss(params)) < 1e-3


def test_adamw_clip_norm():
    params = {"w": jnp.zeros((4, 4))}
    opt_cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                          warmup_steps=0, total_steps=10, min_lr_ratio=1.0)
    state = adamw_init(params)
    g = {"w": 1e6 * jnp.ones((4, 4))}
    _, _, metrics = adamw_update(opt_cfg, params, g, state)
    assert float(metrics["grad_norm"]) == pytest.approx(4e6)
    # post-clip effective gradient has unit norm -> m = (1-b1) * g_clipped


@given(lr=st.floats(1e-5, 1e-2), wd=st.floats(0, 0.3))
@settings(max_examples=10, deadline=None)
def test_adamw_decay_shrinks_weights(lr, wd):
    """With zero gradient + error-free moments, weight decay shrinks
    matrices and leaves vectors (norms/biases) alone."""
    params = {"mat": jnp.ones((4, 4)), "vec": jnp.ones((4,))}
    opt_cfg = AdamWConfig(lr=lr, weight_decay=wd, warmup_steps=0,
                          total_steps=10, min_lr_ratio=1.0)
    state = adamw_init(params)
    g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(opt_cfg, params, g, state)
    assert float(p2["mat"].max()) <= 1.0
    np.testing.assert_allclose(np.asarray(p2["vec"]), 1.0)
