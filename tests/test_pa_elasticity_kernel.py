"""Pallas PAop kernel: shape/dtype sweep against the pure-jnp oracle,
lane resolution (compiled vs interpret with automatic fallback), and the
VMEM block-size estimator invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.basis import basis_tables
from repro.kernels.pa_elasticity import ops
from repro.kernels.pa_elasticity.ref import paop_ref


def _setup(p, ne, dtype, seed=0):
    tb = basis_tables(p)
    rng = np.random.default_rng(seed)
    d1, q1 = tb.d1d, tb.q1d
    x = jnp.asarray(rng.standard_normal((ne, 3, d1, d1, d1)), dtype)
    lam = jnp.asarray(rng.random((ne, q1, q1, q1)) + 0.5, dtype)
    mu = jnp.asarray(rng.random((ne, q1, q1, q1)) + 0.5, dtype)
    jinv = jnp.asarray(np.diag([2.0, 3.0, 4.0]), dtype)
    B = jnp.asarray(tb.B, dtype)
    G = jnp.asarray(tb.G, dtype)
    return x, lam, mu, jinv, B, G


@pytest.mark.parametrize("p", [1, 2, 3, 4, 6, 8])
@pytest.mark.parametrize("ne", [1, 3, 8])
def test_kernel_matches_oracle_f32(p, ne):
    x, lam, mu, jinv, B, G = _setup(p, ne, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=4, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    scale = float(jnp.abs(ref).max())
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-5 * scale, rtol=2e-4)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_kernel_matches_oracle_f64(p):
    x, lam, mu, jinv, B, G = _setup(p, 4, jnp.float64)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=2, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-12)


@pytest.mark.parametrize("eb", [2, 4, 8])
def test_block_size_invariance(eb):
    """Result must not depend on the VMEM tiling choice."""
    x, lam, mu, jinv, B, G = _setup(3, 8, jnp.float32)
    y1 = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=eb, interpret=True)
    y2 = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)


def test_padding_path():
    """ne not divisible by eb exercises the pad/trim wrapper."""
    x, lam, mu, jinv, B, G = _setup(2, 5, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=4, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=1e-5 * float(jnp.abs(ref).max()))


def test_clamp_never_exceeds_element_count():
    """The clamp must bound the block by ne (padding < 2x), fixing the
    old ``eb=128, ne=12 -> pad to 128`` >10x blow-up."""
    for ne in (1, 2, 3, 5, 7, 12, 100, 129):
        for eb in (1, 2, 8, 128, 1024):
            got = ops.clamp_elements_per_block(eb, ne)
            assert 1 <= got <= ne, (eb, ne, got)
            assert got <= eb or eb > ne, (eb, ne, got)
            padded = ne + (-ne) % got
            assert padded < 2 * ne or got == 1, (eb, ne, got, padded)


def test_clamp_prefers_exact_divisors():
    """When a divisor of ne at least half the block exists, it is chosen
    (zero padding beats a slightly larger block)."""
    assert ops.clamp_elements_per_block(128, 12) == 12
    assert ops.clamp_elements_per_block(8, 12) == 6
    assert ops.clamp_elements_per_block(4, 12) == 4
    assert ops.clamp_elements_per_block(8, 64) == 8
    # prime ne with no useful divisor: keep the clamped block, pad < 2x
    assert ops.clamp_elements_per_block(4, 7) == 4


@pytest.mark.parametrize("ne", [1, 3, 12, 64])
def test_elements_per_block_bounded_by_ne(ne):
    for p in (1, 2, 4, 8):
        eb = ops.elements_per_block(p, ne)
        assert 1 <= eb <= ne


def test_small_mesh_padding_roundtrip():
    """The regression shape from the issue (small ne, auto eb): result
    must round-trip through pad/trim and match the oracle."""
    x, lam, mu, jinv, B, G = _setup(2, 12, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=1e-5 * float(jnp.abs(ref).max()))


def test_vmem_budget_respected():
    for p in (1, 2, 4, 8):
        eb = ops.elements_per_block(p, ne=1 << 20)
        assert ops.block_workingset_bytes(p, eb) <= ops.VMEM_BUDGET_BYTES
        assert eb >= 8


# -- lane resolution ---------------------------------------------------------


def test_resolve_lane_basics():
    assert ops.resolve_lane("interpret") == "interpret"
    assert ops.resolve_lane(None, interpret=True) == "interpret"
    # auto (and the legacy interpret=False/None) resolves to a real lane
    for lane in (ops.resolve_lane("auto"), ops.resolve_lane(None),
                 ops.resolve_lane(None, interpret=False)):
        assert lane in ("compiled", "interpret")
    with pytest.raises(ValueError, match="pallas lane"):
        ops.resolve_lane("fast")


def test_resolve_lane_follows_backend_capability(monkeypatch):
    """auto/compiled resolve from the capability probe; an explicit
    interpret request always pins the interpreter."""
    backend = jax.default_backend()
    monkeypatch.setitem(ops._SUPPORT_CACHE, backend, True)
    assert ops.resolve_lane("auto") == "compiled"
    assert ops.resolve_lane("compiled") == "compiled"
    assert ops.resolve_lane(None, interpret=False) == "compiled"
    assert ops.resolve_lane("interpret") == "interpret"
    monkeypatch.setitem(ops._SUPPORT_CACHE, backend, False)
    assert ops.resolve_lane("auto") == "interpret"
    assert ops.resolve_lane("compiled") == "interpret"  # automatic fallback


def test_backend_supports_compiled_never_on_cpu():
    """CPU has no Mosaic/Triton lowering; the probe must say so without
    even attempting a compile (and the answer is cached)."""
    assert ops.backend_supports_compiled("cpu") is False
    assert ops._SUPPORT_CACHE["cpu"] is False


@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 6, 7, 8])
def test_compiled_lane_matches_interpret(p):
    """The compiled lane agrees with the interpreter to machine
    precision for every p in 1..8.  On backends without native Pallas
    lowering the compiled request falls back to the interpreter and the
    outputs are bitwise identical — which is exactly the fallback
    contract this locks down."""
    x, lam, mu, jinv, B, G = _setup(p, 4, jnp.float32)
    yi = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=2, lane="interpret")
    yc = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=2, lane="compiled")
    if ops.backend_supports_compiled():
        scale = float(jnp.abs(yi).max())
        np.testing.assert_allclose(np.asarray(yc), np.asarray(yi),
                                   atol=1e-6 * scale, rtol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(yc), np.asarray(yi))


# -- VMEM estimator: real q1d and call-time budget check ---------------------


def test_workingset_uses_real_q1d():
    """The estimator defaults to the p+2 Gauss rule but must budget
    against the actual quadrature when one is passed."""
    p = 4
    assert (ops.block_workingset_bytes(p, 8, q1d=p + 2)
            == ops.block_workingset_bytes(p, 8))
    assert (ops.block_workingset_bytes(p, 8, q1d=12)
            > ops.block_workingset_bytes(p, 8))
    eb_default = ops.elements_per_block(p, 1 << 20)
    eb_rich = ops.elements_per_block(p, 1 << 20, q1d=12)
    assert eb_rich < eb_default
    assert (ops.block_workingset_bytes(p, eb_rich, q1d=12)
            <= ops.VMEM_BUDGET_BYTES)


def test_call_time_vmem_budget_assertion():
    """An explicit eb whose working set (at the REAL q1d read off
    lam_w) exceeds the budget must fail loudly at call time, not
    silently over-allocate VMEM."""
    ne, p, q1 = 64, 8, 10
    d1 = p + 1
    x = jnp.zeros((ne, 3, d1, d1, d1), jnp.float64)
    lam = jnp.ones((ne, q1, q1, q1), jnp.float64)
    jinv = jnp.eye(3, dtype=jnp.float64)
    B = jnp.zeros((q1, d1), jnp.float64)
    assert ops.block_workingset_bytes(p, ne, 8, q1) > ops.VMEM_BUDGET_BYTES
    with pytest.raises(ValueError, match="VMEM budget"):
        ops.pa_elasticity(x, lam, lam, jinv, B, B, eb=ne, interpret=True)


# -- clamp invariants (property) ---------------------------------------------


@settings(max_examples=300, deadline=None)
@given(ne=st.integers(1, 4096), p=st.integers(1, 8),
       scale=st.integers(0, 12))
def test_clamp_invariants_property(ne, p, scale):
    """Over ne in [1, 4096] and the estimator's whole p range: the
    clamped block is within [1, ne], never larger than the request,
    keeps at least half the requested occupancy, and pads by at most
    one element per grid step (nblocks - 1) — the bound the old
    return-the-request fallback violated for e.g. prime ne."""
    eb_req = ops.elements_per_block(p, 1 << 20) >> scale  # walk the range
    eb_req = max(1, eb_req)
    got = ops.clamp_elements_per_block(eb_req, ne)
    ebc = max(1, min(eb_req, ne))
    assert 1 <= got <= ebc
    assert 2 * got > ebc  # occupancy: never below half the request
    nblocks = -(-ne // got)
    pad = nblocks * got - ne
    assert pad <= nblocks - 1
    # divisor preference: an exact divisor in (ebc/2, ebc] wins (pad 0)
    best = max((d for d in range(1, ebc + 1)
                if ne % d == 0 and 2 * d > ebc), default=None)
    if best is not None:
        assert got == best and pad == 0
