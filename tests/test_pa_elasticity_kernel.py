"""Pallas PAop kernel: shape/dtype sweep against the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.basis import basis_tables
from repro.kernels.pa_elasticity import ops
from repro.kernels.pa_elasticity.ref import paop_ref


def _setup(p, ne, dtype, seed=0):
    tb = basis_tables(p)
    rng = np.random.default_rng(seed)
    d1, q1 = tb.d1d, tb.q1d
    x = jnp.asarray(rng.standard_normal((ne, 3, d1, d1, d1)), dtype)
    lam = jnp.asarray(rng.random((ne, q1, q1, q1)) + 0.5, dtype)
    mu = jnp.asarray(rng.random((ne, q1, q1, q1)) + 0.5, dtype)
    jinv = jnp.asarray(np.diag([2.0, 3.0, 4.0]), dtype)
    B = jnp.asarray(tb.B, dtype)
    G = jnp.asarray(tb.G, dtype)
    return x, lam, mu, jinv, B, G


@pytest.mark.parametrize("p", [1, 2, 3, 4, 6, 8])
@pytest.mark.parametrize("ne", [1, 3, 8])
def test_kernel_matches_oracle_f32(p, ne):
    x, lam, mu, jinv, B, G = _setup(p, ne, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=4, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    scale = float(jnp.abs(ref).max())
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-5 * scale, rtol=2e-4)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_kernel_matches_oracle_f64(p):
    x, lam, mu, jinv, B, G = _setup(p, 4, jnp.float64)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=2, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-12)


@pytest.mark.parametrize("eb", [2, 4, 8])
def test_block_size_invariance(eb):
    """Result must not depend on the VMEM tiling choice."""
    x, lam, mu, jinv, B, G = _setup(3, 8, jnp.float32)
    y1 = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=eb, interpret=True)
    y2 = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)


def test_padding_path():
    """ne not divisible by eb exercises the pad/trim wrapper."""
    x, lam, mu, jinv, B, G = _setup(2, 5, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=4, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=1e-5 * float(jnp.abs(ref).max()))


def test_vmem_budget_respected():
    for p in (1, 2, 4, 8):
        eb = ops.elements_per_block(p, ne=1 << 20)
        assert ops.block_workingset_bytes(p, eb) <= ops.VMEM_BUDGET_BYTES
        assert eb >= 8
