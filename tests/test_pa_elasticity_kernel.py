"""Pallas PAop kernel: shape/dtype sweep against the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.basis import basis_tables
from repro.kernels.pa_elasticity import ops
from repro.kernels.pa_elasticity.ref import paop_ref


def _setup(p, ne, dtype, seed=0):
    tb = basis_tables(p)
    rng = np.random.default_rng(seed)
    d1, q1 = tb.d1d, tb.q1d
    x = jnp.asarray(rng.standard_normal((ne, 3, d1, d1, d1)), dtype)
    lam = jnp.asarray(rng.random((ne, q1, q1, q1)) + 0.5, dtype)
    mu = jnp.asarray(rng.random((ne, q1, q1, q1)) + 0.5, dtype)
    jinv = jnp.asarray(np.diag([2.0, 3.0, 4.0]), dtype)
    B = jnp.asarray(tb.B, dtype)
    G = jnp.asarray(tb.G, dtype)
    return x, lam, mu, jinv, B, G


@pytest.mark.parametrize("p", [1, 2, 3, 4, 6, 8])
@pytest.mark.parametrize("ne", [1, 3, 8])
def test_kernel_matches_oracle_f32(p, ne):
    x, lam, mu, jinv, B, G = _setup(p, ne, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=4, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    scale = float(jnp.abs(ref).max())
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-5 * scale, rtol=2e-4)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_kernel_matches_oracle_f64(p):
    x, lam, mu, jinv, B, G = _setup(p, 4, jnp.float64)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=2, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-12)


@pytest.mark.parametrize("eb", [2, 4, 8])
def test_block_size_invariance(eb):
    """Result must not depend on the VMEM tiling choice."""
    x, lam, mu, jinv, B, G = _setup(3, 8, jnp.float32)
    y1 = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=eb, interpret=True)
    y2 = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)


def test_padding_path():
    """ne not divisible by eb exercises the pad/trim wrapper."""
    x, lam, mu, jinv, B, G = _setup(2, 5, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, eb=4, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=1e-5 * float(jnp.abs(ref).max()))


def test_clamp_never_exceeds_element_count():
    """The clamp must bound the block by ne (padding < 2x), fixing the
    old ``eb=128, ne=12 -> pad to 128`` >10x blow-up."""
    for ne in (1, 2, 3, 5, 7, 12, 100, 129):
        for eb in (1, 2, 8, 128, 1024):
            got = ops.clamp_elements_per_block(eb, ne)
            assert 1 <= got <= ne, (eb, ne, got)
            assert got <= eb or eb > ne, (eb, ne, got)
            padded = ne + (-ne) % got
            assert padded < 2 * ne or got == 1, (eb, ne, got, padded)


def test_clamp_prefers_exact_divisors():
    """When a divisor of ne at least half the block exists, it is chosen
    (zero padding beats a slightly larger block)."""
    assert ops.clamp_elements_per_block(128, 12) == 12
    assert ops.clamp_elements_per_block(8, 12) == 6
    assert ops.clamp_elements_per_block(4, 12) == 4
    assert ops.clamp_elements_per_block(8, 64) == 8
    # prime ne with no useful divisor: keep the clamped block, pad < 2x
    assert ops.clamp_elements_per_block(4, 7) == 4


@pytest.mark.parametrize("ne", [1, 3, 12, 64])
def test_elements_per_block_bounded_by_ne(ne):
    for p in (1, 2, 4, 8):
        eb = ops.elements_per_block(p, ne)
        assert 1 <= eb <= ne


def test_small_mesh_padding_roundtrip():
    """The regression shape from the issue (small ne, auto eb): result
    must round-trip through pad/trim and match the oracle."""
    x, lam, mu, jinv, B, G = _setup(2, 12, jnp.float32)
    y = ops.pa_elasticity(x, lam, mu, jinv, B, G, interpret=True)
    ref = paop_ref(x, lam, mu, jinv, B, G)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=1e-5 * float(jnp.abs(ref).max()))


def test_vmem_budget_respected():
    for p in (1, 2, 4, 8):
        eb = ops.elements_per_block(p, ne=1 << 20)
        assert ops.block_workingset_bytes(p, eb) <= ops.VMEM_BUDGET_BYTES
        assert eb >= 8
