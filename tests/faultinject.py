"""Deterministic fault-injection harness for the continuous serving
engine (the ``faults`` lane's shared machinery, imported by
``tests/test_faults.py``).

Three kinds of scripted fault, all deterministic — the same schedule
always dies at the same point:

* :class:`FaultInjector` — crash the service at a named engine point
  once a given step is reached: ``"mid-chunk"`` (right after a chunk is
  dispatched — the deferred consumed vector is lost in flight) or
  ``"between-retire-and-refill"`` (after a retire pass emitted reports
  but before the freed slots refill).  The crash is a
  :class:`SimulatedCrash` raised from inside ``step()``; the test
  abandons the instance (process death) — only the on-disk checkpoints
  survive.
* :func:`torn_checkpoint_write` — die mid-checkpoint: ``np.save``
  raises after N leaves, leaving a ``.tmp-`` staging dir with no
  manifest, exactly what a SIGKILL mid-write leaves behind.
* :func:`run_schedule` — the replayable driver: a schedule is a list of
  ``(step, request)`` arrivals, submitted when the engine's step index
  reaches ``step``.  Tickets equal arrival indices (asserted), so a
  restored run re-submits exactly the arrivals the checkpoint has not
  seen — at the same step boundaries, with the same tickets — and the
  engine replays the undisturbed decision sequence bit-for-bit.
"""

from __future__ import annotations

import contextlib


class SimulatedCrash(RuntimeError):
    """Scripted process death (stands in for SIGKILL in-process)."""


class FaultInjector:
    """Arms one scripted crash point on an ElasticityService instance.

    Usage::

        inj = FaultInjector(service)
        inj.arm("mid-chunk", at_step=3)
        with pytest.raises(SimulatedCrash):
            run_schedule(service, arrivals, recovery)
    """

    POINTS = ("mid-chunk", "between-retire-and-refill")

    def __init__(self, service):
        self.service = service
        self.tripped = False

    def _maybe_trip(self, at_step: int, point: str) -> None:
        if not self.tripped and self.service._step_index >= at_step:
            self.tripped = True
            raise SimulatedCrash(
                f"scripted crash: {point} at step "
                f"{self.service._step_index}"
            )

    def arm(self, point: str, at_step: int) -> None:
        svc = self.service
        if point == "mid-chunk":
            orig = svc._launch_chunk

            def launch(flight):
                orig(flight)  # chunk dispatched; consumed vector in flight
                self._maybe_trip(at_step, point)

            svc._launch_chunk = launch
        elif point == "between-retire-and-refill":
            orig = svc._retire

            def retire(flight):
                orig(flight)  # reports emitted, slots freed
                self._maybe_trip(at_step, point)

            svc._retire = retire
        else:
            raise ValueError(
                f"unknown fault point {point!r} (expected one of "
                f"{self.POINTS})"
            )


@contextlib.contextmanager
def torn_checkpoint_write(after_leaves: int):
    """Crash the next checkpoint mid-write: ``np.save`` dies after
    ``after_leaves`` successful leaf writes, leaving a manifest-less
    ``.tmp-`` staging dir the manager must skip and later GC."""
    import repro.checkpoint.manager as manager_mod

    orig = manager_mod.np.save
    n = 0

    def bomb(path, arr, *args, **kwargs):
        nonlocal n
        n += 1
        if n > after_leaves:
            raise SimulatedCrash(
                f"torn checkpoint write after {after_leaves} leaves"
            )
        return orig(path, arr, *args, **kwargs)

    manager_mod.np.save = bomb
    try:
        yield
    finally:
        manager_mod.np.save = orig


def run_schedule(service, arrivals, recovery=None):
    """Drive ``service`` through a schedule of ``(step, request)``
    arrivals until every arrival is submitted and the engine drains;
    returns the drained reports.

    Replay-consistent by construction: arrival ``j`` always gets ticket
    ``j`` (tickets are sequential in submission order — asserted), a
    checkpoint written after step ``k`` holds exactly the arrivals with
    ``step < k``, and a restored service (``service._next_ticket`` = how
    many the checkpoint saw) re-submits the remainder at the same step
    boundaries.  A :class:`SimulatedCrash` from an armed injector
    propagates to the caller mid-step, after any checkpoint of the
    preceding boundary."""
    i = service._next_ticket  # arrivals the checkpoint already holds
    assert i <= len(arrivals), "schedule shorter than the restored run"
    while True:
        while i < len(arrivals) and arrivals[i][0] <= service._step_index:
            ticket = service.submit(arrivals[i][1])
            assert ticket == i, (ticket, i)
            i += 1
        if i == len(arrivals) and service.idle():
            return service.drain()
        service.step()
        if recovery is not None:
            recovery.maybe_checkpoint()
