"""Benchmark helpers: wall-clock timing of jitted callables + table IO.

Timing on this container is single-core CPU — absolute numbers are NOT
the paper's (AMD EPYC 7713 x 64 ranks); the *relative* structure (PA vs
PAop, the p-sweep shape, the ablation ordering) is what reproduces the
paper's claims.  TPU-target absolute performance lives in the dry-run
roofline (EXPERIMENTS.md §Roofline), not here.
"""

from __future__ import annotations

import time
from typing import Callable

import jax

__all__ = ["time_fn", "fmt_table", "Row"]


def time_fn(fn: Callable, *args, warmup: int = 1, repeats: int = 3,
            min_time_s: float = 0.05) -> float:
    """Median wall-clock seconds of fn(*args) after jit warmup."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        n = 0
        t0 = time.perf_counter()
        dt = 0.0
        while dt < min_time_s:
            out = fn(*args)
            jax.block_until_ready(out)
            n += 1
            dt = time.perf_counter() - t0
        times.append(dt / n)
    times.sort()
    return times[len(times) // 2]


class Row(dict):
    pass


def fmt_table(rows: list[dict], cols: list[str], title: str = "") -> str:
    out = []
    if title:
        out.append(f"### {title}")
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "|".join("---" for _ in cols) + "|")
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            if isinstance(v, float):
                v = f"{v:.4g}"
            cells.append(str(v))
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out)
