"""Operator-apply throughput sweep -> BENCH_operator_sweep.json.

The first artifact of the repo's perf trajectory: measured DoF/s of the
*batched* elasticity operator (S scenarios' materials folded into the
element axis — the apply the serving stack actually runs) swept over
p in {1, 2, 4, 6, 8} and over the Pallas lanes: per p, one ``paop``
einsum baseline row plus one ``paop_pallas`` row per requested lane
(``interpret`` and ``compiled`` by default).  Every row carries the
analytic models it is judged against — the paper-kernel FLOP count, the
PAop streaming-bytes model, the resulting operational intensity, and
the row's placement on the TPU v5e roofline
(``repro.launch.roofline.place_measured``) — plus the lane that
*actually ran*: ``pallas_lane`` is the operator's resolved lane, so a
``compiled`` request on a backend that cannot lower Pallas is recorded
as the interpret run it really was (``lane_requested`` keeps the ask).

``--precision`` sweeps the measurement over precision policies
(``f64`` by default; add ``f32`` / ``mixed`` / ``mixed-bf16`` for the
mixed-precision trajectory).  Each row is measured at its policy's
``precond_dtype`` — the dtype the V-cycle element kernel streams, which
is where the bandwidth-bound bytes live — and records
``precision_policy`` so the artifact carries the axis.

Absolute numbers on this container are CPU-sized — tiny, and that is
fine: the artifact is schema-versioned
(``repro.bench.operator_sweep/v3``, schema checked into
``benchmarks/schemas/``) so successive perf PRs append comparable
points, and ``fig6_roofline`` places the measured rows next to the
analytic OI trajectory.  The emitted document is validated against the
checked-in schema BEFORE being written — a drifting field name fails the
producer, not just the CI consumer.

    PYTHONPATH=src python -m benchmarks.operator_sweep --smoke
    PYTHONPATH=src python -m benchmarks.operator_sweep \
        --out BENCH_operator_sweep.json --batch 4 --precision f64 f32
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax

jax.config.update("jax_enable_x64", True)

from benchmarks.common import fmt_table  # noqa: E402

SCHEMA = "repro.bench.operator_sweep/v3"
SCHEMA_PATH = os.path.join(
    os.path.dirname(__file__), "schemas", "bench_operator_sweep.schema.json"
)

# Refinement per p for the full sweep: roughly equalized element work at
# batch 4 (the fig5 FIXED_DOF idea, one level coarser since the scenario
# fold multiplies the element count).
SWEEP_REFINE = {1: 2, 2: 1, 4: 1, 6: 0, 8: 0}

# Lanes swept per p for the paop_pallas assembly (requested lanes; each
# row also records the lane that actually ran).
SWEEP_LANES = ("interpret", "compiled")


def run(
    ps=(1, 2, 4, 6, 8),
    batch: int = 4,
    refine: int | None = None,
    repeats: int = 3,
    min_time_s: float = 0.05,
    smoke: bool = False,
    lanes=SWEEP_LANES,
    precisions=("f64",),
) -> list[dict]:
    """Artifact rows: per (p, precision policy), one ``paop`` baseline
    plus one ``paop_pallas`` row per requested lane (measured + models +
    roofline placement).  ``--smoke`` shrinks to refine 0 / batch 2 /
    single short repeat — same code path, same schema, CI-sized."""
    from repro.launch.roofline import place_measured
    from repro.obs.throughput import operator_throughput

    cells = []
    for p in ps:
        r = 0 if smoke else (refine if refine is not None else SWEEP_REFINE[p])
        for prec in precisions:
            cells.append((p, r, "paop", None, prec))
            for lane in lanes:
                cells.append((p, r, "paop_pallas", lane, prec))

    rows = []
    for p, r, assembly, lane, prec in cells:
        row = operator_throughput(
            p,
            r,
            2 if smoke else batch,
            assembly=assembly,
            pallas_lane=lane,
            precision=prec,
            repeats=1 if smoke else repeats,
            min_time_s=0.0 if smoke else min_time_s,
        )
        placed = place_measured(
            flops_per_apply=row["flops_per_apply"],
            bytes_per_apply=row["bytes_per_apply"],
            t_apply_s=row["t_apply_s"],
        )
        row["v5e_roof_fraction"] = placed.fraction
        row["v5e_bound"] = placed.bound
        rows.append(row)
    return rows


def make_document(rows: list[dict], smoke: bool) -> dict:
    from repro.kernels.pa_elasticity.ops import resolve_lane
    from repro.launch.roofline import V5E

    auto_lane = resolve_lane("auto")
    return {
        "schema": SCHEMA,
        "benchmark": "operator_sweep",
        "generated_unix": time.time(),
        "smoke": smoke,
        "host": {
            "platform": platform.platform(),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "pallas_lane_auto": auto_lane,
            "pallas_interpret": auto_lane == "interpret",
            "x64": True,
        },
        "target_hw": {
            "name": V5E.name,
            "peak_flops": V5E.peak_flops,
            "hbm_bw": V5E.hbm_bw,
        },
        "rows": rows,
    }


def write_artifact(doc: dict, out: str) -> None:
    """Self-validate against the checked-in schema, then write."""
    from repro.obs.schema import validate_json

    with open(SCHEMA_PATH) as f:
        validate_json(doc, json.load(f))
    with open(out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--p", type=int, nargs="+", default=[1, 2, 4, 6, 8])
    ap.add_argument("--batch", type=int, default=4,
                    help="scenarios folded into the batched operator")
    ap.add_argument("--lanes", nargs="+", default=list(SWEEP_LANES),
                    choices=["auto", "compiled", "interpret"],
                    help="requested paop_pallas lanes swept per p (rows "
                         "record the lane that actually ran)")
    ap.add_argument("--precision", nargs="+", default=["f64"],
                    choices=["f64", "f32", "mixed", "mixed-bf16"],
                    help="precision policies swept per p (each row is "
                         "measured at the policy's precond_dtype — the "
                         "bytes the V-cycle element kernel streams)")
    ap.add_argument("--refine", type=int, default=None,
                    help="override the per-p refinement map")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: refine 0, batch 2, one short repeat")
    ap.add_argument("--out", default="BENCH_operator_sweep.json",
                    help="artifact path (schema-validated before writing)")
    args = ap.parse_args()

    rows = run(
        ps=tuple(args.p),
        batch=args.batch,
        refine=args.refine,
        repeats=args.repeats,
        smoke=args.smoke,
        lanes=tuple(args.lanes),
        precisions=tuple(args.precision),
    )
    print(fmt_table(
        rows,
        ["p", "assembly", "pallas_lane", "precision_policy", "refine",
         "batch", "dofs",
         "t_apply_s", "dofs_per_s", "gbytes_per_s", "oi_model",
         "v5e_roof_fraction", "v5e_bound"],
        title=(
            "Batched operator apply throughput "
            f"({'smoke, ' if args.smoke else ''}lane column is the lane "
            "that ran — trajectory artifact, not absolute perf)"
        ),
    ))
    doc = make_document(rows, smoke=args.smoke)
    write_artifact(doc, args.out)
    print(f"artifact -> {args.out} (schema {SCHEMA})")


if __name__ == "__main__":
    main()
