"""Paper Table 5: per-element FLOPs, FLOPs/DoF, operational intensity.

FLOPs are counted two ways and cross-checked:
  * analytic — closed-form counts of the sum-factorized sweeps (the
    paper's source-derived accounting),
  * jaxpr    — the repo's loop-aware cost walker on the actual kernel.

OI(theory) = FLOPs/elem / bytes-moved/elem with the PAop streaming model
(read x_e, lambda_w, mu_w; write y_e — the B/G tables and all
intermediates are on-chip, Sec. 4.5): matches the paper's finding that
OI grows with p (the sweet-spot shift).  The Base/PAop FLOP ratio
reproduces the O(p^2) gap of the dense contraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table
from repro.core.basis import basis_tables
from repro.launch.jaxpr_cost import cost_of_fn

__all__ = ["analytic_flops_per_elem", "run", "main"]


def analytic_flops_per_elem(p: int) -> dict[str, float]:
    """Closed-form multiply+add counts per element (d=3, vector)."""
    from repro.core.flops import dense_flops_per_elem, paop_flops_per_elem

    return {
        "paop": paop_flops_per_elem(p),
        "dense_baseline": dense_flops_per_elem(p),
    }


def run(ps=(1, 2, 4, 8), dtype=jnp.float64) -> list[dict]:
    from repro.kernels.pa_elasticity.ref import paop_ref

    itemsize = jnp.dtype(dtype).itemsize
    rows = []
    for p in ps:
        tb = basis_tables(p)
        D, Q = tb.d1d, tb.q1d
        a = analytic_flops_per_elem(p)

        ne = 4
        x = jax.ShapeDtypeStruct((ne, 3, D, D, D), dtype)
        lw = jax.ShapeDtypeStruct((ne, Q, Q, Q), dtype)
        jinv = jax.ShapeDtypeStruct((3, 3), dtype)
        Bt = jax.ShapeDtypeStruct((Q, D), dtype)
        jc = cost_of_fn(paop_ref, x, lw, lw, jinv, Bt, Bt)

        # PAop streaming model: x_e + y_e + lambda_w + mu_w per element
        bytes_elem = itemsize * (2 * 3 * D**3 + 2 * Q**3)
        dofs_elem = 3 * p**3  # asymptotic global DoFs per element (paper)
        rows.append({
            "p": p, "D1D": D, "Q1D": Q,
            "flops_elem_analytic": a["paop"],
            "flops_elem_jaxpr": jc.flops / ne,
            "flops_per_dof": a["paop"] / dofs_elem,
            "oi_theory": a["paop"] / bytes_elem,
            "ratio_base_over_paop": a["dense_baseline"] / a["paop"],
        })
    return rows


def main(fast: bool = False):
    rows = run()
    print(fmt_table(
        rows,
        ["p", "D1D", "Q1D", "flops_elem_analytic", "flops_elem_jaxpr",
         "flops_per_dof", "oi_theory", "ratio_base_over_paop"],
        title="Table 5 analogue: FLOPs/elem, FLOPs/DoF, OI (f64)",
    ))
    return rows


if __name__ == "__main__":
    main()
