"""Paper Table 7: cumulative ablation of the four optimization stages.

Stages in build order (C1/C2/C3/PAop — the paper's Table 7 ordering):
  PA (baseline)         -> MFEM v4.8-equivalent dense-contraction dataflow
  + Sum Factorization   -> pa_sumfact      (C1, Sec. 4.4)
  + Voigt Notation      -> pa_sumfact_voigt(C2, Sec. 4.3)
  + Kernel Fusion       -> paop            (C3, Sec. 4.2: the fused
                           per-element chain is one XLA producer-consumer
                           region; no whole-mesh QVec intermediates)
  + Slice/Tile Loops    -> paop_pallas     (Sec. 4.5's working-set bound,
                           realized as the Pallas VMEM block kernel;
                           timed in interpret mode on CPU, so its wall
                           time here is NOT meaningful — marked)

Reports kernel (AddMult) time and marginal speedup at fixed problem
size.  CPU single-core: relative structure reproduces the paper's story.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, time_fn
from repro.core.operators import ElasticityOperator
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space

STAGES = [
    ("PA (baseline)", "pa_baseline"),
    ("+ Sum Factorization (C1)", "pa_sumfact"),
    ("+ Voigt Notation (C2)", "pa_sumfact_voigt"),
    ("+ Kernel Fusion (C3=PAop)", "paop"),
]


def run(p: int = 8, refine: int = 0, dtype=jnp.float64) -> list[dict]:
    mesh = beam_hex().refined(refine)
    space = H1Space(mesh, p)
    x = jnp.asarray(
        jax.random.normal(jax.random.PRNGKey(0), (space.nscalar, 3), dtype)
    )
    rows = []
    prev = None
    for label, assembly in STAGES:
        op = ElasticityOperator(space, assembly=assembly, dtype=dtype)
        f = jax.jit(op.apply)
        t = time_fn(f, x)
        row = {
            "stage": label,
            "assembly": assembly,
            "kernel_time_s": t,
            "marginal_speedup": (prev / t) if prev else float("nan"),
            "ndof": space.ndof,
            "mdof_per_s": space.ndof / t / 1e6,
        }
        rows.append(row)
        prev = t
    base = rows[0]["kernel_time_s"]
    for r in rows:
        r["cumulative_speedup"] = base / r["kernel_time_s"]
    return rows


def main(fast: bool = False):
    # refine=1 -> 64 elements: enough work that the contraction cost (not
    # dispatch overhead) is what the stages differentiate.
    rows = run(p=8 if not fast else 4, refine=0 if fast else 1)
    print(fmt_table(
        rows,
        ["stage", "kernel_time_s", "marginal_speedup", "cumulative_speedup",
         "mdof_per_s"],
        title="Table 7 analogue: cumulative ablation (p=8, beam, CPU wall)",
    ))
    return rows


if __name__ == "__main__":
    main()
