"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only table7,fig5]

Writes combined markdown to stdout (tee to bench_output.txt) and CSVs to
benchmarks/out/.
"""

from __future__ import annotations

import argparse
import csv
import os
import time

import jax

# FEM comparisons run in f64 (the paper's CPU precision); LM benches pass
# explicit f32 dtypes and are unaffected.
jax.config.update("jax_enable_x64", True)

SUITES = ["table3", "table4", "table5", "table7", "fig5", "fig6", "lm"]


def _write_csv(name: str, rows: list[dict]):
    if not rows:
        return
    os.makedirs("benchmarks/out", exist_ok=True)
    cols = list(rows[0].keys())
    with open(f"benchmarks/out/{name}.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=cols, extrasaction="ignore")
        w.writeheader()
        w.writerows(rows)


def _lm_microbench(fast: bool) -> list[dict]:
    """Token throughput of the reduced LM configs (train + decode) —
    the framework-side sanity benchmark."""
    import dataclasses

    import jax.numpy as jnp

    from benchmarks.common import fmt_table, time_fn
    from repro.configs.base import ShapeConfig, get_reduced
    from repro.data.pipeline import make_batch
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import make_train_step, train_state_init

    archs = ["qwen3_17b", "mixtral_8x7b", "zamba2_27b"]
    if not fast:
        archs += ["xlstm_125m", "musicgen_medium"]
    shape = ShapeConfig("bench", "train", 128, 4)
    rows = []
    for arch in archs:
        cfg = dataclasses.replace(get_reduced(arch), dtype="float32",
                                  chunk_size=32)
        state = train_state_init(jax.random.PRNGKey(0), cfg)
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, 0).items()}
        step = jax.jit(make_train_step(cfg, AdamWConfig()))
        t = time_fn(lambda s, b: step(s, b)[1]["loss"], state, batch,
                    warmup=1, repeats=2)
        toks = shape.seq_len * shape.global_batch
        rows.append({"arch": arch, "tokens_per_s": toks / t,
                     "step_time_s": t})
    print(fmt_table(rows, ["arch", "step_time_s", "tokens_per_s"],
                    title="LM reduced-config train-step microbench (CPU)"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller p-range / fewer cells")
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else SUITES

    t0 = time.time()
    print(f"# Benchmark run (devices: {jax.devices()})\n")
    if "table5" in only:
        from benchmarks import table5_flops

        _write_csv("table5", table5_flops.main(args.fast))
        print()
    if "table7" in only:
        from benchmarks import table7_ablation

        _write_csv("table7", table7_ablation.main(args.fast))
        print()
    if "fig5" in only:
        from benchmarks import fig5_throughput

        _write_csv("fig5", fig5_throughput.main(args.fast))
        print()
    if "table3" in only:
        from benchmarks import table3_preconditioners

        _write_csv("table3", table3_preconditioners.main(args.fast))
        print()
    if "table4" in only:
        from benchmarks import table4_solver

        _write_csv("table4", table4_solver.main(args.fast))
        print()
    if "fig6" in only:
        from benchmarks import fig6_roofline

        _write_csv("fig6", fig6_roofline.main(args.fast))
        print()
    if "lm" in only:
        _write_csv("lm_micro", _lm_microbench(args.fast))
    print(f"\ntotal benchmark wall time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
