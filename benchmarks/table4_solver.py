"""Paper Table 4 / Fig. 4: solver-level comparison of FA / PA / PAop at
fixed problem size across p, under the unified GMG preconditioner.

Reports iterations, Assembly (= Prec + Form-LS), Solve, Total, speedups
vs FA and vs PA, and the stored-operator memory footprint (the paper's
peak-memory columns; here measured as the operator representation size —
CSR vs quadrature data — the dominant scaling term).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import fmt_table
from repro.core.operators import ElasticityOperator
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space
from repro.launch.solve import solve_beam

# per-p refinements for ~fixed DoFs (small CPU-scale problem)
FIXED = {1: 2, 2: 1, 4: 1, 8: 0}


def run(ps=(1, 2, 4, 8)) -> list[dict]:
    rows = []
    for p in ps:
        refine = FIXED[p]
        per_assembly = {}
        for assembly in ("fa", "pa_sumfact_voigt", "paop"):
            rep = solve_beam(p, n_h_refine=refine, assembly=assembly)
            space = H1Space(beam_hex().refined(refine), p)
            op = ElasticityOperator(space, assembly=assembly, dtype=jnp.float64)
            per_assembly[assembly] = (rep, op.memory_bytes())
        fa_t = per_assembly["fa"][0].t_total
        pa_t = per_assembly["pa_sumfact_voigt"][0].t_total
        for assembly, label in (("fa", "FA"), ("pa_sumfact_voigt", "PA"),
                                ("paop", "PAop")):
            rep, mem = per_assembly[assembly]
            rows.append({
                "p": p, "alg": label, "ndof": rep.ndof,
                "iters": rep.iterations,
                "assembly_s": rep.t_precond + rep.t_form_ls,
                "solve_s": rep.t_solve, "total_s": rep.t_total,
                "speedup_vs_fa": fa_t / rep.t_total,
                "speedup_vs_pa": pa_t / rep.t_total,
                "operator_mem_mb": mem / 2**20,
            })
    return rows


def main(fast: bool = False):
    rows = run(ps=(1, 2, 4) if fast else (1, 2, 4, 8))
    print(fmt_table(
        rows,
        ["p", "alg", "ndof", "iters", "assembly_s", "solve_s", "total_s",
         "speedup_vs_pa", "operator_mem_mb"],
        title="Table 4 analogue: solver-level FA/PA/PAop (CPU wall)",
    ))
    return rows


if __name__ == "__main__":
    main()
