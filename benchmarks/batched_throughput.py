"""Batched-solve throughput: scenarios/sec of the ElasticityService vs
the sequential solve_beam driver (p=2, refine=1 beam benchmark).

For each batch size B the service solves one warm generation of B mixed
scenarios (the first call pays hierarchy build + compile; the timed
calls reuse the cached compiled program, which is the steady-state
serving regime).  The sequential baseline is solve_beam called once per
scenario — it re-builds the hierarchy and re-traces every call, exactly
what the service amortizes.

``--continuous`` instead compares the two scheduling policies on a
mixed-tolerance workload (alternating loose/tight rel_tol): generational
batching is gated by the slowest row of every generation, while
continuous batching retires loose rows early, refills their slots from
the queue, and lets the draining tail shrink to smaller padding buckets.
Reports throughput and per-request tail latency for both, plus the
scheduler-stats columns (chunks dispatched, mean chunk length, wasted
iterations) of the chosen ``--chunk-policy`` — fixed, adaptive
(cadence-driven chunk lengths) or shard-adaptive (per-device cadence +
placement); numerics are identical across policies, so the columns
isolate pure scheduling effects (see docs/SCHEDULING.md).

``--devices N`` shards the scenario axis over N devices (forcing N
virtual XLA host devices on CPU — set before backend init, which is why
the heavy imports live inside the functions).  Throughput always counts
REAL scenarios only: padding rows added for bucket or device alignment
ride along in ``SolveReport.padded_rows`` and are excluded from the
scenarios/sec math, so ``--devices 8`` numbers are honest.

``--heterogeneous`` swaps the attribute-dict materials for per-element
``(lam_e, mu_e)`` lognormal random fields (a 4-field vocabulary, so the
continuous engine's digest-keyed prep-row reuse still engages).  This is
the workload the per-element material path exists for; comparing a run
with and without the flag shows the cost of genuinely heterogeneous
coefficients is the same compiled program — materials are runtime
arguments either way.

    PYTHONPATH=src python -m benchmarks.batched_throughput [--quick]
    PYTHONPATH=src python -m benchmarks.batched_throughput --continuous
    PYTHONPATH=src python -m benchmarks.batched_throughput \
        --continuous --chunk-policy adaptive
    PYTHONPATH=src python -m benchmarks.batched_throughput --devices 8 --continuous
    PYTHONPATH=src python -m benchmarks.batched_throughput --heterogeneous --quick
"""

from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from benchmarks.common import fmt_table  # noqa: E402

P, REFINE = 2, 1


def _materials_for(i: int, hetero: bool):
    """Request i's materials: attribute dicts by default, or per-element
    lognormal random fields (4-seed vocabulary) with --heterogeneous."""
    if not hetero:
        return {1: (50.0 + 5 * (i % 3), 50.0), 2: (1.0 + 0.5 * (i % 2), 1.0)}
    from repro.fem.mesh import beam_hex
    from repro.launch.serve_solve import make_material_field

    return make_material_field("lognormal:11", beam_hex(), REFINE, i)


def make_requests(n: int, rel_tol: float = 1e-6, hetero: bool = False):
    from repro.serve.elasticity_service import SolveRequest

    return [
        SolveRequest(
            p=P,
            refine=REFINE,
            materials=_materials_for(i, hetero),
            traction=(0.0, 0.0, -1e-2 * (1 + 0.1 * (i % 4))),
            rel_tol=rel_tol,
        )
        for i in range(n)
    ]


def _real_throughput(reports, dt: float) -> float:
    """Scenarios/sec over REAL requests.  ``reports`` has one entry per
    real request by construction (padding is never surfaced) — guard that
    invariant here so a padding-accounting regression can't silently
    inflate --devices numbers."""
    assert all(r.padded_rows >= r.batch_size > 0 for r in reports)
    return len(reports) / dt


def bench_batched(batch: int, repeats: int, mesh=None, hetero: bool = False) -> dict:
    from repro.serve.elasticity_service import ElasticityService

    service = ElasticityService(max_batch=batch, mesh=mesh)
    # Warm: builds the hierarchy and compiles the batched program.
    t0 = time.perf_counter()
    service.solve(make_requests(batch, hetero=hetero))
    t_warm = time.perf_counter() - t0
    # Steady state: same key -> cached program, setup must be ~0.
    times, setups, pad = [], [], 0
    for _ in range(repeats):
        reqs = make_requests(batch, hetero=hetero)
        t0 = time.perf_counter()
        reports = service.solve(reqs)
        times.append(time.perf_counter() - t0)
        setups.append(reports[0].t_setup)
        pad = max(pad, reports[0].padded_rows)
        assert all(r.converged for r in reports)
        assert len(reports) == batch  # padding rows never surfaced
    t = float(np.median(times))
    return {
        "batch": batch,
        "padded_rows": pad,
        "scenarios_per_s": batch / t,
        "t_generation_s": t,
        "t_warm_s": t_warm,
        "t_setup_cached_s": float(np.median(setups)),
    }


def bench_sequential(n: int) -> dict:
    from repro.launch.solve import solve_beam

    t0 = time.perf_counter()
    for req in make_requests(n):
        rep = solve_beam(
            req.p,
            req.refine,
            assembly="paop",
            rel_tol=req.rel_tol,
            materials=req.materials,
            traction=req.traction,
        )
        assert rep.final_rel_norm < req.rel_tol
    t = time.perf_counter() - t0
    return {
        "batch": "sequential",
        "padded_rows": n,
        "scenarios_per_s": n / t,
        "t_generation_s": t / n,
        "t_warm_s": 0.0,
        "t_setup_cached_s": float("nan"),
    }


def make_mixed_tol_requests(
    n: int, loose: float = 1e-4, tight: float = 1e-10, hetero: bool = False
):
    """Mixed-tolerance workload: one tight-tolerance request per four
    loose ones, with varied materials and tractions — the serving regime
    where a minority of slow scenarios gates every generation while the
    loose majority could have streamed through the freed slots."""
    from repro.serve.elasticity_service import SolveRequest

    return [
        SolveRequest(
            p=P,
            refine=REFINE,
            materials=_materials_for(i, hetero),
            traction=(0.0, 2e-3 * (i % 2), -1e-2 * (1 + 0.1 * (i % 4))),
            rel_tol=tight if i % 4 == 0 else loose,
        )
        for i in range(n)
    ]


def _latency_percentiles(latencies: list[float]) -> tuple[float, float]:
    """p50/p95 through the obs histogram quantile estimator — the SAME
    implementation the service's ``latency_summary()`` reports, so the
    benchmark's tail-latency columns and the serving summary can never
    drift apart (this replaced an ad-hoc np.percentile on raw lists)."""
    from repro.obs.metrics import Histogram, default_latency_edges

    h = Histogram(default_latency_edges())
    for v in latencies:
        h.observe(v)
    return h.quantile(0.5), h.quantile(0.95)


def _time_generational(service, n: int, hetero: bool = False):
    reqs = make_mixed_tol_requests(n, hetero=hetero)
    t0 = time.perf_counter()
    reports = service.solve(reqs)
    dt = time.perf_counter() - t0
    assert all(r.converged for r in reports)
    assert all(r.final_rel_norm <= r.request.rel_tol for r in reports)
    assert len(reports) == n  # padding rows never surfaced
    # A request is done when its generation retires; its latency is the
    # cumulative time of all generations up to and including its own
    # (generations of one key run back-to-back).
    gen_t = {r.generation: r.t_solve for r in reports}
    cum = np.cumsum([gen_t[g] for g in sorted(gen_t)])
    return dt, reports, [float(cum[r.generation]) for r in reports]


def _time_continuous(service, n: int, hetero: bool = False):
    reqs = make_mixed_tol_requests(n, hetero=hetero)
    before = {
        k: service.stats[k]
        for k in ("chunks", "chunk_iters_dispatched", "wasted_iters")
    }
    t0 = time.perf_counter()
    reports = service.solve_continuous(reqs)
    dt = time.perf_counter() - t0
    assert all(r.converged for r in reports)
    assert all(r.final_rel_norm <= r.request.rel_tol for r in reports)
    assert len(reports) == n  # padding rows never surfaced
    delta = {k: service.stats[k] - v for k, v in before.items()}
    sched = {
        "chunks": delta["chunks"],
        "mean_chunk": (
            delta["chunk_iters_dispatched"] / delta["chunks"]
            if delta["chunks"]
            else 0.0
        ),
        "wasted_iters": delta["wasted_iters"],
    }
    # admission -> retirement latency per request
    return dt, reports, [r.t_solve for r in reports], sched


def run_continuous(
    batch: int = 16,
    n_requests: int | None = None,
    repeats: int = 3,
    chunk_iters: int = 8,
    chunk_policy: str = "fixed",
    mesh=None,
    hetero: bool = False,
    precision: str = "f64",
) -> list[dict]:
    """Continuous vs generational on the mixed-tolerance workload.

    ``chunk_policy`` selects the continuous engine's chunk scheduler
    (fixed / adaptive / shard-adaptive — numerics are identical, so the
    comparison isolates pure scheduling effects), and the continuous row
    carries the scheduler counters: chunks dispatched, mean chosen chunk
    length, and wasted iterations (slot-iterations near-converged rows
    idled inside chunks).

    The repeats of the two policies are interleaved in time and each
    policy reports its best repeat: on a shared/throttled CPU a transient
    co-tenant spike would otherwise land on one policy's block and
    dominate the ratio."""
    from repro.serve.elasticity_service import ElasticityService

    n = 2 * batch if n_requests is None else n_requests
    svc_gen = ElasticityService(
        max_batch=batch, mesh=mesh, precision=precision
    )
    svc_cont = ElasticityService(
        max_batch=batch, chunk_iters=chunk_iters,
        chunk_policy=chunk_policy, mesh=mesh, precision=precision,
    )
    # Warm: hierarchy build + one compile per (bucket, reset-flag) the
    # workload visits (16, 8, ... as the continuous tail drains).
    svc_gen.solve(make_mixed_tol_requests(n, hetero=hetero))
    svc_cont.solve_continuous(make_mixed_tol_requests(n, hetero=hetero))
    runs_gen, runs_cont = [], []
    for _ in range(repeats):
        runs_gen.append(
            _time_generational(svc_gen, n, hetero=hetero) + (None,)
        )
        runs_cont.append(_time_continuous(svc_cont, n, hetero=hetero))
    rows = []
    for policy, runs in (
        ("generational", runs_gen),
        (f"continuous({chunk_policy}, k={chunk_iters})", runs_cont),
    ):
        # throughput AND latencies from the same (best) repeat
        t, reports, lat, sched = min(runs, key=lambda r: r[0])
        p50, p95 = _latency_percentiles(lat)
        row = {
            "policy": policy,
            "scenarios_per_s": _real_throughput(reports, t),
            "t_workload_s": t,
            "latency_p50_s": p50,
            "latency_p95_s": p95,
            "chunks": "-",
            "mean_chunk": "-",
            "wasted_iters": "-",
        }
        if sched is not None:
            row["chunks"] = sched["chunks"]
            row["mean_chunk"] = round(sched["mean_chunk"], 2)
            row["wasted_iters"] = sched["wasted_iters"]
        rows.append(row)
    rows[1]["speedup_vs_generational"] = (
        rows[1]["scenarios_per_s"] / rows[0]["scenarios_per_s"]
    )
    return rows


def run(
    fast: bool = False, quick: bool = False, mesh=None, hetero: bool = False
) -> list[dict]:
    batches = [1, 4] if quick else ([1, 4, 16] if fast else [1, 4, 16, 64])
    n_seq = 2 if quick else 4
    repeats = 1 if quick else 3
    # The sequential solve_beam baseline only speaks attribute dicts
    # (its hierarchy builder takes one dict for every level), so under
    # --heterogeneous it would be a DIFFERENT workload — comparing the
    # two would conflate material-form cost with conditioning.  Honest
    # math: no sequential row and no speedup column in that mode.
    rows = [] if hetero else [bench_sequential(n_seq)]
    seq_rate = rows[0]["scenarios_per_s"] if rows else None
    for b in batches:
        row = bench_batched(b, repeats, mesh=mesh, hetero=hetero)
        if seq_rate is not None:
            row["speedup_vs_sequential"] = row["scenarios_per_s"] / seq_rate
        rows.append(row)
    return rows


SERVING_SCHEMA = "repro.bench.serving/v1"


def write_serving_artifact(rows: list[dict], args, out: str) -> None:
    """BENCH_serving.json: the continuous-vs-generational comparison as
    a schema-versioned artifact (``repro.bench.serving/v1``), validated
    against the checked-in schema BEFORE writing.  Scheduler columns are
    null for the generational row (the table prints '-')."""
    import json
    import os

    from repro.obs.schema import validate_json

    def _num(v):
        return None if v == "-" else v

    doc = {
        "schema": SERVING_SCHEMA,
        "benchmark": "batched_throughput",
        "generated_unix": time.time(),
        "workload": {
            "p": P,
            "refine": REFINE,
            "batch": args.batch,
            "n_requests": args.n_requests or 2 * args.batch,
            "chunk_iters": args.chunk_iters,
            "chunk_policy": args.chunk_policy,
            "devices": args.devices or 1,
            "heterogeneous": bool(args.heterogeneous),
            "repeats": args.repeats,
            "precision_policy": args.precision,
        },
        "rows": [
            {
                **{k: v for k, v in r.items()},
                "chunks": _num(r["chunks"]),
                "mean_chunk": _num(r["mean_chunk"]),
                "wasted_iters": _num(r["wasted_iters"]),
            }
            for r in rows
        ],
    }
    schema_path = os.path.join(
        os.path.dirname(__file__), "schemas", "bench_serving.schema.json"
    )
    with open(schema_path) as f:
        validate_json(doc, json.load(f))
    with open(out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: batches {1, 4}, single repeat")
    ap.add_argument("--fast", action="store_true", help="skip batch 64")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous vs generational batching on a "
                         "mixed-tolerance workload")
    ap.add_argument("--batch", type=int, default=16,
                    help="max_batch for --continuous (default 16)")
    ap.add_argument("--n-requests", type=int, default=None,
                    help="workload size for --continuous (default 2*batch)")
    ap.add_argument("--chunk-iters", type=int, default=8,
                    help="PCG iterations per continuous chunk (fixed "
                         "policy) / no-history fallback (adaptive)")
    ap.add_argument("--chunk-policy", default="fixed",
                    choices=["fixed", "adaptive", "shard-adaptive"],
                    help="chunk scheduler for --continuous (identical "
                         "numerics; scheduler-stats columns show the "
                         "chunks/waste difference)")
    ap.add_argument("--precision", default="f64",
                    choices=["f64", "f32", "mixed", "mixed-bf16"],
                    help="precision policy both services run the "
                         "workload under (recorded in the artifact's "
                         "workload block)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the scenario axis over N devices (forces "
                         "N virtual host devices on CPU)")
    ap.add_argument("--heterogeneous", action="store_true",
                    help="per-element lognormal (lam_e, mu_e) random "
                         "fields instead of attribute dicts")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="with --continuous: write the comparison as a "
                         "schema-versioned BENCH_serving.json artifact "
                         "(validated before writing)")
    args = ap.parse_args()

    # Env must be set before anything touches the jax backend.
    from repro.distributed.sharding import (
        force_host_device_count,
        scenario_mesh,
    )

    force_host_device_count(args.devices)
    mesh = None
    if args.devices is not None:
        mesh = scenario_mesh(args.devices)
        print(f"scenario mesh: {mesh.devices.size} devices "
              f"({jax.device_count()} visible)")

    mats = "lognormal fields" if args.heterogeneous else "attribute dicts"
    if args.continuous:
        rows = run_continuous(
            batch=args.batch,
            n_requests=args.n_requests,
            repeats=args.repeats,
            chunk_iters=args.chunk_iters,
            chunk_policy=args.chunk_policy,
            mesh=mesh,
            hetero=args.heterogeneous,
            precision=args.precision,
        )
        print(
            fmt_table(
                rows,
                [
                    "policy",
                    "scenarios_per_s",
                    "t_workload_s",
                    "latency_p50_s",
                    "latency_p95_s",
                    "chunks",
                    "mean_chunk",
                    "wasted_iters",
                    "speedup_vs_generational",
                ],
                title=(
                    f"Continuous vs generational batching "
                    f"(mixed tolerances, {mats}, batch={args.batch}, "
                    f"p={P}, refine={REFINE}, "
                    f"devices={args.devices or 1}, CPU)"
                ),
            )
        )
        if args.bench_out:
            write_serving_artifact(rows, args, args.bench_out)
            print(f"artifact -> {args.bench_out}")
        return
    rows = run(
        fast=args.fast, quick=args.quick, mesh=mesh,
        hetero=args.heterogeneous,
    )
    cols = [
        "batch",
        "padded_rows",
        "scenarios_per_s",
        "t_generation_s",
        "t_warm_s",
        "t_setup_cached_s",
    ]
    if not args.heterogeneous:
        # vs-sequential comparison only exists for the dict workload the
        # sequential baseline can actually run.
        cols.append("speedup_vs_sequential")
    print(
        fmt_table(
            rows,
            cols,
            title=(
                f"Batched GMG-PCG throughput ({mats}, p={P}, "
                f"refine={REFINE}, devices={args.devices or 1}, CPU)"
            ),
        )
    )


if __name__ == "__main__":
    main()
