"""Batched-solve throughput: scenarios/sec of the ElasticityService vs
the sequential solve_beam driver (p=2, refine=1 beam benchmark).

For each batch size B the service solves one warm generation of B mixed
scenarios (the first call pays hierarchy build + compile; the timed
calls reuse the cached compiled program, which is the steady-state
serving regime).  The sequential baseline is solve_beam called once per
scenario — it re-builds the hierarchy and re-traces every call, exactly
what the service amortizes.

    PYTHONPATH=src python -m benchmarks.batched_throughput [--quick]
"""

from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from benchmarks.common import fmt_table  # noqa: E402
from repro.launch.solve import solve_beam  # noqa: E402
from repro.serve.elasticity_service import (  # noqa: E402
    ElasticityService,
    SolveRequest,
)

P, REFINE = 2, 1


def make_requests(n: int, rel_tol: float = 1e-6) -> list[SolveRequest]:
    return [
        SolveRequest(
            p=P,
            refine=REFINE,
            materials={1: (50.0 + 5 * (i % 3), 50.0), 2: (1.0 + 0.5 * (i % 2), 1.0)},
            traction=(0.0, 0.0, -1e-2 * (1 + 0.1 * (i % 4))),
            rel_tol=rel_tol,
        )
        for i in range(n)
    ]


def bench_batched(batch: int, repeats: int) -> dict:
    service = ElasticityService(max_batch=batch)
    # Warm: builds the hierarchy and compiles the batched program.
    t0 = time.perf_counter()
    service.solve(make_requests(batch))
    t_warm = time.perf_counter() - t0
    # Steady state: same key -> cached program, setup must be ~0.
    times, setups = [], []
    for _ in range(repeats):
        reqs = make_requests(batch)
        t0 = time.perf_counter()
        reports = service.solve(reqs)
        times.append(time.perf_counter() - t0)
        setups.append(reports[0].t_setup)
        assert all(r.converged for r in reports)
    t = float(np.median(times))
    return {
        "batch": batch,
        "scenarios_per_s": batch / t,
        "t_generation_s": t,
        "t_warm_s": t_warm,
        "t_setup_cached_s": float(np.median(setups)),
    }


def bench_sequential(n: int) -> dict:
    t0 = time.perf_counter()
    for req in make_requests(n):
        rep = solve_beam(
            req.p,
            req.refine,
            assembly="paop",
            rel_tol=req.rel_tol,
            materials=req.materials,
            traction=req.traction,
        )
        assert rep.final_rel_norm < req.rel_tol
    t = time.perf_counter() - t0
    return {
        "batch": "sequential",
        "scenarios_per_s": n / t,
        "t_generation_s": t / n,
        "t_warm_s": 0.0,
        "t_setup_cached_s": float("nan"),
    }


def run(fast: bool = False, quick: bool = False) -> list[dict]:
    batches = [1, 4] if quick else ([1, 4, 16] if fast else [1, 4, 16, 64])
    n_seq = 2 if quick else 4
    repeats = 1 if quick else 3
    rows = [bench_sequential(n_seq)]
    seq_rate = rows[0]["scenarios_per_s"]
    for b in batches:
        row = bench_batched(b, repeats)
        row["speedup_vs_sequential"] = row["scenarios_per_s"] / seq_rate
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: batches {1, 4}, single repeat")
    ap.add_argument("--fast", action="store_true", help="skip batch 64")
    args = ap.parse_args()
    rows = run(fast=args.fast, quick=args.quick)
    print(
        fmt_table(
            rows,
            [
                "batch",
                "scenarios_per_s",
                "t_generation_s",
                "t_warm_s",
                "t_setup_cached_s",
                "speedup_vs_sequential",
            ],
            title=f"Batched GMG-PCG throughput (p={P}, refine={REFINE}, CPU)",
        )
    )


if __name__ == "__main__":
    main()
