"""Paper Fig. 5: kernel-time operator throughput (MDoF/s) vs polynomial
degree, PA baseline vs PAop, at (approximately) fixed DoF count.

The paper's claim: the unoptimized PA path peaks near p=2 and collapses
at high order; PAop stays high through p=8, moving the sweet spot to
p>=6.  Problem sizes are chosen per-p to hold DoFs roughly constant
(the paper's fixed-DoF protocol compensates p-increases by fewer
h-refinements).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, time_fn
from repro.core.operators import ElasticityOperator
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space

# (p, refinements) pairs with ~constant DoFs (~8-20k scalar nodes on CPU)
FIXED_DOF = {1: 3, 2: 2, 3: 2, 4: 1, 5: 1, 6: 1, 7: 1, 8: 0}


def run(ps=(1, 2, 3, 4, 5, 6, 7, 8), dtype=jnp.float64) -> list[dict]:
    rows = []
    for p in ps:
        mesh = beam_hex().refined(FIXED_DOF[p])
        space = H1Space(mesh, p)
        x = jnp.asarray(
            jax.random.normal(jax.random.PRNGKey(p), (space.nscalar, 3), dtype)
        )
        row = {"p": p, "ndof": space.ndof, "nelem": space.nelem}
        for label, assembly in (("pa", "pa_baseline"), ("paop", "paop")):
            op = ElasticityOperator(space, assembly=assembly, dtype=dtype)
            t = time_fn(jax.jit(op.apply), x)
            row[f"{label}_mdof_s"] = space.ndof / t / 1e6
            row[f"{label}_time_s"] = t
        row["speedup"] = row["paop_mdof_s"] / row["pa_mdof_s"]
        rows.append(row)
    return rows


def main(fast: bool = False):
    ps = (1, 2, 4, 8) if fast else (1, 2, 3, 4, 5, 6, 7, 8)
    rows = run(ps)
    print(fmt_table(
        rows,
        ["p", "ndof", "pa_mdof_s", "paop_mdof_s", "speedup"],
        title="Fig. 5 analogue: AddMult throughput vs p (CPU wall)",
    ))
    best_pa = max(rows, key=lambda r: r["pa_mdof_s"])["p"]
    best_paop = max(rows, key=lambda r: r["paop_mdof_s"])["p"]
    print(f"\nsweet spot: PA peaks at p={best_pa}, PAop at p={best_paop}")
    return rows


if __name__ == "__main__":
    main()
