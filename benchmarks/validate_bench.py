"""Validate BENCH_*.json artifacts against their checked-in schemas.

The bench-smoke CI lane runs this after producing the artifacts; a
schema drift (renamed field, wrong type, vanished row) fails CI with the
exact offending path instead of silently shipping an artifact the next
perf comparison can't consume.

The schema is inferred from the document's own ``schema`` field
(``repro.bench.<name>/v<N>`` -> ``benchmarks/schemas/
bench_<name>.schema.json``); ``--schema`` overrides.

    PYTHONPATH=src python -m benchmarks.validate_bench BENCH_*.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA_DIR = os.path.join(os.path.dirname(__file__), "schemas")


def schema_path_for(doc: dict) -> str:
    """benchmarks/schemas/ path for a document's declared schema id."""
    sid = doc.get("schema")
    if not isinstance(sid, str) or not sid.startswith("repro.bench."):
        raise ValueError(
            f"document carries no recognizable schema id (got {sid!r}); "
            f"pass --schema explicitly"
        )
    name = sid[len("repro.bench."):].split("/", 1)[0]
    path = os.path.join(SCHEMA_DIR, f"bench_{name}.schema.json")
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no checked-in schema for {sid!r} (expected {path})"
        )
    return path


def validate_file(artifact: str, schema: str | None = None) -> dict:
    """Validate one artifact; returns the parsed document or raises
    :class:`repro.obs.schema.SchemaError` naming every violation."""
    from repro.obs.schema import validate_json

    with open(artifact) as f:
        doc = json.load(f)
    with open(schema or schema_path_for(doc)) as f:
        validate_json(doc, json.load(f))
    return doc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifacts", nargs="+", help="BENCH_*.json paths")
    ap.add_argument("--schema", default=None,
                    help="explicit schema path (default: inferred from "
                         "the document's schema field)")
    args = ap.parse_args()

    from repro.obs.schema import SchemaError

    failed = False
    for path in args.artifacts:
        try:
            doc = validate_file(path, args.schema)
        except (SchemaError, ValueError, FileNotFoundError) as e:
            failed = True
            print(f"FAIL {path}: {e}")
            continue
        print(f"ok   {path} ({doc['schema']}, {len(doc.get('rows', []))} rows)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
