"""Paper Fig. 6: roofline placement of the elasticity operator.

Reads the dry-run artifacts (runs/dryrun/elasticity__*.json) produced by
``python -m repro.launch.dryrun`` and prints the three roofline terms
per cell against the TPU v5e ceilings, plus the OI trajectory PA -> PAop
computed analytically (Table 5's counts over the streaming-bytes model).
Falls back to analytic-only output if no dry-run artifacts exist yet.

When a ``BENCH_operator_sweep.json`` artifact exists (produced by
``python -m benchmarks.operator_sweep``), its MEASURED batched-operator
rows are placed on the same roofline — analytic OI on the x-axis,
measured FLOP/s over the OI-allowed roof as the achieved fraction — so
the analytic trajectory and the measured trajectory print side by side.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_table
from benchmarks.table5_flops import analytic_flops_per_elem
from repro.core.flops import default_q1d
from repro.launch.roofline import V5E, place_measured
from repro.obs.throughput import streaming_bytes_per_elem


def analytic_rows(ps=(1, 2, 4, 8), itemsize=4):
    rows = []
    for p in ps:
        D, Q = p + 1, default_q1d(p)
        a = analytic_flops_per_elem(p)
        stream = streaming_bytes_per_elem(p, itemsize)
        # baseline additionally streams QVec (9 ch, fwd+bwd) + dense G3D
        qvec = itemsize * 2 * 9 * Q**3
        g3d = itemsize * (3 * D**3) * (3 * Q**3)
        rows.append({
            "p": p,
            "oi_paop": a["paop"] / stream,
            "oi_pa_baseline": a["dense_baseline"] / (stream + qvec + g3d),
            "ridge_point": V5E.peak_flops / V5E.hbm_bw,
        })
    return rows


def dryrun_rows(dryrun_dir="runs/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "elasticity__*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        c = rec["cost"]
        coll = rec["collectives"]
        chips = rec["chips"]
        rows.append({
            "cell": f"{rec['shape']}@{rec['mesh']}",
            "compute_s": c["flops_per_dev"] / V5E.peak_flops,
            "memory_s": c["bytes_per_dev"] / V5E.hbm_bw,
            "collective_s": coll["link_bytes"] / V5E.link_bw,
            "oi_flops_per_byte": (
                c["flops_per_dev"] / c["bytes_per_dev"]
                if c["bytes_per_dev"] else float("nan")
            ),
        })
    return rows


def measured_rows(artifact="BENCH_operator_sweep.json"):
    """Measured operator-sweep rows placed on the v5e roofline (empty
    list when the artifact hasn't been produced yet).  The artifact is
    schema-validated on read — fig6 consumes the same contract the
    bench-smoke CI lane enforces."""
    if not os.path.exists(artifact):
        return []
    from benchmarks.validate_bench import validate_file

    doc = validate_file(artifact)
    rows = []
    for r in doc["rows"]:
        placed = place_measured(
            flops_per_apply=r["flops_per_apply"],
            bytes_per_apply=r["bytes_per_apply"],
            t_apply_s=r["t_apply_s"],
        )
        rows.append({
            "p": r["p"],
            "assembly": r["assembly"],
            "pallas_lane": r.get("pallas_lane", "none"),
            "precision": r.get("precision_policy", "f64"),
            "batch": r["batch"],
            "dofs_per_s": r["dofs_per_s"],
            "gbytes_per_s": r["gbytes_per_s"],
            "oi_measured_at": placed.oi,
            "v5e_roof_fraction": placed.fraction,
            "v5e_bound": placed.bound,
        })
    return rows


def main(fast: bool = False):
    arows = analytic_rows()
    print(fmt_table(
        arows, ["p", "oi_pa_baseline", "oi_paop", "ridge_point"],
        title="Fig. 6 analogue: OI trajectory PA -> PAop vs v5e ridge "
              f"({V5E.peak_flops/1e12:.0f} TF/s / {V5E.hbm_bw/1e9:.0f} GB/s)",
    ))
    drows = dryrun_rows()
    if drows:
        print()
        print(fmt_table(
            drows,
            ["cell", "compute_s", "memory_s", "collective_s",
             "oi_flops_per_byte"],
            title="Roofline terms from dry-run artifacts (per AddMult)",
        ))
    else:
        print("\n(no dry-run artifacts found; run python -m repro.launch.dryrun)")
    mrows = measured_rows()
    if mrows:
        print()
        print(fmt_table(
            mrows,
            ["p", "assembly", "pallas_lane", "precision", "batch",
             "dofs_per_s", "gbytes_per_s", "oi_measured_at",
             "v5e_roof_fraction", "v5e_bound"],
            title="Measured batched operator on the v5e roofline "
                  "(BENCH_operator_sweep.json; lane column is the lane "
                  "that ran — trajectory, not absolute)",
        ))
    else:
        print("\n(no BENCH_operator_sweep.json; run "
              "python -m benchmarks.operator_sweep)")
    return arows + drows + mrows


if __name__ == "__main__":
    main()
