"""Paper Table 3: preconditioner comparison on the GMG hierarchy.

Four solver variants (the paper's fa_amg column maps to an assembled
coarse-solve configuration; classical AMG setup is CPU-shaped and out of
scope on TPU — see DESIGN.md hardware-adaptation table):

  fa_gmg   — assembled fine operator + GMG
  pa_jac   — matrix-free PA + Jacobi-preconditioned PCG (the simple
             directly matrix-free baseline; iteration counts explode)
  pa_gmg   — matrix-free PA + GMG
  paop_gmg — optimized PAop + GMG (this work)

Reports iterations + phase breakdown (Prec. / Form-LS / Solve / Total),
the paper's three-effect story: GMG slashes iterations; PA keeps setup
flat; PAop shrinks Solve.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table
from repro.core.operators import ElasticityOperator
from repro.fem.bc import eliminate_rhs
from repro.fem.mesh import beam_hex
from repro.fem.space import H1Space
from repro.launch.solve import TRACTION, solve_beam
from repro.solvers.cg import pcg


def _pa_jacobi(p: int, refine: int, rel_tol=1e-6, dtype=jnp.float64):
    mesh = beam_hex().refined(refine)
    space = H1Space(mesh, p)
    t0 = time.perf_counter()
    op = ElasticityOperator(space, assembly="paop", dtype=dtype)
    cop = op.constrained()
    dinv = 1.0 / cop.diagonal()
    t1 = time.perf_counter()
    b = jnp.asarray(space.traction_rhs("x1", TRACTION), dtype=dtype)
    b = eliminate_rhs(op.apply, op.ess_mask, b)
    t2 = time.perf_counter()
    res = jax.jit(
        lambda bv: pcg(cop, bv, M=lambda r: dinv * r, rel_tol=rel_tol,
                       maxiter=5000)
    )(b)
    jax.block_until_ready(res.x)
    t3 = time.perf_counter()
    return {
        "solver": "pa_jac", "p": p, "iters": int(res.iterations),
        "prec_s": t1 - t0, "form_s": t2 - t1, "solve_s": t3 - t2,
        "total_s": t3 - t0,
    }


def run(ps=(1, 2, 4), refine: int = 1) -> list[dict]:
    rows = []
    for p in ps:
        for solver, assembly in (
            ("fa_gmg", "fa"), ("pa_gmg", "pa_sumfact_voigt"), ("paop_gmg", "paop"),
        ):
            rep = solve_beam(p, n_h_refine=refine, assembly=assembly)
            rows.append({
                "solver": solver, "p": p, "iters": rep.iterations,
                "prec_s": rep.t_precond, "form_s": rep.t_form_ls,
                "solve_s": rep.t_solve, "total_s": rep.t_total,
            })
        rows.append(_pa_jacobi(p, refine))
    return rows


def main(fast: bool = False):
    rows = run(ps=(1, 2) if fast else (1, 2, 4), refine=1)
    print(fmt_table(
        rows,
        ["p", "solver", "iters", "prec_s", "form_s", "solve_s", "total_s"],
        title="Table 3 analogue: preconditioner comparison (CPU wall)",
    ))
    return rows


if __name__ == "__main__":
    main()
